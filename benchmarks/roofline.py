"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads results/dryrun.json (written by repro.launch.dryrun) and emits, per
(arch x shape x mesh): the three roofline terms, the dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs, and the roofline fraction.
"""
from __future__ import annotations

import json
import os
import sys

from repro.analysis.roofline import HW, model_flops, roofline_terms
from repro.configs import get_config


def build_rows(path="results/dryrun.json", mesh_filter=None, adapter="none",
               variant="none"):
    with open(path) as f:
        records = json.load(f)
    rows = []
    for r in records:
        if not r.get("ok") or r.get("adapter", "none") != adapter:
            continue
        if r.get("variant", "none") != variant:
            continue
        if mesh_filter and len(r["mesh"]) != mesh_filter:
            continue
        cfg = get_config(r["arch"])
        t = roofline_terms(r, cfg)
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "variant": variant,
            "mesh": "x".join(map(str, r["mesh"])),
            "compute_s": t["compute_s"], "memory_s": t["memory_s"],
            "collective_s": t["collective_s"], "dominant": t["dominant"],
            "useful_ratio": t["useful_flops_ratio"],
            "roofline_frac": t["roofline_fraction"],
            "peak_mb": r["memory"].get("peak_device_mb", 0),
            "compile_s": r.get("compile_s", 0),
        })
    return rows


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.json"
    if not os.path.exists(path):
        print("roofline,SKIP,no dryrun.json (run repro.launch.dryrun first)")
        return
    rows = build_rows(path, mesh_filter=2)  # single-pod for the table
    rows += build_rows(path, mesh_filter=2, variant="padded")
    print("arch,shape,variant,mesh,compute_s,memory_s,collective_s,dominant,"
          "useful_ratio,roofline_frac,peak_dev_mb")
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"], x["variant"])):
        print(f"{r['arch']},{r['shape']},{r['variant']},{r['mesh']},"
              f"{r['compute_s']:.4f},{r['memory_s']:.4f},"
              f"{r['collective_s']:.4f},{r['dominant'].replace('_s','')},"
              f"{r['useful_ratio']:.3f},{r['roofline_frac']:.4f},"
              f"{r['peak_mb']:.0f}")


if __name__ == "__main__":
    main()
