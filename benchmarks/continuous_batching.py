"""Continuous batching vs fixed-batch vs paged multi-tenant serving.

The same mixed-adapter request trace served three ways:

  * fixed-batch — ``MultiTenantEngine.generate``: requests are grouped into
    batches of ``--slots`` up front; each batch decodes as a unit, so a
    finished request's lane idles until the whole batch drains, and the next
    batch cannot start early (this is today's ``launch/serve.py`` stream).
  * continuous  — ``repro.hub.ServingEngine``: one shared cache with
    ``--slots`` lanes, per-lane adapter ids AND cache positions; a lane is
    recycled to the next queued request the step after its request ends.
  * paged       — ``repro.hub.PagedServingEngine``: block-table paged KV
    with COW prefix sharing and chunked prefill, run on a second trace
    where every request opens with a shared system prefix and carries a
    short per-request suffix (the production shape paging targets).

With uniform request lengths the first two do the same work; the win
appears under mixed ``max_tokens`` (``--mixed-lengths``), where fixed
batches serialize on their slowest member. Parity is checked
token-for-token against the fixed-batch engine on every request (the paged
trace is pinned against the continuous engine, itself pinned here).

Besides throughput, the bench reports **memory residency** — resident
requests per GB of pinned KV. Both engines are provisioned for the same
worst-case request (``cache_size`` rows); the lane engine pins
``slots * cache_size`` rows no matter what the trace does, while the paged
engine pins only the working set actually referenced by admitted requests
(shared prefix pages counted once; evictable registry-only pages excluded
— they are reclaimed on demand). The paged engine must clear >= 2x.
``p99_ttft_ms_*`` (wall-clock submit -> first token, queue wait included)
is gate-tracked lower-is-better via ``gate_max``.

``--json [PATH]`` writes the machine-readable result (schema in
``_emit.py``) that CI's tier3-bench gate tracks.

  PYTHONPATH=src python benchmarks/continuous_batching.py --smoke --json
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import _emit
from repro.configs import get_config, get_smoke_config
from repro.launch.serve import make_adapters
from repro.models import layers, lm
from repro.serving import MultiTenantEngine
from repro.hub import AdapterStore, PagedServingEngine, ServingEngine


def p99_ttft_ms(futs) -> float:
    # shared percentile math with every other latency lane (_emit schema v2)
    return _emit.percentile([f.ttft * 1e3 for f in futs], 99)


def serve_fixed_batches(cfg, params, packs, toks, names, lens, slots):
    """Batches of ``slots`` requests; each batch decodes max(lens) tokens
    (a fixed batch cannot retire early members)."""
    engine = MultiTenantEngine(cfg, params)
    for p in packs:
        engine.register(p)
    out = [None] * len(names)
    t0 = time.perf_counter()
    for lo in range(0, len(names), slots):
        hi = min(lo + slots, len(names))
        T = max(lens[lo:hi])
        seq, _ = engine.generate({"tokens": jnp.asarray(toks[lo:hi])},
                                 names[lo:hi], T)
        seq = np.asarray(seq)
        for j in range(lo, hi):
            out[j] = seq[j - lo][:lens[j]]
    return out, time.perf_counter() - t0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--adapters", type=int, default=3)
    ap.add_argument("--mixed-lengths", action="store_true", default=True)
    ap.add_argument("--int8", action="store_true",
                    help="serve from int8-quantized store packs, with int8 "
                    "device-side delta tables (parity is then vs the "
                    "quantized adapters, still exact)")
    ap.add_argument("--json", nargs="?", const="", default=None,
                    metavar="PATH",
                    help="write BENCH_continuous_batching.json (or PATH) "
                    "with the _emit schema")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    with layers.compute_precision(jnp.float32):
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        packs = make_adapters(cfg, params, args.adapters,
                              jax.random.PRNGKey(7), multi_tenant=True)
        import tempfile
        store = AdapterStore(tempfile.mkdtemp(prefix="cc-bench-store-"))
        for p in packs:
            store.add(p, values="int8" if args.int8 else "f32")
        if args.int8:
            # both paths must serve the SAME (quantized) adapters for
            # token parity; reload them through the store
            packs = [store.get(p.name) for p in packs]

        rng = np.random.default_rng(0)
        R = args.requests
        names = [p.name for p in packs]
        pool = names + [None]
        names = (names + [pool[rng.integers(len(pool))]
                          for _ in range(R - len(names))])[:R]
        lens = [args.tokens if not args.mixed_lengths
                else int(rng.integers(2, args.tokens + 1)) for _ in range(R)]
        toks = np.asarray(jax.random.randint(
            jax.random.PRNGKey(1), (R, args.prompt_len), 0, cfg.vocab_size))

        want, dt_fix = serve_fixed_batches(cfg, params, packs, toks, names,
                                           lens, args.slots)

        engine = ServingEngine(cfg, params, slots=args.slots, store=store,
                               cache_size=args.prompt_len + args.tokens + 8,
                               table_dtype="int8" if args.int8 else "f32")
        for p in packs:
            # resolve through the store: with --int8 this is the direct
            # QuantPack -> device-table path (no f32 round trip)
            engine.register(p.name)
        futs = [engine.submit(toks[i], names[i], max_tokens=lens[i])
                for i in range(R)]
        dt_cc = engine.run()

        # ---- paged trace: shared system prefix + short per-request suffix.
        # Both engines are provisioned for the same worst-case request
        # (cache_size rows); paging's point is paying for actual tokens.
        cache_size = args.prompt_len + args.tokens + 8
        prefix = np.asarray(jax.random.randint(
            jax.random.PRNGKey(2), (8,), 0, cfg.vocab_size), np.int32)
        sufs = [int(rng.integers(0, 4)) for _ in range(R)]
        prompts = [np.concatenate([prefix, np.asarray(jax.random.randint(
            jax.random.PRNGKey(100 + i), (sufs[i],), 0, cfg.vocab_size))]
            ).astype(np.int32) for i in range(R)]
        lens_p = [int(rng.integers(2, 6)) for _ in range(R)]

        ref = ServingEngine(cfg, params, slots=args.slots, store=store,
                            cache_size=cache_size,
                            table_dtype="int8" if args.int8 else "f32")
        for p in packs:
            ref.register(p.name)
        rfuts = [ref.submit(prompts[i], names[i], max_tokens=lens_p[i])
                 for i in range(R)]
        ref.run()

        paged = PagedServingEngine(
            cfg, params, slots=args.slots, num_pages=97, page_size=2,
            max_len=cache_size, chunk_size=4, store=store,
            table_dtype="int8" if args.int8 else "f32")
        for p in packs:
            paged.register(p.name)
        # seed the prefix registry the way production would: the system
        # prompt is prefilled once per tenant (prefix pages are salted by
        # the adapter stack), every later request shares its pages
        for nm in dict.fromkeys(names):
            paged.submit(prefix, nm, max_tokens=1)
        paged.run()
        pfuts = [paged.submit(prompts[i], names[i], max_tokens=lens_p[i])
                 for i in range(R)]
        dt_pg = paged.run()

    n_tok = sum(lens)
    for i, f in enumerate(futs):
        got = f.result()
        assert np.array_equal(got, want[i]), \
            f"request {i} diverged: {got} != {want[i]}"
    n_tok_p = sum(lens_p)
    for i, (rf, pf) in enumerate(zip(rfuts, pfuts)):
        assert np.array_equal(pf.result(), rf.result()), \
            f"paged request {i} diverged: {pf.result()} != {rf.result()}"

    # residency: resident requests per GB of KV the engine pins for them.
    # The lane engine pins its full stripe allocation; the paged engine
    # pins the peak working set of admitted requests (see module doc).
    res_cont = args.slots / (engine.kv_cache_bytes() / 1e9)
    res_paged = paged.peak_resident / (
        paged.peak_ws_pages * paged.page_bytes() / 1e9)
    gain = res_paged / res_cont

    print(f"arch={cfg.name} requests={R} slots={args.slots} "
          f"tokens={n_tok} adapters={args.adapters}")
    print(f"fixed-batch: {dt_fix*1e3:8.1f}ms  {n_tok/dt_fix:8.1f} tok/s")
    print(f"continuous:  {dt_cc*1e3:8.1f}ms  {n_tok/dt_cc:8.1f} tok/s "
          f"({engine.step_count} steps, {engine.decode_slot_waste} idle-lane "
          f"steps)")
    print(f"paged:       {dt_pg*1e3:8.1f}ms  {n_tok_p/dt_pg:8.1f} tok/s "
          f"({paged.step_count} steps, {paged.prefill_chunks} prefill "
          f"chunks, {paged.pool.prefix_hits} prefix hits, "
          f"{paged.pool.cow_copies} COW copies)")
    print(f"residency: continuous {res_cont:8.1f} req/GB "
          f"(slots x {engine.cache_size}-row stripes)  paged "
          f"{res_paged:8.1f} req/GB ({paged.peak_resident} resident / "
          f"{paged.peak_ws_pages} pages x {paged.page_size} rows)  "
          f"gain {gain:.2f}x")
    print(f"p99 TTFT: continuous {p99_ttft_ms(futs):.1f}ms  "
          f"paged {p99_ttft_ms(pfuts):.1f}ms")
    print(f"speedup: {dt_fix/dt_cc:.2f}x   PARITY OK (token-for-token, "
          f"{R} + {R} requests)")
    assert gain >= 2.0, \
        f"paged residency gain {gain:.2f}x < 2x over the stripe engine"

    if args.json is not None:
        table_bytes = engine.engine.table_nbytes()
        res = _emit.result(
            "continuous_batching", cfg.name,
            metrics={
                "tokens_per_s_continuous": n_tok / dt_cc,
                "tokens_per_s_fixed": n_tok / dt_fix,
                "tokens_per_s_paged": n_tok_p / dt_pg,
                "speedup": dt_fix / dt_cc,
                "decode_steps": engine.step_count,
                "idle_lane_steps": engine.decode_slot_waste,
                "adapter_table_bytes": table_bytes["total"],
                "resident_requests_per_gb_continuous": res_cont,
                "resident_requests_per_gb_paged": res_paged,
                "residency_gain_paged": gain,
                "p99_ttft_ms_continuous": p99_ttft_ms(futs),
                "p99_ttft_ms_paged": p99_ttft_ms(pfuts),
                "prefix_hits": paged.pool.prefix_hits,
                "cow_copies": paged.pool.cow_copies,
            },
            meta={"smoke": args.smoke, "requests": R, "slots": args.slots,
                  "tokens": n_tok, "adapters": args.adapters,
                  "int8": bool(args.int8),
                  "paged": {"num_pages": paged.num_pages,
                            "page_size": paged.page_size,
                            "peak_ws_pages": paged.peak_ws_pages,
                            "peak_used_pages": paged.peak_used_pages,
                            "peak_resident": paged.peak_resident}})
        print(f"wrote {_emit.emit(res, args.json or None)}")


if __name__ == "__main__":
    main()
