"""Paper App. D Table 6 analog: training memory + speed per adapter type.

Reports (a) optimizer-state + gradient bytes — the component the paper's
packed implementation shrinks (-16.6% peak GPU memory on LLaMA2-7B), exact
by construction, and (b) measured step wall-clock on this host for the
reduced config (relative numbers are the meaningful part on CPU).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import AdapterConfig, RunConfig, TrainConfig, get_smoke_config
from repro.configs.base import ShapeSpec
from repro.data import make_batch
from repro.runtime import Trainer
from repro.runtime.trainer import TrainerConfig

SHAPE = ShapeSpec("bench", 64, 8, "train")
ARCH = "starcoder2-7b"

METHODS = [
    ("full-ft", AdapterConfig(kind="none")),
    ("lora", AdapterConfig(kind="lora", rank=8)),
    ("dora", AdapterConfig(kind="dora", rank=8)),
    ("shira-packed", AdapterConfig(kind="shira", mask="wm", sparsity=0.98,
                                   packed=True)),
    ("shira-hook", AdapterConfig(kind="shira", mask="wm", sparsity=0.98,
                                 packed=False)),
]


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def main() -> None:
    print("method,trainable_mb,opt_state_mb,grad_mb,step_ms")
    for name, acfg in METHODS:
        cfg = get_smoke_config(ARCH)
        run = RunConfig(model=cfg, shape=SHAPE, adapter=acfg,
                        train=TrainConfig(learning_rate=1e-3, total_steps=10,
                                          warmup_steps=1))
        tr = Trainer(run, TrainerConfig())
        state = tr.init_state()
        step = tr.build_step()
        batch = {k: jnp.asarray(v) for k, v in
                 make_batch(cfg, SHAPE, seed=0, step=0).items()}
        state, m = step(state, batch)          # compile
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        reps = 5
        for _ in range(reps):
            state, m = step(state, batch)
        jax.block_until_ready(m["loss"])
        dt = (time.perf_counter() - t0) / reps * 1e3
        t_mb = tree_bytes(state["trainable"]) / 1e6
        o_mb = (tree_bytes(state["mu"]) + tree_bytes(state["nu"])) / 1e6
        print(f"{name},{t_mb:.2f},{o_mb:.2f},{t_mb:.2f},{dt:.1f}")


if __name__ == "__main__":
    main()
