"""Training memory + speed: per-adapter-type table, multi-adapter lane.

Part 1 (paper App. D Table 6 analog): optimizer-state + gradient bytes per
adapter method — the component the paper's packed implementation shrinks
(-16.6% peak GPU memory on LLaMA2-7B) — plus measured step wall-clock for
the reduced config (relative numbers are the meaningful part on CPU).

Part 2 (the gated lane, ``--json``): the continuous-personalization
trainer. ``MultiAdapterTrainer`` holds A adapters' values + optimizer
moments resident per device, so the capacity metric is

  adapters_per_gb_<mode> = how many concurrently-training adapters fit in
                           1 GB of trainable+optimizer state

for f32 vs int8 moment storage (``training.qstate`` — bytes are exact by
construction, not sampled), ``moment_bytes_ratio`` (f32/int8 moment bytes,
~3.9x), and ``swap_latency_ms`` — publish-to-first-token of a versioned
hot-swap on a live ServingEngine (``gate_max``: a latency ceiling).

  PYTHONPATH=src python benchmarks/train_efficiency.py --smoke --json
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import _emit  # noqa: E402

from repro.configs import AdapterConfig, RunConfig, TrainConfig, get_smoke_config
from repro.configs.base import ShapeSpec
from repro.data import make_batch
from repro.runtime import Trainer
from repro.runtime.trainer import TrainerConfig
from repro.training import MultiAdapterTrainer, multi_batch_iterator

SHAPE = ShapeSpec("bench", 64, 8, "train")
ARCH = "starcoder2-7b"

METHODS = [
    ("full-ft", AdapterConfig(kind="none")),
    ("lora", AdapterConfig(kind="lora", rank=8)),
    ("dora", AdapterConfig(kind="dora", rank=8)),
    ("shira-packed", AdapterConfig(kind="shira", mask="wm", sparsity=0.98,
                                   packed=True)),
    ("shira-hook", AdapterConfig(kind="shira", mask="wm", sparsity=0.98,
                                 packed=False)),
]


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)
               if x is not None)


def method_table() -> None:
    print("method,trainable_mb,opt_state_mb,grad_mb,step_ms")
    for name, acfg in METHODS:
        cfg = get_smoke_config(ARCH)
        run = RunConfig(model=cfg, shape=SHAPE, adapter=acfg,
                        train=TrainConfig(learning_rate=1e-3, total_steps=10,
                                          warmup_steps=1))
        tr = Trainer(run, TrainerConfig())
        state = tr.init_state()
        step = tr.build_step()
        batch = {k: jnp.asarray(v) for k, v in
                 make_batch(cfg, SHAPE, seed=0, step=0).items()}
        state, m = step(state, batch)          # compile
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        reps = 5
        for _ in range(reps):
            state, m = step(state, batch)
        jax.block_until_ready(m["loss"])
        dt = (time.perf_counter() - t0) / reps * 1e3
        t_mb = tree_bytes(state["trainable"]) / 1e6
        o_mb = (tree_bytes(state["mu"]) + tree_bytes(state["nu"])) / 1e6
        print(f"{name},{t_mb:.2f},{o_mb:.2f},{t_mb:.2f},{dt:.1f}")


def _moment_bytes(state) -> int:
    return sum(tree_bytes(state[k])
               for k in ("mu", "nu", "mu_scale", "nu_scale"))


def multi_adapter_lane(args) -> dict:
    """The gated metrics: multi-adapter state capacity + step time."""
    from repro.data import TaskSpec
    shape = (ShapeSpec("tiny", 8, 8, "train") if args.smoke else SHAPE)
    run = RunConfig(
        model=get_smoke_config(ARCH), shape=shape,
        adapter=AdapterConfig(kind="shira", mask="rand", sparsity=0.95),
        train=TrainConfig(learning_rate=1e-2, total_steps=100,
                          warmup_steps=2))
    A = args.adapters
    names = [f"u{i}" for i in range(A)]
    metrics, reps = {}, args.reps
    mb = next(multi_batch_iterator(run.model, shape, 0,
                                   [TaskSpec(i) for i in range(A)]))
    batch = {k: jnp.asarray(v) for k, v in mb.items()}
    mt = None
    moment_b = {}
    for mode in ("f32", "int8"):
        mt = MultiAdapterTrainer(run, names, moments=mode)
        state = mt.init_state()
        vals_b = tree_bytes(state["values"])
        moment_b[mode] = _moment_bytes(state)
        metrics[f"adapters_per_gb_{mode}"] = (
            A * 1e9 / (vals_b + moment_b[mode]))
        step = mt.build_step()
        state, m = step(state, batch)          # compile
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for _ in range(reps):
            state, m = step(state, batch)
        jax.block_until_ready(m["loss"])
        metrics[f"multi_step_ms_{mode}"] = (
            (time.perf_counter() - t0) / reps * 1e3)
    metrics["moment_bytes_ratio"] = moment_b["f32"] / moment_b["int8"]

    # sequential baseline (informational): one adapter's own step, x A
    tr = Trainer(run, TrainerConfig())
    st = tr.init_state()
    single = tr.build_step()
    sb = {k: jnp.asarray(v)
          for k, v in make_batch(run.model, shape, seed=0, step=0).items()}
    st, m = single(st, sb)
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for _ in range(reps):
        st, m = single(st, sb)
    jax.block_until_ready(m["loss"])
    single_ms = (time.perf_counter() - t0) / reps * 1e3
    metrics["concurrency_speedup"] = (
        A * single_ms / metrics["multi_step_ms_f32"])

    # swap latency: publish a new version against a LIVE engine, measure
    # publish -> first token on the new version (slot_pad keeps the table
    # shapes constant, so no recompile rides the measurement)
    from repro.hub import AdapterStore, ServingEngine
    packs = mt.export_packs(state)
    store = AdapterStore(tempfile.mkdtemp(prefix="train-eff-store-"))
    store.publish(packs[0])
    eng = ServingEngine(run.model, mt.base, slots=2, cache_size=32,
                        store=store, slot_pad=4)
    rng = np.random.default_rng(0)
    prompt = rng.integers(1, run.model.vocab_size, (5,))
    f = eng.submit(prompt, "u0", max_tokens=2)
    eng.run()                                  # warm every jit path
    assert f.adapter == "u0@1"
    swaps = []
    for _ in range(reps):
        t0 = time.perf_counter()
        store.publish(packs[0])
        f = eng.submit(prompt, "u0", max_tokens=1)
        eng.step()                             # admit + prefill + retire old
        swaps.append((time.perf_counter() - t0) * 1e3)
        assert f.done()
    metrics["swap_latency_ms"] = min(swaps)
    eng.shutdown(include_store=True)
    return metrics


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes (CI-class machines)")
    ap.add_argument("--adapters", type=int, default=3)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--skip-table", action="store_true",
                    help="only the multi-adapter lane (faster)")
    ap.add_argument("--json", nargs="?", const="", default=None,
                    metavar="PATH", help="write BENCH_train_efficiency.json "
                    "(or PATH) with the _emit schema")
    args = ap.parse_args()

    if not args.skip_table and args.json is None:
        method_table()
    metrics = multi_adapter_lane(args)
    print(f"\nmulti-adapter lane (A={args.adapters}):")
    for k in sorted(metrics):
        print(f"  {k}: {metrics[k]:.2f}")
    if args.json is not None:
        res = _emit.result("train_efficiency", ARCH + "-smoke", metrics,
                           meta={"smoke": args.smoke,
                                 "adapters": args.adapters,
                                 "reps": args.reps})
        print("wrote", _emit.emit(res, args.json or None))


if __name__ == "__main__":
    main()
