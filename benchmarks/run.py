"""Benchmark harness — one section per paper table/figure.

  adapter_quality   -> Tables 1-3 (LoRA vs SHiRA masks vs DoRA)
  multi_adapter     -> Table 4   (fusion interference, %Drop)
  rapid_switching   -> Fig. 5    (scatter vs fuse)
  train_efficiency  -> Table 6   (memory / step time per adapter)
  roofline          -> EXPERIMENTS §Roofline (from dry-run artifacts)

Each section prints CSV. Run everything:
  PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import sys
import time
import traceback

from benchmarks import (adapter_quality, multi_adapter, rapid_switching,
                        roofline, train_efficiency)

SECTIONS = [
    ("rapid_switching", rapid_switching.main),
    ("train_efficiency", train_efficiency.main),
    ("adapter_quality", adapter_quality.main),
    ("multi_adapter", multi_adapter.main),
    ("roofline", roofline.main),
]


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    for name, fn in SECTIONS:
        if only and only != name:
            continue
        print(f"\n### {name} " + "#" * (60 - len(name)))
        t0 = time.time()
        try:
            fn()
        except Exception:  # noqa: BLE001 — keep the harness running
            traceback.print_exc()
            print(f"{name},ERROR")
        print(f"### {name} done in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
