"""SLO load bench: tail latency + goodput under Zipf/bursty/overload traffic.

The fixed-batch and continuous-batching benches measure throughput on
tidy traces. Production multi-adapter serving is judged on *tails*: what
p99 latency and TTFT look like when arrivals are bursty, adapter
popularity is Zipf, and an overload phase floods the queue. This bench
drives the paged engine (``repro.hub.PagedServingEngine``) with
``repro.serving.loadgen`` through a three-phase trace:

    normal -> overload (rate x ``--overload``) -> normal

Only the ``--hot`` Zipf-head adapters are pre-registered; the tail stays
on disk, so the trace carries real *cold* admissions (disk load + table
rebuild + H2D on first touch). The SAME generated schedule is replayed
twice — once through the synchronous path and once with the async
prefetch pipeline (``async_prefetch=True``) — and the cold-adapter TTFT
tail is compared head-to-head. The engines run with ``--slot-pad``
slot-capacity bucketing so a cold registration never changes the device
-table shapes (no prefill/decode recompile inside the measured trace).

Reported via the shared ``_emit`` schema so CI's tier3 gate can track
them (percentiles from ``_emit.percentiles`` — the same math every
latency lane quotes); all latency/throughput lanes come from the async
(measured) run, the sync run contributes the ``*_sync`` comparison
lanes:

  * ``p50/p95/p99_latency_ms`` — end-to-end submit -> final token
    (queue wait included; gate_max lanes in baseline.json)
  * ``p50/p99_ttft_ms`` — submit -> first token
  * ``p50/p99_ttft_cold_ms`` vs ``p99_ttft_cold_sync_ms`` — the cold
    -admission TTFT tail with and without the prefetch pipeline;
    ``p99_ttft_cold_ms`` is a gate_max lane
  * ``prefetch_hit_rate`` / ``prefetch_stall_ms`` — store prefetch
    outcomes and the stall time the pipeline failed to hide
  * ``tokens_per_s`` vs ``goodput_tok_s`` — raw throughput vs tokens from
    requests that met ``--slo-ms``; under overload these diverge, which
    is the number that matters
  * ``slo_violation_rate`` — fraction of completed requests over SLO
  * with ``--chaos``: ``goodput_under_faults`` (chaos-pass goodput as a
    fraction of the fault-free async run; gated floor) plus
    ``shed_rate`` / ``degraded_rate`` ceilings — the chaos pass replays
    the same trace under the seeded fault injector
    (``repro.runtime.faults``) and hard-asserts the chaos contract
    (every future terminal, every failure typed, goodput >= 70%)

``--trace PATH`` installs the serving tracer (``repro.analysis.trace``)
for the measured (async) run, writes the JSONL + Chrome exports, prints
the replay cost model's wall-time attribution and the
``replay.verify_overlap()`` check — how much of the predicted
disk-load/table-build hiding the pipeline actually realized (CI gates
this via ``benchmarks/check_replay.py``). ``--plan-cache PATH``
installs an autotuned sidedelta tile-plan cache (``repro.analysis
.autotune``) before the engines compile.

  PYTHONPATH=src python benchmarks/slo_load.py --smoke --json \
      --trace TRACE_slo_load.jsonl
"""
from __future__ import annotations

import argparse
import tempfile

import jax
import jax.numpy as jnp

import _emit
from repro.analysis import autotune, replay, trace
from repro.configs import get_config, get_smoke_config
from repro.hub import AdapterStore, PagedServingEngine
from repro.launch.serve import make_adapters
from repro.models import layers, lm
from repro.serving import loadgen


def build_serving(cfg, params, packs, args, async_mode: bool,
                  chaos: bool = False):
    """A fresh store + paged engine for one pass over the trace.

    Every pack is written to its own store; only the ``--hot`` Zipf-head
    adapters stay resident/registered — the tail is explicitly evicted
    back to the disk tier so its first touch is a true cold admission.
    The chaos pass additionally arms the NaN guard (so an injected
    poisoned slot is quarantined, not emitted as garbage)."""
    store = AdapterStore(tempfile.mkdtemp(prefix="cc-slo-store-"))
    for p in packs:
        store.add(p, values="f32")
    for p in packs[args.hot:]:
        store.evict(p.name)
    engine = PagedServingEngine(
        cfg, params, slots=args.slots, num_pages=args.num_pages,
        page_size=args.page_size, max_len=args.max_len,
        chunk_size=args.chunk_size, store=store,
        async_prefetch=async_mode, slot_pad=args.slot_pad,
        nan_guard=chaos)
    for p in packs[:args.hot]:
        engine.register(p.name)
    return store, engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--adapters", type=int, default=6)
    ap.add_argument("--hot", type=int, default=2,
                    help="Zipf-head adapters pre-registered (warm); the "
                    "rest are cold on first touch")
    ap.add_argument("--slot-pad", type=int, default=8,
                    help="table slot-capacity bucket (keep >= --adapters "
                    "+ 2 so cold admissions never recompile)")
    ap.add_argument("--num-pages", type=int, default=97)
    ap.add_argument("--page-size", type=int, default=2)
    ap.add_argument("--chunk-size", type=int, default=4)
    ap.add_argument("--duration", type=float, default=0.4,
                    help="seconds per traffic phase")
    ap.add_argument("--rate", type=float, default=25.0,
                    help="normal-phase arrival rate (requests/s)")
    ap.add_argument("--overload", type=float, default=8.0,
                    help="overload-phase rate multiplier")
    ap.add_argument("--burst", type=float, default=3.0,
                    help="arrival burstiness (1 = Poisson)")
    ap.add_argument("--zipf", type=float, default=1.1,
                    help="adapter-popularity Zipf exponent")
    ap.add_argument("--slo-ms", type=float, default=1500.0,
                    help="per-request end-to-end latency SLO")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chaos", action="store_true",
                    help="run a third pass over the SAME trace with the "
                    "fault injector installed (seeded 10%% disk failures, "
                    "injected I/O latency, payload corruption, worker "
                    "deaths, one poisoned slot) and gate goodput-under-"
                    "faults against the fault-free async run")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write the serving trace of the async run "
                    "(JSONL; a .chrome.json twin is written next to it) "
                    "and print replay attribution + overlap verification")
    ap.add_argument("--plan-cache", nargs="?", const="benchmarks/"
                    "plan_cache.json", default=None, metavar="PATH",
                    help="install an autotuned sidedelta plan cache "
                    "before compiling")
    ap.add_argument("--json", nargs="?", const="", default=None,
                    metavar="PATH",
                    help="write BENCH_slo_load.json (or PATH) with the "
                    "_emit schema")
    args = ap.parse_args()
    if not 0 < args.hot < args.adapters:
        raise SystemExit("need 0 < --hot < --adapters: the bench measures "
                         "warm AND cold admissions")

    installed = 0
    if args.plan_cache is not None:
        installed = autotune.maybe_install_file(args.plan_cache)
        print(f"plan cache: {installed} plans installed "
              f"from {args.plan_cache}")

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    prompt_hi = 12
    gen_max = 8
    args.max_len = args.page_size * (
        (4 + prompt_hi + gen_max) // args.page_size + 2)
    with layers.compute_precision(jnp.float32):
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        packs = make_adapters(cfg, params, args.adapters,
                              jax.random.PRNGKey(7), multi_tenant=True)

        gen = loadgen.LoadGen(
            adapters=[p.name for p in packs], vocab=cfg.vocab_size,
            seed=args.seed, zipf_s=args.zipf,
            phases=[loadgen.Phase(args.duration, args.rate, args.burst),
                    loadgen.Phase(args.duration, args.rate * args.overload,
                                  args.burst),
                    loadgen.Phase(args.duration, args.rate, args.burst)],
            prompt_len=(4, prompt_hi), max_tokens=(2, gen_max),
            shared_prefix=4)
        reqs = gen.schedule()

        if not reqs:
            raise SystemExit("trace generated zero arrivals — raise "
                             "--rate or --duration")

        reports = {}
        engines = {}
        tracer = None
        for mode in ("sync", "async"):
            store, engine = build_serving(cfg, params, packs, args,
                                          async_mode=(mode == "async"))
            # warmup: compile prefill/decode at the padded table capacity
            # and seed the prefix registry, exactly like steady-state
            # production — first-request compile time must not masquerade
            # as queueing latency (cold admissions inside the measured
            # trace reuse these shapes thanks to --slot-pad)
            for p in packs[:args.hot]:
                engine.submit(reqs[0].prompt[:4 + 1], p.name, max_tokens=1)
            engine.run()
            if mode == "async" and args.trace:
                tracer = trace.install()
            reports[mode] = loadgen.run(engine, reqs, slo_ms=args.slo_ms)
            if mode == "async" and tracer is not None:
                trace.uninstall()
            engine.shutdown(include_store=True)
            engines[mode] = (store, engine)

        # chaos pass: the same trace through an engine under injected
        # faults — the robustness twin of the async (measured) run
        chaos_rep, chaos_inj, chaos_engine = None, None, None
        if args.chaos:
            from repro.runtime import faults
            store_c, chaos_engine = build_serving(cfg, params, packs, args,
                                                  async_mode=True,
                                                  chaos=True)
            for p in packs[:args.hot]:
                chaos_engine.submit(reqs[0].prompt[:4 + 1], p.name,
                                    max_tokens=1)
            chaos_engine.run()          # compile outside the fault window
            chaos_inj = faults.install(faults.FaultPlan(
                seed=args.seed, disk_fail_p=0.10, io_latency_s=0.002,
                corrupt_p=0.05, worker_death_p=0.05,
                poison_step=chaos_engine.step_count + 8, poison_slot=0))
            try:
                chaos_rep = loadgen.run(
                    chaos_engine, reqs, slo_ms=args.slo_ms,
                    deadline_s=4.0 * args.slo_ms / 1e3)
            finally:
                faults.uninstall()
            chaos_engine.shutdown(include_store=True)

    rep = reports["async"]          # the measured run: all primary lanes
    rep_sync = reports["sync"]
    store, engine = engines["async"]

    per_phase = {pi: len(v) for pi, v in
                 sorted(rep.per_phase_latencies_ms.items())}
    print(f"arch={cfg.name} slots={args.slots} adapters={args.adapters} "
          f"(hot {args.hot}) pages={args.num_pages}x{args.page_size} "
          f"slot_pad={args.slot_pad}")
    print(f"offered {rep.offered} requests over "
          f"{3 * args.duration:.1f}s of trace (per phase: {per_phase}); "
          f"completed {rep.completed} in {rep.wall_s:.2f}s wall, "
          f"{rep.steps} steps")
    lat = _emit.percentiles(rep.latencies_ms, (50, 95, 99), "latency_ms")
    ttft = _emit.percentiles(rep.ttfts_ms, (50, 99), "ttft_ms")
    print(f"latency p50/p95/p99: {lat['p50_latency_ms']:.1f} / "
          f"{lat['p95_latency_ms']:.1f} / {lat['p99_latency_ms']:.1f} ms   "
          f"TTFT p50/p99: {ttft['p50_ttft_ms']:.1f} / "
          f"{ttft['p99_ttft_ms']:.1f} ms")
    print(f"throughput {rep.tokens_per_s:.1f} tok/s; goodput "
          f"(SLO {args.slo_ms:.0f}ms) {rep.goodput_tok_s:.1f} tok/s; "
          f"violations {rep.slo_violation_rate:.1%}")
    print(f"paged: {engine.prefill_chunks} prefill chunks, "
          f"{engine.pool.prefix_hits} prefix hits, "
          f"{engine.pool.cow_copies} COW copies")

    # cold-admission comparison: same schedule, sync vs async pipeline
    if not rep.ttfts_cold_ms or not rep_sync.ttfts_cold_ms:
        raise SystemExit("trace produced no cold admissions — lower --hot "
                         "or raise --duration/--rate")
    cold = _emit.percentiles(rep.ttfts_cold_ms, (50, 99), "ttft_cold_ms")
    cold_sync = _emit.percentiles(rep_sync.ttfts_cold_ms, (50, 99),
                                  "ttft_cold_ms", "_sync")
    hits, misses = store.prefetch_hits, store.prefetch_misses
    hit_rate = hits / max(hits + misses, 1)
    mt = engine.engine
    print(f"cold admissions: {len(rep.ttfts_cold_ms)} async / "
          f"{len(rep_sync.ttfts_cold_ms)} sync of {rep.offered} requests")
    print(f"cold TTFT p50/p99: async {cold['p50_ttft_cold_ms']:.1f} / "
          f"{cold['p99_ttft_cold_ms']:.1f} ms   sync "
          f"{cold_sync['p50_ttft_cold_ms_sync']:.1f} / "
          f"{cold_sync['p99_ttft_cold_ms_sync']:.1f} ms   "
          f"(p99 gain {cold_sync['p99_ttft_cold_ms_sync'] / max(cold['p99_ttft_cold_ms'], 1e-9):.2f}x)")
    print(f"prefetch: {hits} hits / {misses} misses "
          f"(hit rate {hit_rate:.1%}); table builds: "
          f"{mt.async_builds} kicked, {mt.async_adopted} adopted, "
          f"{mt.async_stale} stale")
    if installed:
        from repro.kernels.sidedelta import plan_cache_stats
        print(f"plan cache: {plan_cache_stats['hits']} hits, "
              f"{plan_cache_stats['misses']} misses, "
              f"{plan_cache_stats['rejected']} rejected")

    assert rep.completed == rep.offered, \
        f"dropped requests: {rep.completed}/{rep.offered}"
    assert rep_sync.completed == rep_sync.offered, \
        f"sync pass dropped requests: {rep_sync.completed}/{rep_sync.offered}"

    goodput_under_faults = None
    if chaos_rep is not None:
        goodput_under_faults = (chaos_rep.goodput_tok_s
                                / max(rep.goodput_tok_s, 1e-9))
        health = chaos_engine.health()
        print(f"chaos: completed {chaos_rep.completed}/{chaos_rep.offered} "
              f"(failed {chaos_rep.failed}, shed {chaos_rep.shed}, "
              f"degraded {chaos_rep.degraded}); goodput "
              f"{chaos_rep.goodput_tok_s:.1f} tok/s = "
              f"{goodput_under_faults:.1%} of fault-free; injected "
              f"{chaos_inj.counts}; errors {chaos_rep.errors_by_type}; "
              f"quarantined {health['quarantined']}")
        # the chaos contract (zero unhandled exceptions is implied by
        # reaching this line: loadgen.run drives step() bare)
        assert chaos_rep.completed + chaos_rep.failed == chaos_rep.offered, \
            (f"untracked requests under faults: {chaos_rep.completed} + "
             f"{chaos_rep.failed} != {chaos_rep.offered}")
        typed = {"StoreError", "AdapterUnavailable", "RequestShed",
                 "SlotPoisoned", "TableBuildError"}
        untyped = set(chaos_rep.errors_by_type) - typed
        assert not untyped, f"untyped failures under faults: {untyped}"
        assert goodput_under_faults >= 0.70, \
            (f"goodput under faults {goodput_under_faults:.1%} < 70% of "
             f"the fault-free run")

    stall_ms = 0.0
    realized = None
    if tracer is not None:
        events = list(tracer.events())
        stall_ms = sum(e.get("dur", 0.0) for e in events
                       if e.get("ph") == "X"
                       and e.get("name") == "prefetch.stall") / 1e3
        jsonl = tracer.to_jsonl(args.trace)
        chrome = tracer.to_chrome(
            args.trace.rsplit(".jsonl", 1)[0] + ".chrome.json"
            if args.trace.endswith(".jsonl") else args.trace + ".chrome.json")
        att = replay.attribute(tracer, wall_us=rep.wall_s * 1e6)
        print(f"trace: {len(tracer)} events -> {jsonl} (+ {chrome}); "
              f"spans cover {att['coverage']:.1%} of wall")
        for row in replay.critical_path(tracer, top=5):
            print(f"  {row['name']:<16} {row['self_us'] / 1e3:9.2f} ms "
                  f"({row['frac']:.1%})")
        vo = replay.verify_overlap(events)
        realized = vo["realized_frac"]
        print(f"overlap: {vo['async_spans']} worker spans, "
              f"{vo['async_us'] / 1e3:.1f} ms async work; hidden "
              f"{vo['measured_hidden_us'] / 1e3:.1f} of "
              f"{vo['predicted_hidden_us'] / 1e3:.1f} ms predicted "
              f"({realized:.1%} realized); stalls {stall_ms:.1f} ms")

    if args.json is not None:
        metrics = {
            **lat, **ttft, **cold, **cold_sync,
            "tokens_per_s": rep.tokens_per_s,
            "goodput_tok_s": rep.goodput_tok_s,
            "slo_violation_rate": rep.slo_violation_rate,
            "completed": rep.completed,
            "offered": rep.offered,
            "steps": rep.steps,
            "cold_requests": len(rep.ttfts_cold_ms),
            "cold_ttft_p99_gain": (cold_sync["p99_ttft_cold_ms_sync"]
                                   / max(cold["p99_ttft_cold_ms"], 1e-9)),
            "prefetch_hit_rate": hit_rate,
            "prefetch_stall_ms": stall_ms,
            "async_builds": mt.async_builds,
            "async_adopted": mt.async_adopted,
            "prefix_hits": engine.pool.prefix_hits,
            "cow_copies": engine.pool.cow_copies,
            "plan_cache_plans": installed,
        }
        if realized is not None:
            metrics["overlap_realized_frac"] = realized
        if chaos_rep is not None:
            metrics["goodput_under_faults"] = goodput_under_faults
            metrics["shed_rate"] = chaos_rep.shed_rate
            metrics["degraded_rate"] = chaos_rep.degraded_rate
            metrics["chaos_completed"] = chaos_rep.completed
            metrics["chaos_failed"] = chaos_rep.failed
            metrics["chaos_shed"] = chaos_rep.shed
            metrics["chaos_degraded"] = chaos_rep.degraded
        res = _emit.result(
            "slo_load", cfg.name,
            metrics=metrics,
            meta={"smoke": args.smoke, "slots": args.slots,
                  "adapters": args.adapters, "hot": args.hot,
                  "slot_pad": args.slot_pad, "seed": args.seed,
                  "slo_ms": args.slo_ms, "rate": args.rate,
                  "overload": args.overload, "burst": args.burst,
                  "zipf": args.zipf, "duration": args.duration,
                  "num_pages": args.num_pages, "page_size": args.page_size,
                  "trace": args.trace,
                  "chaos_injected": (dict(chaos_inj.counts)
                                     if chaos_inj is not None else None),
                  "chaos_errors": (dict(chaos_rep.errors_by_type)
                                   if chaos_rep is not None else None)})
        print(f"wrote {_emit.emit(res, args.json or None)}")


if __name__ == "__main__":
    main()
