"""Machine-readable benchmark output + the CI regression gate.

Every benchmark in this directory can emit a ``BENCH_<name>.json`` file
(``--json [PATH]``) with one shared schema, so CI can archive throughput
trajectories and fail PRs that regress them:

  {
    "schema": 1,
    "bench": "multi_tenant",          # stable name, keys baseline.json
    "arch": "starcoder2-7b-smoke",
    "metrics": {"tokens_per_s_batched": 123.4, ...},   # numbers only
    "meta": {"smoke": true, ...}      # free-form run parameters
  }

The regression gate (``check_regression.py``) compares a run's metrics
against ``benchmarks/baseline.json``:

  {"multi_tenant": {"gate": {"tokens_per_s_batched": 40.0},
                    "gate_max": {"p99_ttft_ms": 900.0}}, ...}

``gate`` metrics are HIGHER-IS-BETTER: the gate trips when
``current < baseline * (1 - threshold)`` (threshold defaults to 25%).
``gate_max`` metrics are LOWER-IS-BETTER (latencies): the gate trips when
``current > baseline * (1 + threshold)``. Metrics present in a run but
absent from the baseline are informational only — so new metrics can ship
before a baseline exists for them.

Refreshing the baseline: run the bench with ``--smoke --json`` on a
CI-class machine, then copy the gated metrics into baseline.json at ~60%
of the measured value (CI runners are noisy shared VMs; the gate should
catch real regressions, not scheduler jitter).
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

SCHEMA_VERSION = 1

# bench name -> metrics that may be gated in baseline.json. check_regression
# refuses baselines that gate a metric its bench never emits (catches typos
# in baseline refreshes at unit-test time, not in a red CI run).
GATED_METRICS = {
    "multi_tenant": ("tokens_per_s_batched", "tokens_per_s_sequential",
                     "resident_requests_per_gb_batched"),
    "continuous_batching": ("tokens_per_s_continuous",
                            "tokens_per_s_fixed",
                            "tokens_per_s_paged",
                            "resident_requests_per_gb_continuous",
                            "resident_requests_per_gb_paged",
                            "residency_gain_paged"),
    "rapid_switching": ("switches_per_s",),
}

# lower-is-better counterparts (latencies), gateable via "gate_max".
GATED_MAX_METRICS = {
    "multi_tenant": ("p99_ttft_ms_batched",),
    "continuous_batching": ("p99_ttft_ms_continuous", "p99_ttft_ms_paged"),
}


def result(bench: str, arch: str, metrics: Dict[str, float],
           meta: Optional[dict] = None) -> dict:
    bad = [k for k, v in metrics.items()
           if not isinstance(v, (int, float)) or isinstance(v, bool)]
    if bad:
        raise TypeError(f"non-numeric metrics {bad} in bench {bench!r}")
    return {"schema": SCHEMA_VERSION, "bench": bench, "arch": arch,
            "metrics": {k: float(v) for k, v in metrics.items()},
            "meta": dict(meta or {})}


def default_path(bench: str) -> str:
    return f"BENCH_{bench}.json"


def emit(res: dict, path: Optional[str] = None) -> str:
    """Write one result dict as JSON; returns the path written."""
    path = path or default_path(res["bench"])
    with open(path, "w") as f:
        json.dump(res, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def compare(current: dict, baseline: dict,
            threshold: float = 0.25) -> List[str]:
    """Gate one bench result against the checked-in baseline.

    Returns a list of human-readable failure strings (empty = pass)."""
    bench = current.get("bench", "?")
    if current.get("schema") != SCHEMA_VERSION:
        return [f"{bench}: schema {current.get('schema')!r} != "
                f"{SCHEMA_VERSION} (refresh the bench or this gate)"]
    failures = []
    for key, known, lower_is_better in (
            ("gate", GATED_METRICS.get(bench), False),
            ("gate_max", GATED_MAX_METRICS.get(bench), True)):
        for metric, base in baseline.get(bench, {}).get(key, {}).items():
            if known is not None and metric not in known:
                failures.append(f"{bench}: baseline {key}s unknown metric "
                                f"{metric!r} (allowed: {list(known)})")
                continue
            cur = current.get("metrics", {}).get(metric)
            if cur is None:
                failures.append(f"{bench}: gated metric {metric!r} missing "
                                "from the run")
                continue
            if lower_is_better:
                ceil = base * (1.0 + threshold)
                if cur > ceil:
                    failures.append(
                        f"{bench}: {metric} regressed: {cur:.2f} > "
                        f"{ceil:.2f} (baseline {base:.2f}, threshold "
                        f"{threshold:.0%})")
            else:
                floor = base * (1.0 - threshold)
                if cur < floor:
                    failures.append(
                        f"{bench}: {metric} regressed: {cur:.2f} < "
                        f"{floor:.2f} (baseline {base:.2f}, threshold "
                        f"{threshold:.0%})")
    return failures
