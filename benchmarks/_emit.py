"""Machine-readable benchmark output + the CI regression gate.

Every benchmark in this directory can emit a ``BENCH_<name>.json`` file
(``--json [PATH]``) with one shared schema, so CI can archive throughput
trajectories and fail PRs that regress them:

  {
    "schema": 2,
    "bench": "multi_tenant",          # stable name, keys baseline.json
    "arch": "starcoder2-7b-smoke",
    "metrics": {"tokens_per_s_batched": 123.4, ...},   # numbers only
    "meta": {"smoke": true, ...}      # free-form run parameters
  }

The regression gate (``check_regression.py``) compares a run's metrics
against ``benchmarks/baseline.json``:

  {"multi_tenant": {"gate": {"tokens_per_s_batched": 40.0},
                    "gate_max": {"p99_ttft_ms": 900.0}}, ...}

``gate`` metrics are HIGHER-IS-BETTER: the gate trips when
``current < baseline * (1 - threshold)`` (threshold defaults to 25%).
``gate_max`` metrics are LOWER-IS-BETTER (latencies): the gate trips when
``current > baseline * (1 + threshold)``. Metrics present in a run but
absent from the baseline are informational only — so new metrics can ship
before a baseline exists for them.

Refreshing the baseline: run the bench with ``--smoke --json`` on a
CI-class machine, then copy the gated metrics into baseline.json at ~60%
of the measured value (CI runners are noisy shared VMs; the gate should
catch real regressions, not scheduler jitter).
"""
from __future__ import annotations

import json
import math
from typing import Dict, Iterable, List, Optional, Sequence

# v2 adds nothing the gate reads — it marks results whose percentile
# metrics come from the shared ``percentiles()`` helper below. The gate
# accepts every version in COMPAT_SCHEMAS so checked-in v1 artifacts and
# old baselines stay comparable.
SCHEMA_VERSION = 2
COMPAT_SCHEMAS = (1, 2)

# bench name -> metrics that may be gated in baseline.json. check_regression
# refuses baselines that gate a metric its bench never emits (catches typos
# in baseline refreshes at unit-test time, not in a red CI run).
GATED_METRICS = {
    "multi_tenant": ("tokens_per_s_batched", "tokens_per_s_sequential",
                     "resident_requests_per_gb_batched"),
    "continuous_batching": ("tokens_per_s_continuous",
                            "tokens_per_s_fixed",
                            "tokens_per_s_paged",
                            "resident_requests_per_gb_continuous",
                            "resident_requests_per_gb_paged",
                            "residency_gain_paged"),
    "rapid_switching": ("switches_per_s",),
    "slo_load": ("tokens_per_s", "goodput_tok_s", "completed",
                 "prefetch_hit_rate", "cold_ttft_p99_gain",
                 "overlap_realized_frac", "goodput_under_faults"),
    "train_efficiency": ("adapters_per_gb_f32", "adapters_per_gb_int8",
                         "moment_bytes_ratio", "concurrency_speedup"),
}

# lower-is-better counterparts (latencies), gateable via "gate_max".
GATED_MAX_METRICS = {
    "multi_tenant": ("p99_ttft_ms_batched",),
    "continuous_batching": ("p99_ttft_ms_continuous", "p99_ttft_ms_paged"),
    "slo_load": ("p50_latency_ms", "p99_latency_ms", "p99_ttft_ms",
                 "slo_violation_rate", "p99_ttft_cold_ms",
                 "prefetch_stall_ms", "shed_rate", "degraded_rate"),
    "train_efficiency": ("swap_latency_ms", "multi_step_ms_f32",
                         "multi_step_ms_int8"),
}


def percentile(samples: Sequence[float], p: float) -> float:
    """The p-th percentile (0..100) by linear interpolation between order
    statistics — numpy's default method, without requiring numpy, so every
    bench and the serving load generator quote identical tail math."""
    xs = sorted(float(x) for x in samples)
    if not xs:
        raise ValueError("percentile of empty sample set")
    if len(xs) == 1:
        return xs[0]
    rank = (p / 100.0) * (len(xs) - 1)
    lo = math.floor(rank)
    hi = min(lo + 1, len(xs) - 1)
    frac = rank - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


def percentiles(samples: Sequence[float], ps: Iterable[float] = (50, 95, 99),
                name: str = "latency_ms",
                suffix: str = "") -> Dict[str, float]:
    """Metric-dict fragment ``{"p50_<name><suffix>": ...}`` for a sample
    set — e.g. ``percentiles(ttfts, (99,), "ttft_ms", "_paged")``."""
    return {f"p{g}_{name}{suffix}": percentile(samples, p)
            for p in ps for g in [int(p) if float(p).is_integer() else p]}


def result(bench: str, arch: str, metrics: Dict[str, float],
           meta: Optional[dict] = None) -> dict:
    bad = [k for k, v in metrics.items()
           if not isinstance(v, (int, float)) or isinstance(v, bool)]
    if bad:
        raise TypeError(f"non-numeric metrics {bad} in bench {bench!r}")
    return {"schema": SCHEMA_VERSION, "bench": bench, "arch": arch,
            "metrics": {k: float(v) for k, v in metrics.items()},
            "meta": dict(meta or {})}


def default_path(bench: str) -> str:
    return f"BENCH_{bench}.json"


def emit(res: dict, path: Optional[str] = None) -> str:
    """Write one result dict as JSON; returns the path written."""
    path = path or default_path(res["bench"])
    with open(path, "w") as f:
        json.dump(res, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def compare(current: dict, baseline: dict,
            threshold: float = 0.25) -> List[str]:
    """Gate one bench result against the checked-in baseline.

    Returns a list of human-readable failure strings (empty = pass)."""
    bench = current.get("bench", "?")
    if current.get("schema") not in COMPAT_SCHEMAS:
        return [f"{bench}: schema {current.get('schema')!r} not in "
                f"{COMPAT_SCHEMAS} (refresh the bench or this gate)"]
    failures = []
    for key, known, lower_is_better in (
            ("gate", GATED_METRICS.get(bench), False),
            ("gate_max", GATED_MAX_METRICS.get(bench), True)):
        for metric, base in baseline.get(bench, {}).get(key, {}).items():
            if known is not None and metric not in known:
                failures.append(f"{bench}: baseline {key}s unknown metric "
                                f"{metric!r} (allowed: {list(known)})")
                continue
            cur = current.get("metrics", {}).get(metric)
            if cur is None:
                failures.append(f"{bench}: gated metric {metric!r} missing "
                                "from the run")
                continue
            if lower_is_better:
                ceil = base * (1.0 + threshold)
                if cur > ceil:
                    failures.append(
                        f"{bench}: {metric} regressed: {cur:.2f} > "
                        f"{ceil:.2f} (baseline {base:.2f}, threshold "
                        f"{threshold:.0%})")
            else:
                floor = base * (1.0 - threshold)
                if cur < floor:
                    failures.append(
                        f"{bench}: {metric} regressed: {cur:.2f} < "
                        f"{floor:.2f} (baseline {base:.2f}, threshold "
                        f"{threshold:.0%})")
    return failures
