"""CI regression gate: compare BENCH_*.json runs against baseline.json.

  python benchmarks/check_regression.py BENCH_multi_tenant.json \
      [BENCH_continuous_batching.json ...] \
      --baseline benchmarks/baseline.json [--threshold 0.25]

Exit code 1 (with a per-metric report) when any gated metric falls more
than ``threshold`` below its baseline, when a run omits a gated metric,
or when a baseline bench with gates gets no run file at all — a deleted
or renamed BENCH artifact must trip the gate, not silently pass (use
``--allow-missing bench`` for a lane that is intentionally absent).
See ``_emit.py`` for the schema and the baseline-refresh procedure.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import _emit  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("runs", nargs="+", help="BENCH_*.json files to check")
    ap.add_argument("--baseline", default=os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "baseline.json"))
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="allowed fractional drop below baseline")
    ap.add_argument("--allow-missing", action="append", default=[],
                    metavar="BENCH",
                    help="baseline bench allowed to have no run file")
    args = ap.parse_args()

    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = []
    seen = set()
    for path in args.runs:
        with open(path) as f:
            current = json.load(f)
        bench = current.get("bench", path)
        seen.add(bench)
        fails = _emit.compare(current, baseline, threshold=args.threshold)
        gates = baseline.get(bench, {}).get("gate", {})
        for metric, base in sorted(gates.items()):
            cur = current.get("metrics", {}).get(metric)
            status = "FAIL" if any(metric in f for f in fails) else "ok"
            shown = "missing" if cur is None else f"{cur:.2f}"
            print(f"[{status:>4}] {bench}.{metric}: {shown} "
                  f"(baseline {base:.2f}, floor "
                  f"{base * (1 - args.threshold):.2f})")
        for metric, base in sorted(
                baseline.get(bench, {}).get("gate_max", {}).items()):
            cur = current.get("metrics", {}).get(metric)
            status = "FAIL" if any(metric in f for f in fails) else "ok"
            shown = "missing" if cur is None else f"{cur:.2f}"
            print(f"[{status:>4}] {bench}.{metric}: {shown} "
                  f"(baseline {base:.2f}, ceiling "
                  f"{base * (1 + args.threshold):.2f})")
        failures.extend(fails)
    # a baseline bench with gates and no run file at all must trip too:
    # otherwise deleting a BENCH artifact (or renaming a bench) silently
    # un-gates every metric under it
    for bench, entry in sorted(baseline.items()):
        if (not isinstance(entry, dict) or bench in seen
                or bench in args.allow_missing):
            continue
        gated = list(entry.get("gate", {})) + list(entry.get("gate_max", {}))
        if gated:
            print(f"[FAIL] {bench}: no run file provided "
                  f"({len(gated)} gated metrics uncovered)")
            failures.append(f"{bench}: baseline gates "
                            f"{sorted(gated)} but no run file was provided")
    if failures:
        print("\nREGRESSION GATE TRIPPED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nregression gate: all metrics within threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
