"""Paper Table 4 analog: multi-adapter fusion interference, SHiRA vs LoRA.

Train one adapter per synthetic task (independently), then naively fuse and
measure each task's loss before/after fusion. Reports the paper's %Drop
metric plus the §3.2 interference diagnostics (index overlap, ||A1'A2||
density) that explain WHY sparse adapters fuse better.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.configs import AdapterConfig, RunConfig, TrainConfig, get_smoke_config
from repro.configs.base import ShapeSpec
from repro.data import TaskSpec, batch_iterator, make_batch
from repro.models import lm
from repro.runtime import Trainer
from repro.runtime.trainer import TrainerConfig

SHAPE = ShapeSpec("bench", 64, 8, "train")
ARCH = "starcoder2-7b"
STEPS = 60
TASKS = (1, 2, 3)


def eval_loss(cfg, params, task) -> float:
    batch = {k: jnp.asarray(v) for k, v in
             make_batch(cfg, SHAPE, seed=77, step=123,
                        task=TaskSpec(task_id=task)).items()}
    return float(lm.train_loss(params, cfg, batch)[0])


def train_all(acfg: AdapterConfig):
    cfg = get_smoke_config(ARCH)
    run = RunConfig(model=cfg, shape=SHAPE, adapter=acfg,
                    train=TrainConfig(learning_rate=2e-2, total_steps=STEPS,
                                      warmup_steps=3))
    trained = {}
    base = None
    for t in TASKS:
        tr = Trainer(run, TrainerConfig())
        out = tr.fit(STEPS, batches=batch_iterator(
            cfg, SHAPE, seed=0, task=TaskSpec(task_id=t)), log=None)
        trained[t] = (tr, out["state"]["trainable"])
        base = tr.base
    return cfg, base, trained


def fused_params_shira(cfg, base, trained):
    # packs round-trip through an on-disk AdapterStore (format v2) — the
    # fuse path consumes adapter IDS, like production serving would
    import tempfile

    from repro.hub import AdapterStore
    store = AdapterStore(tempfile.mkdtemp(prefix="ma-bench-store-"))
    names = [store.add(core.pack_from_shira(f"t{t}", v, tr.aux))
             for t, (tr, v) in trained.items()]
    eng = core.SwitchEngine(base, store=store)
    eng.load_fused(names)
    return eng.params, [store.get(n) for n in names]


def fused_params_lora(cfg, base, trained, acfg):
    params = base
    for t, (tr, v) in trained.items():
        params = core.materialize(params, v, tr.aux, acfg)
    return params


def report(label, cfg, base, trained, fused):
    single = {t: eval_loss(cfg, core.materialize(
        trained[t][0].base, trained[t][1], trained[t][0].aux,
        trained[t][0].acfg), t) for t in TASKS}
    multi = {t: eval_loss(cfg, fused, t) for t in TASKS}
    s_avg = np.mean(list(single.values()))
    m_avg = np.mean(list(multi.values()))
    drop = 100 * (m_avg - s_avg) / max(abs(s_avg), 1e-9)
    for t in TASKS:
        print(f"{label},task{t},{single[t]:.4f},{multi[t]:.4f}")
    print(f"{label},avg,{s_avg:.4f},{m_avg:.4f},drop_pct={drop:.1f}")
    return s_avg, m_avg


def main() -> None:
    print("method,task,single_adapter_loss,multi_adapter_loss")

    acfg_s = AdapterConfig(kind="shira", mask="wm", sparsity=0.95)
    cfg, base, trained_s = train_all(acfg_s)
    fused_s, packs = fused_params_shira(cfg, base, trained_s)
    report("shira-wm", cfg, base, trained_s, fused_s)

    # interference diagnostics (§3.2)
    ov = core.index_overlap(packs[0], packs[1])
    print(f"shira-wm,index_overlap_mean,{np.mean(list(ov.values())):.4f}")

    acfg_l = AdapterConfig(kind="lora", rank=8)
    cfg, base, trained_l = train_all(acfg_l)
    fused_l = fused_params_lora(cfg, base, trained_l, acfg_l)
    report("lora", cfg, base, trained_l, fused_l)

    # gram interference on one target matrix: SHiRA deltas vs LoRA deltas
    path = sorted(packs[0].entries)[0]
    w_shape = None
    for p, leaf in jax.tree_util.tree_flatten_with_path(base)[0]:
        if core.masks.path_str(p) == path:
            w_shape = leaf.shape
    d1 = core.fusion.pack_to_dense(packs[0], path, w_shape)[0]
    d2 = core.fusion.pack_to_dense(packs[1], path, w_shape)[0]
    nz_s, rel_s = core.fusion.gram_interference(d1, d2)
    print(f"shira-wm,gram_nonzero_frac,{nz_s:.4f},rel={rel_s:.4f}")


if __name__ == "__main__":
    main()
