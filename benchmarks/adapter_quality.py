"""Paper Tables 1-3 analog: adapter quality, LoRA vs the 5 SHiRA masks
(+ DoRA, SHiRA-DoRA).

The container has no LLaMA/SD checkpoints or benchmark datasets, so the
*mechanism* is measured on a learnable synthetic task: each method finetunes
the same frozen base model; we report final loss (lower = better), trainable
params %, and %C (fraction of base weights changed in fused/deployed form —
the paper's rapid-switching figure of merit).

Also runs the alpha-sweep of App. G (--alpha-sweep).
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.configs import AdapterConfig, RunConfig, TrainConfig, get_smoke_config
from repro.configs.base import ShapeSpec
from repro.data import TaskSpec, batch_iterator, make_batch
from repro.models import lm
from repro.runtime import Trainer
from repro.runtime.trainer import TrainerConfig

SHAPE = ShapeSpec("bench", 64, 8, "train")
ARCH = "starcoder2-7b"
STEPS = 50
TASK = TaskSpec(task_id=5)

METHODS = [
    ("lora", AdapterConfig(kind="lora", rank=8)),
    ("dora", AdapterConfig(kind="dora", rank=8)),
    ("shira-struct", AdapterConfig(kind="shira", mask="struct", sparsity=0.98)),
    ("shira-rand", AdapterConfig(kind="shira", mask="rand", sparsity=0.98)),
    ("shira-wm", AdapterConfig(kind="shira", mask="wm", sparsity=0.98)),
    ("shira-grad", AdapterConfig(kind="shira", mask="grad", sparsity=0.98)),
    ("shira-snip", AdapterConfig(kind="shira", mask="snip", sparsity=0.98)),
    ("shira-dora", AdapterConfig(kind="shira-dora", mask="wm",
                                 sparsity=0.98, rank=8)),
]


def calib_grads(cfg, params):
    batch = {k: jnp.asarray(v) for k, v in
             make_batch(cfg, SHAPE, seed=1, step=0, task=TASK).items()}
    return jax.grad(lambda p: lm.train_loss(p, cfg, batch)[0])(params)


def eval_loss(cfg, params) -> float:
    batch = {k: jnp.asarray(v) for k, v in
             make_batch(cfg, SHAPE, seed=77, step=123, task=TASK).items()}
    return float(lm.train_loss(params, cfg, batch)[0])


def run_method(name: str, acfg: AdapterConfig):
    cfg = get_smoke_config(ARCH)
    run = RunConfig(model=cfg, shape=SHAPE, adapter=acfg,
                    train=TrainConfig(learning_rate=1e-2, total_steps=STEPS,
                                      warmup_steps=3))
    base = lm.init_params(cfg, jax.random.PRNGKey(0))
    cg = (calib_grads(cfg, base) if acfg.kind == "shira"
          and acfg.mask in ("grad", "snip") else None)
    tr = Trainer(run, TrainerConfig(), calib_grads=cg)
    out = tr.fit(STEPS, batches=batch_iterator(cfg, SHAPE, seed=0, task=TASK),
                 log=None)
    eff = core.materialize(tr.base, out["state"]["trainable"], tr.aux,
                           acfg) if acfg.kind != "none" else \
        out["state"]["trainable"]
    final = eval_loss(cfg, eff)
    n_train = sum(x.size for x in jax.tree.leaves(out["state"]["trainable"]))
    n_base = sum(x.size for x in jax.tree.leaves(tr.base))
    pct_c = core.switching.changed_fraction(tr.base, eff)
    return final, 100 * n_train / n_base, 100 * pct_c


def alpha_sweep():
    cfg = get_smoke_config(ARCH)
    acfg = AdapterConfig(kind="shira", mask="wm", sparsity=0.98)
    run = RunConfig(model=cfg, shape=SHAPE, adapter=acfg,
                    train=TrainConfig(learning_rate=1e-2, total_steps=STEPS,
                                      warmup_steps=3))
    tr = Trainer(run, TrainerConfig())
    out = tr.fit(STEPS, batches=batch_iterator(cfg, SHAPE, seed=0, task=TASK),
                 log=None)
    print("alpha,task_loss")
    for alpha in (0.0, 0.5, 1.0, 1.5, 2.0):
        eff = core.materialize(tr.base, out["state"]["trainable"], tr.aux,
                               acfg, alpha=alpha)
        print(f"{alpha},{eval_loss(cfg, eff):.4f}")


def main() -> None:
    if "--alpha-sweep" in sys.argv:
        alpha_sweep()
        return
    print("method,final_loss,trainable_pct,changed_pct")
    base_loss = eval_loss(get_smoke_config(ARCH),
                          lm.init_params(get_smoke_config(ARCH),
                                         jax.random.PRNGKey(0)))
    print(f"base,{base_loss:.4f},0.00,0.00")
    for name, acfg in METHODS:
        loss, pct_t, pct_c = run_method(name, acfg)
        print(f"{name},{loss:.4f},{pct_t:.2f},{pct_c:.2f}")


if __name__ == "__main__":
    main()
