"""Multi-tenant serving: per-request batched adapters vs sequential switching.

A mixed-tenant request stream (B requests, each naming one of A adapters or
the base model) served two ways:

  * sequential — today's switch-per-batch loop: partition the batch by
    adapter, SwitchEngine-switch to each adapter in turn, run a separate
    (smaller) batched forward per group. Tenants never share a decode step.
  * batched    — MultiTenantEngine: ONE forward over the whole batch; each
    request's SHiRA pack applied as a Pallas side-delta, routed by ids.

Reports throughput/latency for both and checks the batched outputs match
the sequential ones (greedy tokens AND fp32 logits within 1e-3). With
``--int8`` the engine keeps its device-side delta tables quantized
(values int8 + per-adapter scale, indices int16 where they fit) and the
parity bar is 1e-2 — the dequant happens inside the kernel.

``--capacity-sweep A1,A2,...`` additionally serves the same batch at
growing adapter registries, reporting throughput and resident
adapter-table bytes per point (how the engine scales with tenant count).

``--json [PATH]`` writes the machine-readable result (schema in
``_emit.py``) that CI's tier3-bench gate tracks.

  PYTHONPATH=src python benchmarks/multi_tenant.py --smoke --json
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import _emit
from repro import core
from repro.configs import get_smoke_config, get_config
from repro.launch.serve import make_adapters
from repro.models import layers, lm
from repro.serving import MultiTenantEngine
from repro.serving.multitenant import greedy_decode, serving_cache_size


def serve_sequential(cfg, params, packs, toks, names, tokens: int):
    """Switch-per-batch baseline: group requests by adapter, switch, serve."""
    B, S = toks.shape
    cs = serving_cache_size(cfg, S, tokens)
    engine = core.SwitchEngine(params)
    prefill = jax.jit(lambda p, b: lm.prefill(p, cfg, b, cs))
    decode = jax.jit(lambda p, t, c, pos: lm.decode_step(p, cfg, t, c, pos))
    by_name = {p.name: p for p in packs}
    groups = {}
    for b, name in enumerate(names):
        groups.setdefault(name, []).append(b)

    out = np.zeros((B, tokens), np.int32)
    logits_last = [None] * B
    t0 = time.perf_counter()
    for name, idxs in groups.items():
        while engine.active:
            engine.unload()
        if name is not None:
            engine.load(by_name[name])
        sub = toks[np.asarray(idxs)]
        seq, logits = greedy_decode(
            cfg, {"tokens": sub}, tokens,
            lambda b: prefill(engine.params, b),
            lambda t, c, pos: decode(engine.params, t, c, pos))
        seq = np.asarray(seq)
        lg = np.asarray(logits, np.float32)
        for j, b in enumerate(idxs):
            out[b] = seq[j]
            logits_last[b] = lg[j]
    dt = time.perf_counter() - t0
    while engine.active:
        engine.unload()
    return out, np.stack(logits_last), dt


def serve_batched(cfg, engine, toks, names, tokens: int):
    B, S = toks.shape
    cs = serving_cache_size(cfg, S, tokens)
    ids = engine.ids_for(names)
    p = engine.wrapped_params(ids)
    t0 = time.perf_counter()
    out, logits = greedy_decode(
        cfg, {"tokens": toks}, tokens,
        lambda b: engine._prefill(p, b, cs),
        lambda t, c, pos: engine._decode(p, t, c, pos))
    dt = time.perf_counter() - t0
    return np.asarray(out), np.asarray(logits, np.float32), dt


def measure_switch_latency(params, pack, reps: int = 3) -> float:
    """Seconds for one SwitchEngine adapter switch (load or unload — each
    is one sparse scatter pass), best of ``reps`` load+unload cycles."""
    engine = core.SwitchEngine(params)
    engine.load(pack)       # compile the scatter path
    engine.unload()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        engine.load(pack)
        engine.unload()
        jax.block_until_ready(jax.tree.leaves(engine.params)[0])
        best = min(best, (time.perf_counter() - t0) / 2)
    return best


def capacity_sweep(cfg, params, toks, names_template, tokens, counts,
                   table_dtype):
    """Throughput + resident table bytes as the adapter registry grows."""
    points = []
    for A in counts:
        packs = make_adapters(cfg, params, A, jax.random.PRNGKey(11),
                              multi_tenant=True)
        engine = MultiTenantEngine(cfg, params, table_dtype=table_dtype)
        for p in packs:
            engine.register(p)
        pool = [p.name for p in packs]
        names = [pool[i % A] for i in range(len(names_template))]
        _, _, dt = serve_batched(cfg, engine, toks, names, tokens)
        _, _, dt2 = serve_batched(cfg, engine, toks, names, tokens)
        dt = min(dt, dt2)
        n_tok = toks.shape[0] * tokens
        points.append({"adapters": A, "tokens_per_s": n_tok / dt,
                       "table_bytes": engine.table_nbytes()["total"]})
        print(f"  capacity A={A:4d}: {n_tok/dt:8.1f} tok/s  "
              f"{points[-1]['table_bytes']:10d} table bytes")
    return points


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--adapters", type=int, default=3)
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--int8", action="store_true",
                    help="int8 device-side delta tables (dequant in-kernel)")
    ap.add_argument("--capacity-sweep", default=None, metavar="A1,A2,...",
                    help="also sweep adapter-registry sizes (batched path)")
    ap.add_argument("--json", nargs="?", const="", default=None,
                    metavar="PATH", help="write BENCH_multi_tenant.json "
                    "(or PATH) with the _emit schema")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.adapters < 3 or args.batch < args.adapters:
        raise SystemExit("need --adapters >= 3 and --batch >= --adapters "
                         "(the parity check wants >=3 distinct adapters "
                         "in one batch)")
    table_dtype = "int8" if args.int8 else "f32"

    # fp32 compute: the two paths evaluate the adapter delta in different
    # orders, and the parity check below needs a meaningful tolerance.
    with layers.compute_precision(jnp.float32):
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        packs = make_adapters(cfg, params, args.adapters,
                              jax.random.PRNGKey(7), multi_tenant=True)
        # all packs go through the on-disk store (format v2; f32 round
        # trips bit-exactly, int8 serves the store's quantized tables
        # directly so both paths see the same adapter values)
        import tempfile

        from repro.hub import AdapterStore
        store = AdapterStore(tempfile.mkdtemp(prefix="mt-bench-store-"))
        for p in packs:
            store.add(p, values=table_dtype if args.int8 else "f32")
            store.evict(p.name)      # registration below starts disk-cold
        engine = MultiTenantEngine(cfg, params, store=store,
                                   table_dtype=table_dtype)
        # registration rides the store's prefetch pool: every pack's disk
        # read runs concurrently, register() only joins the handles
        t0 = time.perf_counter()
        handles = [store.prefetch(p.name, dequantize=not args.int8)
                   for p in packs]
        for h in handles:
            engine.register(h.result())
        prefetch_register_ms = (time.perf_counter() - t0) * 1e3
        if args.int8:
            # the sequential baseline must serve the SAME (quantized)
            # adapter values for the parity bars to mean anything
            packs = [store.get(p.name) for p in packs]

        rng = np.random.default_rng(0)
        B = args.batch
        # every adapter appears at least once; remainder mixed (incl. base)
        names = [p.name for p in packs]
        pool = names + [None]
        names = names + [pool[rng.integers(len(pool))]
                         for _ in range(B - len(names))]
        toks = jax.random.randint(jax.random.PRNGKey(1),
                                  (B, args.prompt_len), 0, cfg.vocab_size)

        t_seq = t_bat = None
        for _ in range(args.reps):  # first rep compiles; keep the best
            out_s, lg_s, dt_s = serve_sequential(cfg, params, packs,
                                                 np.asarray(toks), names,
                                                 args.tokens)
            out_b, lg_b, dt_b = serve_batched(cfg, engine, toks, names,
                                              args.tokens)
            t_seq = dt_s if t_seq is None else min(t_seq, dt_s)
            t_bat = dt_b if t_bat is None else min(t_bat, dt_b)
        switch_s = measure_switch_latency(params, packs[0])
        table_bytes = engine.table_nbytes()

        # memory residency: the fixed-batch path provisions B x cache_size
        # rows up front regardless of realized request lengths
        cs = serving_cache_size(cfg, args.prompt_len, args.tokens)
        kv_bytes = sum(int(x.nbytes)
                       for x in jax.tree.leaves(lm.init_cache(cfg, B, cs)))
        res_per_gb = B / (kv_bytes / 1e9)
        # TTFT: a fixed batch admits everyone at once, so every request's
        # first token lands after the whole-batch prefill — p99 == the
        # (warm) prefill wall time
        ids = engine.ids_for(names)
        wp = engine.wrapped_params(ids)
        t0 = time.perf_counter()
        lg, _ = engine._prefill(wp, {"tokens": toks}, cs)
        jax.block_until_ready(lg)
        ttft_ms = (time.perf_counter() - t0) * 1e3

        # cold-miss admission cost: disk load + table rebuild for an
        # adapter first seen after serving started (what the async
        # serving engines hide under in-flight decode — see slo_load.py
        # for the overlapped measurement)
        extra = make_adapters(cfg, params, 1, jax.random.PRNGKey(99),
                              multi_tenant=True)[0]
        extra = type(extra)("cold_extra", extra.entries, extra.alpha)
        store.add(extra, values=table_dtype if args.int8 else "f32")
        store.evict(extra.name)
        t0 = time.perf_counter()
        engine.register(extra.name)
        engine._ensure_tables()
        cold_admit_ms = (time.perf_counter() - t0) * 1e3

        sweep = None
        if args.capacity_sweep:
            counts = [int(a) for a in args.capacity_sweep.split(",")]
            print("capacity sweep (batched path):")
            sweep = capacity_sweep(cfg, params, toks, names, args.tokens,
                                   counts, table_dtype)

    err = float(np.max(np.abs(lg_s - lg_b)))
    tok_match = bool(np.array_equal(out_s, out_b))
    n_tok = B * args.tokens
    n_switch = len({n for n in names if n is not None})
    print(f"arch={cfg.name} B={B} adapters={args.adapters} "
          f"tokens={args.tokens} distinct_in_batch={n_switch} "
          f"tables={table_dtype}")
    print(f"sequential-switch: {t_seq*1e3:8.1f}ms  {n_tok/t_seq:8.1f} tok/s "
          f"({n_switch} switches/batch)")
    print(f"per-request batch: {t_bat*1e3:8.1f}ms  {n_tok/t_bat:8.1f} tok/s "
          f"(0 switches)")
    print(f"switch latency: {switch_s*1e3:.2f}ms   adapter tables: "
          f"{table_bytes['total']} bytes ({table_bytes['vals']} vals)")
    hit_rate = store.prefetch_hits / max(store.prefetch_hits
                                         + store.prefetch_misses, 1)
    print(f"store: {args.adapters} adapters prefetch-registered in "
          f"{prefetch_register_ms:.1f}ms ({store.loads} disk loads, "
          f"hit rate {hit_rate:.1%})   cold admit: {cold_admit_ms:.1f}ms "
          "(disk load + table rebuild)")
    print(f"residency: {res_per_gb:.1f} req/GB ({B} x {cs}-row stripes, "
          f"{kv_bytes} KV bytes)   p99 TTFT: {ttft_ms:.1f}ms")
    print(f"speedup: {t_seq/t_bat:.2f}x   max|logit diff|={err:.2e}   "
          f"greedy tokens equal: {tok_match}")
    tol = 1e-2 if args.int8 else 1e-3
    assert err < tol, f"batched vs sequential logits diverged: {err}"
    assert tok_match, "greedy tokens diverged"
    print(f"PARITY OK (<{tol:g})")

    if args.json is not None:
        metrics = {
            "tokens_per_s_batched": n_tok / t_bat,
            "tokens_per_s_sequential": n_tok / t_seq,
            "speedup": t_seq / t_bat,
            "switch_latency_ms": switch_s * 1e3,
            "adapter_table_bytes": table_bytes["total"],
            "adapter_table_vals_bytes": table_bytes["vals"],
            "max_logit_diff": err,
            "resident_requests_per_gb_batched": res_per_gb,
            "p99_ttft_ms_batched": ttft_ms,
            "prefetch_register_ms": prefetch_register_ms,
            "prefetch_hit_rate": hit_rate,
            "cold_admit_ms": cold_admit_ms,
        }
        # capacity-sweep points land in metrics (one lane per registry
        # size) so the BENCH artifact archives the scaling curve, not
        # just free-form meta
        for pt in sweep or []:
            A = pt["adapters"]
            metrics[f"capacity_tokens_per_s_a{A}"] = pt["tokens_per_s"]
            metrics[f"capacity_table_bytes_a{A}"] = pt["table_bytes"]
        res = _emit.result(
            "multi_tenant", cfg.name, metrics=metrics,
            meta={"smoke": args.smoke, "batch": B, "tokens": args.tokens,
                  "adapters": args.adapters, "table_dtype": table_dtype,
                  "capacity_sweep": sweep})
        print(f"wrote {_emit.emit(res, args.json or None)}")


if __name__ == "__main__":
    main()
