"""Multi-tenant serving: per-request batched adapters vs sequential switching.

A mixed-tenant request stream (B requests, each naming one of A adapters or
the base model) served two ways:

  * sequential — today's switch-per-batch loop: partition the batch by
    adapter, SwitchEngine-switch to each adapter in turn, run a separate
    (smaller) batched forward per group. Tenants never share a decode step.
  * batched    — MultiTenantEngine: ONE forward over the whole batch; each
    request's SHiRA pack applied as a Pallas side-delta, routed by ids.

Reports throughput/latency for both and checks the batched outputs match
the sequential ones (greedy tokens AND fp32 logits within 1e-3).

  PYTHONPATH=src python benchmarks/multi_tenant.py --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.configs import get_smoke_config, get_config
from repro.launch.serve import make_adapters
from repro.models import layers, lm
from repro.serving import MultiTenantEngine
from repro.serving.multitenant import greedy_decode, serving_cache_size


def serve_sequential(cfg, params, packs, toks, names, tokens: int):
    """Switch-per-batch baseline: group requests by adapter, switch, serve."""
    B, S = toks.shape
    cs = serving_cache_size(cfg, S, tokens)
    engine = core.SwitchEngine(params)
    prefill = jax.jit(lambda p, b: lm.prefill(p, cfg, b, cs))
    decode = jax.jit(lambda p, t, c, pos: lm.decode_step(p, cfg, t, c, pos))
    by_name = {p.name: p for p in packs}
    groups = {}
    for b, name in enumerate(names):
        groups.setdefault(name, []).append(b)

    out = np.zeros((B, tokens), np.int32)
    logits_last = [None] * B
    t0 = time.perf_counter()
    for name, idxs in groups.items():
        while engine.active:
            engine.unload()
        if name is not None:
            engine.load(by_name[name])
        sub = toks[np.asarray(idxs)]
        seq, logits = greedy_decode(
            cfg, {"tokens": sub}, tokens,
            lambda b: prefill(engine.params, b),
            lambda t, c, pos: decode(engine.params, t, c, pos))
        seq = np.asarray(seq)
        lg = np.asarray(logits, np.float32)
        for j, b in enumerate(idxs):
            out[b] = seq[j]
            logits_last[b] = lg[j]
    dt = time.perf_counter() - t0
    while engine.active:
        engine.unload()
    return out, np.stack(logits_last), dt


def serve_batched(cfg, engine, toks, names, tokens: int):
    B, S = toks.shape
    cs = serving_cache_size(cfg, S, tokens)
    ids = engine.ids_for(names)
    p = engine.wrapped_params(ids)
    t0 = time.perf_counter()
    out, logits = greedy_decode(
        cfg, {"tokens": toks}, tokens,
        lambda b: engine._prefill(p, b, cs),
        lambda t, c, pos: engine._decode(p, t, c, pos))
    dt = time.perf_counter() - t0
    return np.asarray(out), np.asarray(logits, np.float32), dt


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--adapters", type=int, default=3)
    ap.add_argument("--reps", type=int, default=2)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.adapters < 3 or args.batch < args.adapters:
        raise SystemExit("need --adapters >= 3 and --batch >= --adapters "
                         "(the parity check wants >=3 distinct adapters "
                         "in one batch)")

    # fp32 compute: the two paths evaluate the adapter delta in different
    # orders, and the parity check below needs a meaningful tolerance.
    with layers.compute_precision(jnp.float32):
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        packs = make_adapters(cfg, params, args.adapters,
                              jax.random.PRNGKey(7), multi_tenant=True)
        # all packs go through the on-disk store (format v2, f32 round trips
        # bit-exactly so the parity bars are unaffected)
        import tempfile

        from repro.hub import AdapterStore
        store = AdapterStore(tempfile.mkdtemp(prefix="mt-bench-store-"))
        for p in packs:
            store.add(p)
        engine = MultiTenantEngine(cfg, params, store=store)
        for p in packs:
            engine.register(p.name)

        rng = np.random.default_rng(0)
        B = args.batch
        # every adapter appears at least once; remainder mixed (incl. base)
        names = [p.name for p in packs]
        pool = names + [None]
        names = names + [pool[rng.integers(len(pool))]
                         for _ in range(B - len(names))]
        toks = jax.random.randint(jax.random.PRNGKey(1),
                                  (B, args.prompt_len), 0, cfg.vocab_size)

        t_seq = t_bat = None
        for _ in range(args.reps):  # first rep compiles; keep the best
            out_s, lg_s, dt_s = serve_sequential(cfg, params, packs,
                                                 np.asarray(toks), names,
                                                 args.tokens)
            out_b, lg_b, dt_b = serve_batched(cfg, engine, toks, names,
                                              args.tokens)
            t_seq = dt_s if t_seq is None else min(t_seq, dt_s)
            t_bat = dt_b if t_bat is None else min(t_bat, dt_b)

    err = float(np.max(np.abs(lg_s - lg_b)))
    tok_match = bool(np.array_equal(out_s, out_b))
    n_tok = B * args.tokens
    n_switch = len({n for n in names if n is not None})
    print(f"arch={cfg.name} B={B} adapters={args.adapters} "
          f"tokens={args.tokens} distinct_in_batch={n_switch}")
    print(f"sequential-switch: {t_seq*1e3:8.1f}ms  {n_tok/t_seq:8.1f} tok/s "
          f"({n_switch} switches/batch)")
    print(f"per-request batch: {t_bat*1e3:8.1f}ms  {n_tok/t_bat:8.1f} tok/s "
          f"(0 switches)")
    print(f"speedup: {t_seq/t_bat:.2f}x   max|logit diff|={err:.2e}   "
          f"greedy tokens equal: {tok_match}")
    assert err < 1e-3, f"batched vs sequential logits diverged: {err}"
    assert tok_match, "greedy tokens diverged"
    print("PARITY OK (<1e-3)")


if __name__ == "__main__":
    main()
