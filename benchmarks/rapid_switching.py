"""Paper Fig. 5 / App. B analog: SHiRA scatter-load vs LoRA fuse latency.

For weight dims 1024..4096 (paper uses up to 8192; CPU wall-clock here),
measures:
  * SHiRA switch: scatter-add of 1% packed updates (jnp path + Pallas
    scatter_apply in interpret mode for the kernel-shape check),
  * LoRA fuse: W + A@B at rank 64 (the paper's LVM rank),
  * pack I/O: loading the adapter from a format-v2 file via the
    ``repro.hub`` store, f32 and int8 (the cold-start path: an evicted
    tenant's first request pays load + scatter),
and derives the TPU-side byte model: adapter bytes moved vs full-weight
rewrite + GEMM FLOPs (reported as model terms since this container has no
TPU clock).
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

import _emit
from repro.core import masks as M
from repro.core.adapters import AdapterPack
from repro.hub import load_pack, save_pack

RANK = 64
SPARSITY = 0.99


def timed(fn, *args, reps=5):
    fn(*args).block_until_ready()  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", nargs="?", const="", default=None,
                    metavar="PATH", help="write BENCH_rapid_switching.json "
                    "(or PATH) with the _emit schema")
    args = ap.parse_args()
    rows = []
    print("dim,shira_scatter_ms,lora_fuse_ms,speedup,"
          "shira_bytes_mb,lora_bytes_mb,lora_gemm_gflop,"
          "pack_load_f32_ms,pack_load_int8_ms,int8_shrink")
    rng = np.random.RandomState(0)
    tmp = tempfile.mkdtemp(prefix="rs-bench-")
    for dim in (1024, 2048, 4096):
        w = jnp.asarray(rng.randn(dim, dim), jnp.float32)
        k = int((1 - SPARSITY) * dim * dim)
        idx = jnp.asarray(np.sort(rng.choice(dim * dim, k, replace=False)),
                          jnp.int32)
        vals = jnp.asarray(rng.randn(k), jnp.float32)
        a = jnp.asarray(rng.randn(dim, RANK), jnp.float32)
        b = jnp.asarray(rng.randn(RANK, dim), jnp.float32)

        scatter = jax.jit(lambda w, i, v: M.scatter_packed_add(
            w[None], i[None], v[None])[0])
        fuse = jax.jit(lambda w, a, b: w + a @ b)

        t_s = timed(scatter, w, idx, vals) * 1e3
        t_f = timed(fuse, w, a, b) * 1e3

        shira_mb = k * 8 / 1e6                      # idx + val
        lora_mb = (2 * dim * RANK + dim * dim) / 1e6 * 4  # A,B in + W rewrite
        gemm_gflop = 2 * RANK * dim * dim / 1e9

        # cold-start pack I/O: format-v2 file -> usable AdapterPack
        pack = AdapterPack(f"d{dim}", {"w": (idx, vals)})
        t_io = {}
        for mode in ("f32", "int8"):
            f = save_pack(pack, os.path.join(tmp, f"d{dim}_{mode}.shpk"),
                          values=mode)
            t0 = time.perf_counter()
            loaded = load_pack(f)
            t_io[mode] = (time.perf_counter() - t0) * 1e3
        q = load_pack(os.path.join(tmp, f"d{dim}_int8.shpk"),
                      dequantize=False)
        print(f"{dim},{t_s:.2f},{t_f:.2f},{t_f / t_s:.2f},"
              f"{shira_mb:.2f},{lora_mb:.2f},{gemm_gflop:.2f},"
              f"{t_io['f32']:.2f},{t_io['int8']:.2f},"
              f"{pack.nbytes() / q.nbytes():.1f}x")
        rows.append({"dim": dim, "shira_scatter_ms": t_s,
                     "lora_fuse_ms": t_f,
                     "pack_load_f32_ms": t_io["f32"],
                     "pack_load_int8_ms": t_io["int8"],
                     "int8_shrink": pack.nbytes() / q.nbytes()})

    if args.json is not None:
        top = rows[-1]            # the largest dim anchors the gate
        res = _emit.result(
            "rapid_switching", f"dense-{top['dim']}",
            metrics={
                "switches_per_s": 1e3 / top["shira_scatter_ms"],
                "switch_latency_ms": top["shira_scatter_ms"],
                "lora_fuse_ms": top["lora_fuse_ms"],
                "pack_load_int8_ms": top["pack_load_int8_ms"],
                "int8_shrink": top["int8_shrink"],
            },
            meta={"sparsity": SPARSITY, "rank": RANK, "dims": rows})
        print(f"wrote {_emit.emit(res, args.json or None)}")


if __name__ == "__main__":
    main()
