"""CI trace gate: replay an archived serving trace through the cost model.

  python benchmarks/check_replay.py TRACE_slo_load.jsonl \
      [--min-coverage 0.9] [--min-realized 0.5] [--allow-no-async]

Two properties of the archived ``TRACE_*.jsonl`` artifact are gated:

  * **coverage** — the serving thread's top-level spans must account for
    at least ``--min-coverage`` of the observed wall window
    (``replay.attribute``). A drop means the engine loop grew untraced
    phases and the replay what-if model is flying blind.
  * **realized overlap** — ``replay.verify_overlap`` must show at least
    ``--min-realized`` of the predicted disk-load/table-build hiding
    actually ran concurrently with decode/prefill/admit. A pipeline
    that silently serializes (the serving thread blocking on every
    load) measures ~0 here even when end-to-end numbers hide it in
    run-to-run noise. Traces with zero worker spans fail outright
    unless ``--allow-no-async`` (the async pipeline never ran — wrong
    artifact or the flag got dropped from the bench invocation).

Exit code 1 (with a per-check report) on any violation. See
``src/repro/analysis/README.md`` for the trace schema.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))
from repro.analysis import replay  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("traces", nargs="+", help="TRACE_*.jsonl files to gate")
    ap.add_argument("--min-coverage", type=float, default=0.9,
                    help="minimum span coverage of the wall window")
    ap.add_argument("--min-realized", type=float, default=0.5,
                    help="minimum realized fraction of predicted hiding")
    ap.add_argument("--allow-no-async", action="store_true",
                    help="pass traces with zero worker spans (pre-async "
                    "artifacts)")
    args = ap.parse_args()

    failures = []
    for path in args.traces:
        events = replay.load_trace(path)
        att = replay.attribute(events)
        vo = replay.verify_overlap(events)
        print(f"{path}: {len(events)} events, {att['spans']} spans")
        cov_ok = att["coverage"] >= args.min_coverage
        print(f"  [{'ok' if cov_ok else 'FAIL':>4}] coverage "
              f"{att['coverage']:.1%} (min {args.min_coverage:.0%})")
        if not cov_ok:
            failures.append(f"{path}: coverage {att['coverage']:.1%} < "
                            f"{args.min_coverage:.0%}")
        if vo["async_spans"] == 0:
            status = "ok" if args.allow_no_async else "FAIL"
            print(f"  [{status:>4}] no worker spans — async pipeline "
                  "never ran")
            if not args.allow_no_async:
                failures.append(f"{path}: no async worker spans (expected "
                                "the prefetch pipeline; --allow-no-async "
                                "for pre-async traces)")
            continue
        rel_ok = vo["realized_frac"] >= args.min_realized
        print(f"  [{'ok' if rel_ok else 'FAIL':>4}] overlap: "
              f"{vo['async_spans']} worker spans, "
              f"{vo['measured_hidden_us'] / 1e3:.1f} of "
              f"{vo['predicted_hidden_us'] / 1e3:.1f} ms predicted hiding "
              f"realized ({vo['realized_frac']:.1%}, min "
              f"{args.min_realized:.0%})")
        for name, us in sorted(vo["async_by_name"].items()):
            print(f"         {name:<16} {us / 1e3:9.2f} ms")
        if not rel_ok:
            failures.append(f"{path}: realized overlap "
                            f"{vo['realized_frac']:.1%} < "
                            f"{args.min_realized:.0%}")
    if failures:
        print("\nREPLAY GATE TRIPPED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nreplay gate: all traces within bounds")
    return 0


if __name__ == "__main__":
    sys.exit(main())
