"""Serving fault tolerance: injection, degradation ladder, shedding.

Acceptance bars pinned here:
  * The fault injector is deterministic (stateless hash draws) and a
    no-op when uninstalled — the disabled NaN-guard/injector decode path
    produces the same tokens as the fast path.
  * Store loads retry with backoff, then quarantine: persistent failures
    fail fast with ``AdapterUnavailable`` until ``clear_quarantine``;
    a REAL corrupt pack on disk walks the same ladder (crc32 ->
    ``PackFormatError`` -> retries -> ``StoreError`` + quarantine).
  * A dead prefetch worker surfaces as a typed ``StoreError`` from
    ``PrefetchHandle.result()`` AND releases the eviction pin.
  * ``AdapterStore.shutdown(wait=False)`` drains deterministically and
    is idempotent.
  * The engines' degradation ladder: quarantined ``name@v`` falls back
    to ``name@v-1``, unversioned adapters fall to base, ``fallback=
    "none"`` fails typed — degraded requests are flagged, with token
    parity against serving the fallback directly.
  * Admission robustness: bounded queue sheds at submit, queue deadlines
    shed in ``step()`` — both typed ``RequestShed``, never silent.
  * A poisoned slot (NaN logits) fails only its own request; survivors
    keep token parity. ``SimulatedPreemption`` mid-run -> rebuild ->
    resubmit reproduces the fault-free tokens (crash recovery).
"""
import hashlib
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as FutTimeoutError

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.adapters import AdapterPack
from repro.core.switching import prior_version
from repro.hub import AdapterStore, ServingEngine
from repro.models import layers, lm
from repro.runtime import faults
from repro.runtime.faults import (AdapterUnavailable, EngineWatchdog,
                                  FaultPlan, RequestShed, SlotPoisoned,
                                  StoreError)
from repro.runtime.ft import SimulatedPreemption

from test_hub import make_model_packs, synth_pack


@pytest.fixture(autouse=True)
def _no_leaked_injector():
    """Every test leaves the global switchboard clean."""
    yield
    faults.uninstall()


def draw(seed, site, key, n):
    """The injector's stateless draw, replicated so tests can *search*
    for a seed with a wanted fail/succeed pattern instead of flaking."""
    digest = hashlib.sha256(f"{seed}:{site}:{key}:{n}".encode()).digest()
    return int.from_bytes(digest[:4], "big") / 2.0 ** 32


def find_seed(site, key, pattern, p):
    """Smallest seed whose first len(pattern) draws fail (True) exactly
    per ``pattern`` at probability ``p``."""
    for seed in range(10_000):
        if all((draw(seed, site, key, i) < p) == want
               for i, want in enumerate(pattern)):
            return seed
    raise AssertionError("no seed found — widen the search")


def cold_store(tmp_path, n=3, **kw):
    store = AdapterStore(str(tmp_path / "store"), **kw)
    for i in range(n):
        store.add(synth_pack(name=f"t{i}", seed=i))
        store.evict(f"t{i}")
    return store


# ---------------------------------------------------------------------------
# Injector determinism + disabled cost
# ---------------------------------------------------------------------------

def test_injector_draws_are_deterministic():
    plan = FaultPlan(seed=3, disk_fail_p=0.5, corrupt_p=1.0)
    a, b = faults.FaultInjector(plan), faults.FaultInjector(plan)
    seq_a = [a._draw("disk", "t0") for _ in range(8)]
    seq_b = [b._draw("disk", "t0") for _ in range(8)]
    assert seq_a == seq_b                      # thread-schedule independent
    assert len(set(seq_a)) > 1                 # retries get fresh draws
    # attempts must be decorrelated: a failing first draw cannot force
    # every retry to fail too (p=0.5 would deadlock the retry rung)
    assert any(x < 0.5 for x in seq_a) and any(x >= 0.5 for x in seq_a)
    payload = b"0123456789" * 20
    ca = a.corrupt_payload("/x/p.shpk", payload)
    cb = b.corrupt_payload("/x/p.shpk", payload)
    assert ca == cb and ca != payload
    assert sum(x != y for x, y in zip(ca, payload)) == 1   # one byte flipped


def test_uninstalled_hooks_are_noops():
    assert not faults.enabled() and faults.active() is None
    payload = b"abc"
    assert faults.corrupt_payload("/p", payload) is payload
    assert faults.poison_logits(123) is None
    faults.on_disk_read("t0")
    faults.on_worker("t0")
    faults.on_table_build()
    faults.on_engine_step(99)


def test_poison_and_preempt_fire_once_at_first_reachable_step():
    inj = faults.FaultInjector(FaultPlan(poison_step=5, poison_slot=2,
                                         preempt_step=7))
    assert inj.poison_logits(4) is None
    assert inj.poison_logits(6) == 2           # >= threshold, not exact
    assert inj.poison_logits(7) is None        # once only
    inj.on_engine_step(6)
    with pytest.raises(SimulatedPreemption):
        inj.on_engine_step(9)
    inj.on_engine_step(10)                     # a rebuilt engine survives
    assert inj.counts == {"poison": 1, "preempt": 1}


# ---------------------------------------------------------------------------
# Store: retry -> quarantine ladder
# ---------------------------------------------------------------------------

def test_load_retry_then_success(tmp_path):
    store = cold_store(tmp_path, load_retries=2, retry_backoff_s=0.001)
    seed = find_seed("disk", "t0", (True, False), p=0.5)
    inj = faults.install(FaultPlan(seed=seed, disk_fail_p=0.5))
    pack = store.get("t0")
    assert pack.name == "t0"
    assert store.retries == 1
    assert inj.counts["disk_fail"] == 1
    assert store.quarantined() == []


def test_persistent_failure_quarantines_then_fail_fast(tmp_path):
    store = cold_store(tmp_path, load_retries=1, retry_backoff_s=0.001)
    inj = faults.install(FaultPlan(seed=0, disk_fail_p=1.0))
    with pytest.raises(StoreError, match="t0"):
        store.get("t0")
    assert inj.counts["disk_fail"] == 2        # initial + 1 retry
    assert store.load_failures == 1
    assert store.quarantined() == ["t0"]
    # fail-fast: no further disk attempts while quarantined
    with pytest.raises(AdapterUnavailable, match="quarantined"):
        store.get("t0")
    with pytest.raises(AdapterUnavailable):
        store.prefetch("t0")
    assert inj.counts["disk_fail"] == 2
    faults.uninstall()
    assert store.clear_quarantine("t0")
    assert not store.clear_quarantine("t0")    # second clear: nothing to do
    assert store.get("t0").name == "t0"


def test_corrupt_pack_on_disk_quarantines(tmp_path):
    """A REAL flipped payload byte (no injector): crc32 rejects it as
    PackFormatError, the retry ladder exhausts, the pack quarantines."""
    store = cold_store(tmp_path, n=2, load_retries=1,
                       retry_backoff_s=0.001)
    path = store._paths["t0"]
    good = open(path, "rb").read()
    raw = bytearray(good)
    raw[-1] ^= 0xFF                            # payload tail: crc32 territory
    open(path, "wb").write(bytes(raw))
    with pytest.raises(StoreError, match="t0"):
        store.get("t0")
    assert store.quarantined() == ["t0"]
    assert store.get("t1").name == "t1"        # siblings unaffected
    # repair + clear: the pack serves again
    open(path, "wb").write(good)
    store.clear_quarantine("t0")
    assert store.get("t0").name == "t0"


def test_injected_corruption_walks_the_real_crc_path(tmp_path):
    """corrupt_payload flips bytes BEFORE the crc check, so the injected
    fault exercises the production rejection path, not a simulated one."""
    store = cold_store(tmp_path, n=1, load_retries=0)
    faults.install(FaultPlan(seed=0, corrupt_p=1.0))
    with pytest.raises(StoreError):
        store.get("t0")
    assert store.quarantined() == ["t0"]


# ---------------------------------------------------------------------------
# Prefetch worker death + pin release (the eviction-unblocked contract)
# ---------------------------------------------------------------------------

def test_worker_death_is_typed_and_releases_pin(tmp_path):
    store = cold_store(tmp_path)
    faults.install(FaultPlan(seed=0, worker_death_p=1.0))
    h = store.prefetch("t0")
    with pytest.raises(StoreError, match="t0"):
        h.result()
    # the pin died with the handle: eviction is unblocked
    assert store.inflight_names() == []
    faults.uninstall()
    assert store.get("t0").name == "t0"        # recoverable after the fault
    assert store.evict("t0")                   # and evictable


def wedge_pool(store, gate):
    """Pre-create the store's (single) worker pool with a job parked on
    ``gate``, so every prefetch submitted after this queues behind it."""
    with store._lock:
        store._pool = ThreadPoolExecutor(max_workers=1,
                                         thread_name_prefix="shira-store")
    store._pool.submit(gate.wait)


def test_prefetch_result_timeout_keeps_handle_alive(tmp_path):
    store = cold_store(tmp_path, workers=1)
    gate = threading.Event()
    wedge_pool(store, gate)                    # wedge the single worker
    h = store.prefetch("t0")
    with pytest.raises(FutTimeoutError):
        h.result(timeout=0.05)
    assert "t0" in store.inflight_names()      # pin survives a timeout
    gate.set()
    assert h.result(timeout=20.0).name == "t0"
    assert store.inflight_names() == []


# ---------------------------------------------------------------------------
# Store shutdown: deterministic, idempotent
# ---------------------------------------------------------------------------

def test_shutdown_no_wait_cancels_and_is_idempotent(tmp_path):
    store = cold_store(tmp_path, n=3, workers=1)
    gate = threading.Event()
    wedge_pool(store, gate)                    # wedge: queued jobs cancelable
    hs = [store.prefetch(f"t{i}") for i in range(3)]
    store.shutdown(wait=False)
    gate.set()
    store.shutdown(wait=False)                 # idempotent
    store.shutdown()                           # and mixed-mode safe
    # every handle still settles deterministically: a cancelled job falls
    # back to a synchronous load, nothing blocks forever
    for i, h in enumerate(hs):
        assert h.result(timeout=20.0).name == f"t{i}"
    assert store.inflight_names() == []
    assert store._inflight_bytes == 0
    # post-shutdown prefetch degrades to sync-on-result, never respawns
    h = store.prefetch("t0")
    assert h.result().name == "t0"
    assert store._pool is None


# ---------------------------------------------------------------------------
# Engine: admission shedding, typed futures, degradation ladder
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine_setup():
    with layers.compute_precision(jnp.float32):
        cfg = get_smoke_config("starcoder2-7b")
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        packs = make_model_packs(cfg, params, 3)
        yield cfg, params, packs


def store_of(tmp_path, packs, **kw):
    store = AdapterStore(str(tmp_path / "store"), **kw)
    for p in packs:
        store.add(p, values="f32")
    return store


def prompt_of(cfg, n=6, seed=5):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(seed), (n,),
                                         0, cfg.vocab_size))


def test_serve_future_timeout_and_typed_result(engine_setup, tmp_path):
    with layers.compute_precision(jnp.float32):
        cfg, params, packs = engine_setup
        se = ServingEngine(cfg, params, slots=2, cache_size=24,
                           store=store_of(tmp_path, packs))
        fut = se.submit(prompt_of(cfg), "a0", max_tokens=2)
        t0 = time.monotonic()
        with pytest.raises(RuntimeError, match="in flight"):
            fut.result(timeout=0.05)           # bounded wait, no engine
        assert time.monotonic() - t0 < 5.0
        se.run()
        assert len(fut.result(timeout=1.0)) == 2
        se.shutdown(include_store=True)


def test_bounded_queue_sheds_typed(engine_setup, tmp_path):
    with layers.compute_precision(jnp.float32):
        cfg, params, packs = engine_setup
        se = ServingEngine(cfg, params, slots=1, cache_size=24,
                           store=store_of(tmp_path, packs), max_queue=2)
        futs = [se.submit(prompt_of(cfg), "a0", max_tokens=2)
                for _ in range(3)]
        # admission drains only at step(): the queue holds both early
        # submits, the third is shed at the door
        assert futs[2].done() and isinstance(futs[2].error, RequestShed)
        assert futs[2].error.reason == "queue_full"
        with pytest.raises(RequestShed):
            futs[2].result()
        assert se.shed == 1
        se.run()
        for f in futs[:2]:
            assert len(f.result()) == 2        # backpressure, not loss
        assert se.health()["shed"] == 1
        se.shutdown(include_store=True)


def test_queue_deadline_sheds_typed(engine_setup, tmp_path):
    with layers.compute_precision(jnp.float32):
        cfg, params, packs = engine_setup
        se = ServingEngine(cfg, params, slots=1, cache_size=24,
                           store=store_of(tmp_path, packs))
        keep = se.submit(prompt_of(cfg), "a0", max_tokens=2)
        doomed = se.submit(prompt_of(cfg), "a1", max_tokens=2,
                           deadline_s=1e-6)
        time.sleep(0.01)                       # let the deadline lapse
        se.run()
        assert len(keep.result()) == 2
        assert isinstance(doomed.error, RequestShed)
        assert doomed.error.reason == "deadline"
        with pytest.raises(RequestShed, match="deadline"):
            doomed.result()
        assert se.shed == 1
        se.shutdown(include_store=True)


def test_fallback_to_previous_version(engine_setup, tmp_path):
    """name@v quarantined -> the ladder serves name@v-1, flagged."""
    with layers.compute_precision(jnp.float32):
        cfg, params, packs = engine_setup
        store = AdapterStore(str(tmp_path / "store"))
        v1 = store.publish(AdapterPack("p", packs[0].entries,
                                       packs[0].alpha))
        v2 = store.publish(AdapterPack("p", packs[1].entries,
                                       packs[1].alpha))
        assert (v1, v2) == ("p@1", "p@2")
        assert prior_version(v2) == v1
        se = ServingEngine(cfg, params, slots=2, cache_size=24, store=store)
        toks = prompt_of(cfg)
        want = se.submit(toks, v1, max_tokens=3)
        se.run()
        store.quarantine(v2, reason="test")
        got = se.submit(toks, "p", max_tokens=3)   # resolves to p@2 -> fails
        se.run()
        assert got.degraded and got.degraded_from == "p"
        np.testing.assert_array_equal(got.result(), want.result())
        assert se.degraded == 1
        se.shutdown(include_store=True)


def test_fallback_to_base_and_none_policy(engine_setup, tmp_path):
    with layers.compute_precision(jnp.float32):
        cfg, params, packs = engine_setup
        store = store_of(tmp_path, packs)
        se = ServingEngine(cfg, params, slots=2, cache_size=24, store=store)
        toks = prompt_of(cfg)
        base = se.submit(toks, None, max_tokens=3)
        se.run()
        store.quarantine("a0", reason="test")  # unversioned: no prior rung
        got = se.submit(toks, "a0", max_tokens=3)
        se.run()
        assert got.degraded
        np.testing.assert_array_equal(got.result(), base.result())
        se.shutdown()

        strict = ServingEngine(cfg, params, slots=2, cache_size=24,
                               store=store, fallback="none")
        failed = strict.submit(toks, "a0", max_tokens=3)
        assert failed.done() and isinstance(failed.error, AdapterUnavailable)
        with pytest.raises(AdapterUnavailable):
            failed.result()
        assert strict.failed == 1
        strict.shutdown(include_store=True)


def test_nan_guard_token_parity_when_disabled_path_differs(engine_setup,
                                                           tmp_path):
    """nan_guard=True (host argmax) must reproduce the fast jnp.argmax
    path token-for-token when nothing is poisoned."""
    with layers.compute_precision(jnp.float32):
        cfg, params, packs = engine_setup
        toks = prompt_of(cfg)
        outs = []
        for guard in (False, True):
            se = ServingEngine(cfg, params, slots=2, cache_size=24,
                               store=store_of(tmp_path / str(guard), packs),
                               nan_guard=guard)
            futs = [se.submit(toks, a, max_tokens=4)
                    for a in ("a0", None)]
            se.run()
            outs.append([f.result() for f in futs])
            se.shutdown(include_store=True)
        for a, b in zip(*outs):
            np.testing.assert_array_equal(a, b)


def test_poisoned_slot_isolated_survivors_keep_parity(engine_setup,
                                                      tmp_path):
    with layers.compute_precision(jnp.float32):
        cfg, params, packs = engine_setup
        se = ServingEngine(cfg, params, slots=2, cache_size=24,
                           store=store_of(tmp_path, packs), nan_guard=True)
        toks = prompt_of(cfg)
        # fault-free reference
        ref = [se.submit(toks, a, max_tokens=6) for a in ("a0", "a1")]
        se.run()
        want = [f.result() for f in ref]
        inj = faults.install(FaultPlan(poison_step=se.step_count + 2,
                                       poison_slot=0))
        victim = se.submit(toks, "a0", max_tokens=6)
        other = se.submit(toks, "a1", max_tokens=6)
        se.run()
        faults.uninstall()
        assert inj.counts["poison"] == 1
        assert isinstance(victim.error, SlotPoisoned)
        with pytest.raises(SlotPoisoned):
            victim.result()
        # the survivor never saw the poison: token parity with fault-free
        np.testing.assert_array_equal(other.result(), want[1])
        assert se.poisoned == 1 and se.health()["poisoned"] == 1
        # the slot is reusable after quarantine
        again = se.submit(toks, "a0", max_tokens=6)
        se.run()
        np.testing.assert_array_equal(again.result(), want[0])
        se.shutdown(include_store=True)


def test_table_build_failure_backs_off_and_retries(engine_setup, tmp_path):
    with layers.compute_precision(jnp.float32):
        cfg, params, packs = engine_setup
        toks = prompt_of(cfg)
        se = ServingEngine(cfg, params, slots=2, cache_size=24,
                           store=store_of(tmp_path / "ref", packs))
        ref = se.submit(toks, "a0", max_tokens=3)
        se.run()
        se.shutdown(include_store=True)

        seed = find_seed("build", "tables", (True, False), p=0.5)
        se = ServingEngine(cfg, params, slots=2, cache_size=24,
                           store=store_of(tmp_path / "inj", packs))
        inj = faults.install(FaultPlan(seed=seed, build_fail_p=0.5))
        fut = se.submit(toks, "a0", max_tokens=3)
        se.run()
        faults.uninstall()
        assert inj.counts["build_fail"] >= 1   # a build DID fail...
        np.testing.assert_array_equal(fut.result(), ref.result())
        se.shutdown(include_store=True)


def test_crash_recovery_preempt_rebuild_resubmit(engine_setup, tmp_path):
    """SimulatedPreemption kills the loop mid-decode; a rebuilt engine
    over the same store replays the requests to identical tokens."""
    with layers.compute_precision(jnp.float32):
        cfg, params, packs = engine_setup
        store = store_of(tmp_path, packs)
        toks = prompt_of(cfg)
        spec = [("a0", 4), ("a1", 3), (None, 2)]

        se = ServingEngine(cfg, params, slots=2, cache_size=24, store=store)
        ref = [se.submit(toks, a, max_tokens=n) for a, n in spec]
        se.run()
        want = [f.result() for f in ref]
        se.shutdown()

        se = ServingEngine(cfg, params, slots=2, cache_size=24, store=store)
        futs = [se.submit(toks, a, max_tokens=n) for a, n in spec]
        faults.install(FaultPlan(preempt_step=se.step_count + 2))
        with pytest.raises(SimulatedPreemption):
            se.run()
        faults.uninstall()
        assert any(not f.done() for f in futs)    # it really died mid-work
        se.shutdown()

        rebuilt = ServingEngine(cfg, params, slots=2, cache_size=24,
                                store=store)
        futs = [rebuilt.submit(toks, a, max_tokens=n) for a, n in spec]
        rebuilt.run()
        for f, w in zip(futs, want):
            np.testing.assert_array_equal(f.result(), w)
        rebuilt.shutdown(include_store=True)


# ---------------------------------------------------------------------------
# Watchdog / health
# ---------------------------------------------------------------------------

def test_watchdog_ewma_and_stall():
    now = [100.0]
    wd = EngineWatchdog(alpha=0.5, stall_ratio=10.0, min_stall_s=0.5,
                        clock=lambda: now[0])
    assert not wd.stalled()                    # no steps yet: never stalled
    wd.record(0.010)
    wd.record(0.030)
    assert wd.ewma_s == pytest.approx(0.020)
    assert not wd.stalled()                    # gap 0 < floor
    now[0] += 0.3
    assert not wd.stalled()                    # 0.3 < max(0.2, 0.5) floor
    now[0] += 0.4
    assert wd.stalled()                        # 0.7 > 0.5
    snap = wd.snapshot()
    assert snap["steps"] == 2 and snap["stalled"]
    assert snap["since_last_step_s"] == pytest.approx(0.7)


def test_engine_health_snapshot(engine_setup, tmp_path):
    with layers.compute_precision(jnp.float32):
        cfg, params, packs = engine_setup
        se = ServingEngine(cfg, params, slots=2, cache_size=24,
                           store=store_of(tmp_path, packs))
        se.submit(prompt_of(cfg), "a0", max_tokens=2)
        se.run()
        h = se.health()
        assert h["watchdog"]["steps"] == se.step_count > 0
        assert h["watchdog"]["ewma_step_s"] > 0
        assert not h["watchdog"]["stalled"]
        assert h["queued"] == 0 and h["active"] == 0
        assert h["quarantined"] == []
        assert h["tokens_out"] == 2
        se.shutdown(include_store=True)
