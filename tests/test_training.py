"""Training integration: the paper's claims at test scale.

  * SHiRA adapters learn (loss drops on a learnable synthetic task)
  * hook-mode (App. C) and packed-mode (App. D) produce the SAME trajectory
  * packed optimizer state is 50x+ smaller than dense (the memory claim)
  * %C changed in fused mode ~1-2% for SHiRA vs ~majority for LoRA (Tab. 2)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.configs import AdapterConfig, RunConfig, TrainConfig, get_smoke_config
from repro.configs.base import ShapeSpec
from repro.data import TaskSpec, batch_iterator
from repro.runtime import Trainer
from repro.runtime.trainer import TrainerConfig

SHAPE = ShapeSpec("tiny", 64, 8, "train")


def _run(adapter: AdapterConfig, steps=40, lr=1e-2, arch="starcoder2-7b"):
    run = RunConfig(model=get_smoke_config(arch), shape=SHAPE, adapter=adapter,
                    train=TrainConfig(learning_rate=lr, total_steps=steps,
                                      warmup_steps=2))
    t = Trainer(run, TrainerConfig())
    out = t.fit(steps, log=None)
    return t, out


def test_shira_packed_reduces_loss():
    t, out = _run(AdapterConfig(kind="shira", mask="wm", sparsity=0.9))
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0] - 0.05, losses[::10]


def test_full_finetune_reduces_loss():
    t, out = _run(AdapterConfig(kind="none"), steps=25, lr=3e-3)
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0] - 0.05


def test_hook_vs_packed_equivalence():
    """Same mask => identical training trajectory (App. C == App. D)."""
    packed = AdapterConfig(kind="shira", mask="wm", sparsity=0.9, packed=True)
    hook = AdapterConfig(kind="shira", mask="wm", sparsity=0.9, packed=False)
    _, out_p = _run(packed, steps=10)
    _, out_h = _run(hook, steps=10)
    lp = [h["loss"] for h in out_p["history"]]
    lh = [h["loss"] for h in out_h["history"]]
    np.testing.assert_allclose(lp, lh, rtol=2e-3, atol=2e-3)


def test_packed_optimizer_state_is_sparse():
    """Paper App. D: optimizer state only for the 1-2% trainable set."""
    t, _ = _run(AdapterConfig(kind="shira", mask="wm", sparsity=0.98),
                steps=1)
    opt_elems = sum(x.size for x in jax.tree.leaves(t.trainable0))
    model_elems = sum(x.size for x in jax.tree.leaves(t.base))
    assert opt_elems < 0.05 * model_elems


@pytest.mark.slow
def test_percent_changed_shira_vs_lora():
    """%C column of paper Tab. 2: SHiRA overwrites ~1-2%, LoRA the majority."""
    t, out = _run(AdapterConfig(kind="shira", mask="wm", sparsity=0.98),
                  steps=5)
    pack = t.export_pack(out["state"])
    eng = core.SwitchEngine(t.base)
    eng.load(pack)
    c_shira = core.switching.changed_fraction(t.base, eng.params)
    assert c_shira < 0.05

    acfg = AdapterConfig(kind="lora", rank=4)
    t2, out2 = _run(acfg, steps=5)
    eff = core.materialize(t2.base, out2["state"]["trainable"], None, acfg)
    c_lora = core.switching.changed_fraction(t2.base, eff)
    assert c_lora > 5 * c_shira, (c_lora, c_shira)


def test_multi_task_adapters_learn_their_tasks():
    """Two adapters on different synthetic tasks: each reduces ITS task loss
    (setup for the paper's multi-adapter fusion experiment, §4.3.2)."""
    from repro.models import lm
    run = RunConfig(model=get_smoke_config("starcoder2-7b"), shape=SHAPE,
                    adapter=AdapterConfig(kind="shira", mask="wm",
                                          sparsity=0.9),
                    train=TrainConfig(learning_rate=1e-2, total_steps=30,
                                      warmup_steps=2))
    losses = {}
    for task in (1, 2):
        t = Trainer(run, TrainerConfig())
        batches = batch_iterator(run.model, SHAPE, seed=0,
                                 task=TaskSpec(task_id=task))
        out = t.fit(30, batches=batches, log=None)
        hist = [h["loss"] for h in out["history"]]
        losses[task] = hist
        assert hist[-1] < hist[0] - 0.03, f"task {task}: {hist[::10]}"


def test_gradient_masking_zeroes_nontarget():
    cfg = get_smoke_config("starcoder2-7b")
    from repro.models import lm
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    acfg = AdapterConfig(kind="shira", mask="wm", sparsity=0.95)
    masks = core.make_dense_masks(params, acfg, jax.random.PRNGKey(1))
    batch = {"tokens": jnp.ones((2, 32), jnp.int32),
             "labels": jnp.ones((2, 32), jnp.int32)}
    grads = jax.grad(lambda p: lm.train_loss(p, cfg, batch)[0])(params)
    mg = core.mask_grads(grads, masks)
    for (p, g), (_, m) in zip(
            jax.tree_util.tree_flatten_with_path(mg)[0],
            jax.tree_util.tree_flatten_with_path(
                masks, is_leaf=lambda x: x is None)[0]):
        if m is not None:
            # every gradient entry outside the mask must be exactly zero
            off = np.asarray(g) * (1 - np.asarray(m))
            assert np.all(off == 0)
