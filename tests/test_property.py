"""Property-based tests (hypothesis) on the system's invariants.

``hypothesis`` is an optional test dependency: when it is absent the whole
module is skipped at collection time instead of erroring the tier-1 run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import masks as M
from repro.kernels import ops, ref

SETTINGS = dict(max_examples=20, deadline=None)


@given(n=st.integers(2, 12).map(lambda x: x * 32),
       m=st.integers(2, 12).map(lambda x: x * 32),
       frac=st.floats(0.005, 0.05),
       seed=st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_scatter_gather_roundtrip(n, m, frac, seed):
    """gather(scatter_add(w, idx, v)) - gather(w) == v at idx."""
    rng = np.random.RandomState(seed)
    k = max(1, int(frac * n * m))
    w = jnp.asarray(rng.randn(1, n, m), jnp.float32)
    idx = jnp.asarray(np.sort(rng.choice(n * m, k, replace=False))[None],
                      jnp.int32)
    v = jnp.asarray(rng.randn(1, k), jnp.float32)
    w2 = M.scatter_packed_add(w, idx, v)
    got = M.gather_packed(w2, idx) - M.gather_packed(w, idx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(v), atol=1e-5)


@given(n=st.integers(1, 8).map(lambda x: x * 64),
       m=st.integers(1, 8).map(lambda x: x * 64),
       seed=st.integers(0, 2 ** 16),
       alpha=st.floats(-2.0, 2.0))
@settings(**SETTINGS)
def test_scatter_set_then_add_inverse(n, m, seed, alpha):
    """W + aS - aS == W exactly (load/unload invariant of rapid switching)."""
    rng = np.random.RandomState(seed)
    k = max(1, (n * m) // 100)
    w = jnp.asarray(rng.randn(1, n, m), jnp.float32)
    idx = jnp.asarray(rng.choice(n * m, k, replace=False)[None], jnp.int32)
    v = jnp.asarray(rng.randn(1, k), jnp.float32)
    w2 = M.scatter_packed_add(M.scatter_packed_add(w, idx, v, alpha),
                              idx, v, -alpha)
    np.testing.assert_allclose(np.asarray(w2), np.asarray(w), atol=1e-5)


@given(B=st.integers(1, 4), S=st.integers(1, 8),
       n=st.integers(3, 160), m=st.integers(3, 520),
       K=st.integers(0, 300), seed=st.integers(0, 2 ** 16),
       int8=st.booleans(), interpret=st.booleans())
@settings(max_examples=25, deadline=None)
def test_sidedelta_tiled_matches_ref(B, S, n, m, K, seed, int8, interpret):
    """The tiled+vectorised sidedelta (interpret AND compiled dispatch)
    must match ``sidedelta_ref`` for arbitrary (B, S, n, m, K): K=0,
    all-base batches (ids=-1), nonzeros straddling m-tile boundaries (bm
    is forced to 128 so any m > 128 tiles), and int8 tables within dequant
    tolerance."""
    rng = np.random.RandomState(seed)
    A = rng.randint(1, 4)
    x = jnp.asarray(rng.randn(B, S, n), jnp.float32)
    rows = jnp.asarray(rng.randint(0, n, (A, K)), jnp.int32)
    # bias columns toward tile edges so boundary straddling is common
    cols_np = rng.randint(0, m, (A, K))
    if K and m > 130:
        edge = rng.randint(0, K, max(K // 4, 1))
        cols_np[:, edge] = rng.choice([127, 128, m - 1], edge.shape[0])
    cols = jnp.asarray(cols_np, jnp.int32)
    vf = (0.05 * rng.randn(A, K)).astype(np.float32)
    ids = jnp.asarray(rng.randint(-1, A, (B,)), jnp.int32)
    if int8:
        pairs = [ops.quantize_table(vf[a]) for a in range(A)]
        vals = jnp.asarray(np.stack([q for q, _ in pairs]))
        scale = jnp.asarray(np.array([s for _, s in pairs], np.float32))
        want = ref.sidedelta_int8_ref(x, rows, cols, vals, scale, ids, m)
        tol = 1e-5   # vs the int8 oracle: same dequant math, exact
    else:
        vals, scale = jnp.asarray(vf), None
        want = ref.sidedelta_ref(x, rows, cols, vals, ids, m)
        tol = 1e-5
    out = ops.sidedelta(x, rows, cols, vals, ids, m=m, scale=scale,
                        interpret=interpret, bm=128, kc=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want, np.float32),
                               atol=tol, rtol=tol)
    if int8:   # and the dequant stays within the serving tolerance
        want_f = ref.sidedelta_ref(x, rows, cols, jnp.asarray(vf), ids, m)
        assert float(np.max(np.abs(
            np.asarray(out) - np.asarray(want_f, np.float32)))) < 1e-2


@given(n=st.integers(64, 256), m=st.integers(64, 256),
       sparsity=st.floats(0.9, 0.995), seed=st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_mask_budget_exact(n, m, sparsity, seed):
    k = M.budget(n, m, sparsity)
    assert 1 <= k <= n * m
    assert abs(k - (1 - sparsity) * n * m) <= 1


@given(seed=st.integers(0, 2 ** 16), steps=st.integers(1, 5))
@settings(**SETTINGS)
def test_adamw_zero_grad_only_decays(seed, steps):
    """With zero grads and wd=0 the packed AdamW must be an exact no-op."""
    rng = np.random.RandomState(seed)
    k = 256
    v = jnp.asarray(rng.randn(k), jnp.float32)
    mu = jnp.zeros((k,), jnp.float32)
    nu = jnp.zeros((k,), jnp.float32)
    for s in range(1, steps + 1):
        v2, mu, nu = ops.sparse_adamw(v, jnp.zeros((k,)), mu, nu,
                                      jnp.asarray(s), lr=1e-2, wd=0.0,
                                      interpret=True)
    np.testing.assert_allclose(np.asarray(v2), np.asarray(v), atol=1e-7)


@given(seed=st.integers(0, 2 ** 16),
       s=st.integers(2, 6).map(lambda x: x * 16),
       chunk=st.sampled_from([8, 16, 32]))
@settings(max_examples=10, deadline=None)
def test_ssd_chunk_size_invariance(seed, s, chunk):
    """SSD output must not depend on the chunk size (pure reformulation)."""
    from repro.models import mamba2
    rng = np.random.RandomState(seed)
    b, h, p, g, n = 1, 2, 4, 1, 8
    x = jnp.asarray(rng.randn(b, s, h, p), jnp.float32)
    dt = jnp.asarray(rng.rand(b, s, h) * 0.3 + 0.01, jnp.float32)
    Ah = -jnp.asarray(rng.rand(h) + 0.1, jnp.float32)
    B = jnp.asarray(rng.randn(b, s, g, n), jnp.float32)
    C = jnp.asarray(rng.randn(b, s, g, n), jnp.float32)
    y1, f1 = mamba2.ssd_chunked(x, dt, Ah, B, C, chunk)
    y2, f2 = mamba2.ssd_chunked(x, dt, Ah, B, C, s)  # one chunk
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2),
                               rtol=1e-3, atol=1e-3)


@given(seed=st.integers(0, 2 ** 16), kv_len=st.integers(1, 512))
@settings(max_examples=15, deadline=None)
def test_flash_decode_kv_len_property(seed, kv_len):
    """Tokens beyond kv_len must not influence the output."""
    rng = np.random.RandomState(seed)
    B, KV, G, D, S = 1, 1, 2, 32, 512
    q = jnp.asarray(rng.randn(B, KV, G, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, KV, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, KV, D), jnp.float32)
    out1 = ops.flash_decode(q, k, v, kv_len, sb=256, interpret=True)
    k2 = k.at[:, kv_len:].set(99.0)
    v2 = v.at[:, kv_len:].set(-99.0)
    out2 = ops.flash_decode(q, k2, v2, kv_len, sb=256, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-5)


@given(seed=st.integers(0, 2 ** 16))
@settings(max_examples=10, deadline=None)
def test_checkpoint_roundtrip_identity(seed):
    import tempfile
    from repro.checkpoint import restore_tree, save_tree
    rng = np.random.RandomState(seed)
    tree = {"a": jnp.asarray(rng.randn(4, 8), jnp.float32),
            "b": [{"c": jnp.asarray(rng.randn(3), jnp.bfloat16)},
                  jnp.asarray(rng.randint(0, 5, (2, 2)), jnp.int32)]}
    with tempfile.TemporaryDirectory() as d:
        save_tree(tree, d)
        out = restore_tree(tree, d)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
