"""Sharding rules: divisibility on the production meshes for every arch,
plus a real 4-device lower+compile of the full train step (mini dry-run)."""
import os
import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.launch import sharding as shd


class FakeMesh:
    """Just enough mesh for spec computation (no devices)."""
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_divide_production_mesh(arch):
    from repro.launch.steps import abstract_params
    cfg = get_config(arch)
    p = abstract_params(cfg)
    specs = shd.param_specs(p, cfg, FakeMesh())
    flat_p = jax.tree_util.tree_flatten_with_path(p)[0]
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for (path, leaf), spec in zip(flat_p, flat_s):
        entries = list(spec) + [None] * (leaf.ndim - len(spec))
        for dim, entry in zip(leaf.shape, entries):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            prod = 1
            for a in axes:
                prod *= FakeMesh.shape[a]
            assert dim % prod == 0, (arch, path, leaf.shape, spec)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_attention_projections_head_aligned(arch):
    """wq/wk/wv must never be sharded across a head boundary."""
    from repro.launch.steps import abstract_params
    cfg = get_config(arch)
    if cfg.attn_type != "gqa":
        return
    p = abstract_params(cfg)
    specs = shd.param_specs(p, cfg, FakeMesh())
    flat = jax.tree_util.tree_flatten_with_path(specs,
                                                is_leaf=lambda x: isinstance(x, P))[0]
    for path, spec in flat:
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if name in ("wq", "wo") and cfg.num_heads % 16 != 0:
            assert all(e is None for e in spec), (arch, name, spec)
        if name in ("wk", "wv") and cfg.num_kv_heads % 16 != 0:
            assert all(e is None for e in spec), (arch, name, spec)


MINI_DRYRUN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from repro.configs import get_smoke_config, TrainConfig
from repro.configs.base import ShapeSpec
from repro.launch import steps as S
from repro.launch.actctx import act_sharding
from repro.launch.mesh import make_mesh

cfg = get_smoke_config("%ARCH%").replace(remat="full")
shape = ShapeSpec("mini", 64, 4, "train")
mesh = make_mesh((2, 2), ("data", "model"))
state_sh, batch_sh = S.train_shardings(cfg, shape, mesh)
with act_sharding(S.act_spec_for(cfg, shape, mesh)):
    lowered = jax.jit(S.make_train_step(cfg, TrainConfig()),
                      in_shardings=(state_sh, batch_sh),
                      donate_argnums=(0,)).lower(
        S.abstract_train_state(cfg), S.abstract_batch(cfg, shape))
compiled = lowered.compile()
assert compiled.memory_analysis() is not None
print("MINI_DRYRUN_OK")
"""


@pytest.mark.parametrize("arch", ["starcoder2-7b", "mamba2-780m",
                                  "granite-moe-1b-a400m"])
def test_mini_dryrun_4dev(arch):
    """Real lower+compile of the sharded train step on 4 host devices."""
    script = MINI_DRYRUN.replace("%ARCH%", arch)
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600)
    assert "MINI_DRYRUN_OK" in out.stdout, out.stderr[-3000:]
