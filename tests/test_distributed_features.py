"""Distributed-feature numerics (subprocess: forced multi-device CPU).

  * shard_map expert-parallel MoE == single-program dispatch
  * head-group padding is function-preserving (zero-init pads)
  * shard-local SHiRA materialize == replicated materialize
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(script: str) -> str:
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, cwd=REPO, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


EP_MOE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.models.moe import moe_ffn, init_moe
from repro.launch.mesh import make_mesh
from repro.launch.actctx import sharding_hints
from jax.sharding import NamedSharding, PartitionSpec as P

cfg = get_smoke_config("granite-moe-1b-a400m")
p = init_moe(jax.random.PRNGKey(0), cfg)
x = (jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model)) * 0.5).astype(jnp.bfloat16)
y_dense, _ = moe_ffn(p, cfg, x)
mesh = make_mesh((2, 2), ("data", "model"))
with sharding_hints(moe_ep_mesh=(mesh, 2)):
    pm = dict(p)
    for k in ("experts_w_up", "experts_w_gate", "experts_w_down"):
        pm[k] = jax.device_put(p[k], NamedSharding(mesh, P("model", None, None)))
    xm = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
    y_ep, _ = jax.jit(lambda p, x: moe_ffn(p, cfg, x))(pm, xm)
    g = jax.jit(jax.grad(lambda p, x: jnp.sum(moe_ffn(p, cfg, x)[0].astype(jnp.float32))))(pm, xm)
err = float(jnp.max(jnp.abs(y_ep.astype(jnp.float32) - y_dense.astype(jnp.float32))))
assert err < 0.05, err
assert all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(g))
print("EP_MOE_OK", err)
"""


SHARD_LOCAL_SHIRA = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.adapters import materialize_sharded
from repro.core.masks import scatter_packed_add
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 2), ("data", "model"))
rng = np.random.RandomState(0)
L, n, m = 3, 8, 16
w = jnp.asarray(rng.randn(L, n, m), jnp.float32)
# shard-local indices: (L, 2, 2, Ks) flat into the (n/2, m/2) tile
ks = 5
idx = jnp.asarray(rng.randint(0, (n // 2) * (m // 2), (L, 2, 2, ks)), jnp.int32)
val = jnp.asarray(rng.randn(L, 2, 2, ks), jnp.float32)
spec = P(None, "data", "model")
params = {"wq": jax.device_put(w, NamedSharding(mesh, spec))}
out = materialize_sharded(params, {"wq": val}, {"wq": idx},
                          {"wq": spec}, mesh, alpha=0.5)["wq"]
# reference: apply each shard's updates to its tile in numpy
ref = np.asarray(w).copy()
for di in range(2):
    for mi in range(2):
        for l in range(L):
            for t in range(ks):
                fi = int(idx[l, di, mi, t])
                r, c = fi // (m // 2), fi % (m // 2)
                ref[l, di * (n // 2) + r, mi * (m // 2) + c] += 0.5 * float(val[l, di, mi, t])
np.testing.assert_allclose(np.asarray(out), ref, atol=1e-5)
print("SHARD_LOCAL_OK")
"""


def test_ep_moe_matches_dense():
    assert "EP_MOE_OK" in run_sub(EP_MOE)


def test_shard_local_shira_materialize():
    assert "SHARD_LOCAL_OK" in run_sub(SHARD_LOCAL_SHIRA)


def test_padded_heads_function_preserving():
    """Extracting the real-head sub-blocks of a padded model reproduces the
    unpadded model's outputs exactly."""
    from repro.configs import get_smoke_config
    from repro.models import lm
    from repro.models.attention import _pad_masks
    cfg = get_smoke_config("starcoder2-7b").replace(
        num_heads=6, num_kv_heads=2, pad_heads_to=8, pad_kv_to=2)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    q_real, kv_real = _pad_masks(cfg)
    hd = cfg.resolved_head_dim
    qm = np.repeat(np.asarray(q_real), hd)
    km = np.repeat(np.asarray(kv_real), hd)
    st = params["stages"][0]
    a = dict(st["attn"])
    a["wq"] = st["attn"]["wq"][:, :, qm]
    a["wk"] = st["attn"]["wk"][:, :, km]
    a["wv"] = st["attn"]["wv"][:, :, km]
    a["wo"] = st["attn"]["wo"][:, qm, :]
    if "bq" in a:
        a["bq"] = st["attn"]["bq"][:, qm]
        a["bk"] = st["attn"]["bk"][:, km]
        a["bv"] = st["attn"]["bv"][:, km]
    params_u = dict(params)
    params_u["stages"] = [dict(st, attn=a)]
    cfg_u = cfg.replace(pad_heads_to=0, pad_kv_to=0)
    l_pad = lm.train_loss(params, cfg, batch)[0]
    l_unp = lm.train_loss(params_u, cfg_u, batch)[0]
    assert float(jnp.abs(l_pad - l_unp)) < 1e-6

    # decode consistency with padding + kv-repeat
    cfg2 = cfg.replace(attn_repeat_kv=True)
    p2 = lm.init_params(cfg2, jax.random.PRNGKey(1))
    _, caches = lm.prefill(p2, cfg2, {"tokens": toks[:, :31]}, 40)
    ld, _ = lm.decode_step(p2, cfg2, toks[:, 31:32], caches, 31)
    lr, _ = lm.prefill(p2, cfg2, {"tokens": toks}, 40)
    rel = float(jnp.max(jnp.abs(ld - lr))) / (float(jnp.max(jnp.abs(lr))) + 1e-9)
    assert rel < 0.03, rel
