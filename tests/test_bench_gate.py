"""tier3-bench contract: the benchmark JSON schema and the regression
gate comparator (benchmarks/_emit.py + check_regression.py).

The gate itself must be tested — a comparator that never trips is a
green light painted on a wall. ``test_gate_trips_on_doctored_baseline``
runs the real CLI against a baseline demanding impossible throughput and
asserts the nonzero exit.
"""
import importlib.util
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_DIR = os.path.join(REPO, "benchmarks")


def _load_emit():
    spec = importlib.util.spec_from_file_location(
        "_emit", os.path.join(BENCH_DIR, "_emit.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


emit = _load_emit()


def _result(**metrics):
    return emit.result("multi_tenant", "smoke-arch", metrics,
                       meta={"smoke": True})


def test_schema_shape():
    r = _result(tokens_per_s_batched=100.0)
    assert r["schema"] == emit.SCHEMA_VERSION
    assert r["bench"] == "multi_tenant"
    assert r["metrics"] == {"tokens_per_s_batched": 100.0}
    assert r["meta"]["smoke"] is True
    with pytest.raises(TypeError):
        emit.result("multi_tenant", "a", {"tokens_per_s_batched": "fast"})


def test_emit_roundtrip(tmp_path):
    p = emit.emit(_result(tokens_per_s_batched=1.5),
                  str(tmp_path / "BENCH_x.json"))
    assert json.load(open(p))["metrics"]["tokens_per_s_batched"] == 1.5


def test_compare_passes_within_threshold():
    base = {"multi_tenant": {"gate": {"tokens_per_s_batched": 100.0}}}
    assert emit.compare(_result(tokens_per_s_batched=80.0), base) == []
    assert emit.compare(_result(tokens_per_s_batched=75.0), base) == []


def test_compare_trips_below_threshold():
    base = {"multi_tenant": {"gate": {"tokens_per_s_batched": 100.0}}}
    fails = emit.compare(_result(tokens_per_s_batched=74.9), base)
    assert len(fails) == 1 and "regressed" in fails[0]
    # custom threshold
    assert emit.compare(_result(tokens_per_s_batched=74.9), base,
                        threshold=0.5) == []


def test_compare_gate_max_trips_above_ceiling():
    """gate_max metrics are lower-is-better (latencies): the gate trips
    when current exceeds baseline * (1 + threshold)."""
    base = {"multi_tenant": {"gate_max": {"p99_ttft_ms_batched": 100.0}}}
    assert emit.compare(_result(p99_ttft_ms_batched=80.0), base) == []
    assert emit.compare(_result(p99_ttft_ms_batched=124.9), base) == []
    fails = emit.compare(_result(p99_ttft_ms_batched=125.1), base)
    assert len(fails) == 1 and "regressed" in fails[0]
    # unknown and missing metrics fail just like the floor gate
    bad = {"multi_tenant": {"gate_max": {"tokens_per_s_batched": 1.0}}}
    fails = emit.compare(_result(tokens_per_s_batched=0.5), bad)
    assert len(fails) == 1 and "unknown metric" in fails[0]
    fails = emit.compare(_result(speedup=2.0), base)
    assert len(fails) == 1 and "missing" in fails[0]


def test_compare_flags_missing_and_unknown_metrics():
    base = {"multi_tenant": {"gate": {"tokens_per_s_batched": 1.0}}}
    fails = emit.compare(_result(speedup=2.0), base)
    assert len(fails) == 1 and "missing" in fails[0]
    bad = {"multi_tenant": {"gate": {"no_such_metric": 1.0}}}
    fails = emit.compare(_result(tokens_per_s_batched=9.0), bad)
    assert len(fails) == 1 and "unknown metric" in fails[0]
    # schema drift is a failure, not a silent pass
    stale = dict(_result(tokens_per_s_batched=9.0), schema=0)
    assert emit.compare(stale, base)


def test_checked_in_baseline_is_valid():
    """baseline.json must only gate metrics its bench actually emits
    (GATED_METRICS), with positive floors — catches baseline-refresh typos
    here instead of in a red CI run."""
    base = json.load(open(os.path.join(BENCH_DIR, "baseline.json")))
    gated = {b: g["gate"] for b, g in base.items()
             if isinstance(g, dict) and "gate" in g}
    assert gated, "baseline.json gates nothing — the tier is decorative"
    for bench, gates in gated.items():
        assert bench in emit.GATED_METRICS, bench
        for metric, floor in gates.items():
            assert metric in emit.GATED_METRICS[bench], (bench, metric)
            assert isinstance(floor, (int, float)) and floor > 0
    for bench, g in base.items():
        if isinstance(g, dict) and "gate_max" in g:
            assert bench in emit.GATED_MAX_METRICS, bench
            for metric, ceil in g["gate_max"].items():
                assert metric in emit.GATED_MAX_METRICS[bench], \
                    (bench, metric)
                assert isinstance(ceil, (int, float)) and ceil > 0


def test_percentile_matches_numpy():
    """The shared helper must agree with np.percentile's default linear
    interpolation — every latency lane quotes this math."""
    np = pytest.importorskip("numpy")
    rng = np.random.default_rng(0)
    for n in (1, 2, 5, 100):
        xs = list(rng.exponential(10.0, n))
        for p in (0, 50, 95, 99, 100):
            assert emit.percentile(xs, p) == pytest.approx(
                float(np.percentile(xs, p)), rel=1e-12)
    with pytest.raises(ValueError):
        emit.percentile([], 50)


def test_percentiles_metric_fragment():
    d = emit.percentiles([1.0, 2.0, 3.0], (50, 99), "ttft_ms", "_paged")
    assert set(d) == {"p50_ttft_ms_paged", "p99_ttft_ms_paged"}
    assert d["p50_ttft_ms_paged"] == 2.0


def test_compare_accepts_schema_v1():
    """Old checked-in v1 artifacts must stay comparable under schema v2."""
    base = {"multi_tenant": {"gate": {"tokens_per_s_batched": 1.0}}}
    v1 = dict(_result(tokens_per_s_batched=9.0), schema=1)
    assert emit.compare(v1, base) == []
    assert 1 in emit.COMPAT_SCHEMAS and 2 in emit.COMPAT_SCHEMAS


def test_cli_trips_on_missing_gated_metric(tmp_path):
    """End to end: a run that silently DROPS a gated metric (bench edited,
    metric renamed) must exit 1 with a FAIL row, not print ok."""
    run = tmp_path / "BENCH_multi_tenant.json"
    emit.emit(_result(speedup=2.0), str(run))      # gated metric absent
    base = tmp_path / "baseline.json"
    json.dump({"multi_tenant": {"gate": {"tokens_per_s_batched": 1.0}}},
              open(base, "w"))
    cli = os.path.join(BENCH_DIR, "check_regression.py")
    r = subprocess.run([sys.executable, cli, str(run),
                        "--baseline", str(base)],
                       capture_output=True, text=True)
    assert r.returncode == 1, r.stdout + r.stderr
    assert "[FAIL] multi_tenant.tokens_per_s_batched: missing" in r.stdout
    assert "REGRESSION GATE TRIPPED" in r.stdout


def test_cli_trips_on_uncovered_baseline_bench(tmp_path):
    """A baseline bench with gates whose BENCH file is never passed must
    trip — deleting an artifact must not silently un-gate its metrics."""
    run = tmp_path / "BENCH_multi_tenant.json"
    emit.emit(_result(tokens_per_s_batched=9.0), str(run))
    base = tmp_path / "baseline.json"
    json.dump({"_comment": "strings are skipped",
               "multi_tenant": {"gate": {"tokens_per_s_batched": 1.0}},
               "slo_load": {"gate_max": {"p99_latency_ms": 100.0}}},
              open(base, "w"))
    cli = os.path.join(BENCH_DIR, "check_regression.py")
    r = subprocess.run([sys.executable, cli, str(run),
                        "--baseline", str(base)],
                       capture_output=True, text=True)
    assert r.returncode == 1, r.stdout + r.stderr
    assert "no run file" in r.stdout
    # an intentionally-absent lane is opted out explicitly
    r = subprocess.run([sys.executable, cli, str(run),
                        "--baseline", str(base),
                        "--allow-missing", "slo_load"],
                       capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr


def test_gate_trips_on_doctored_baseline(tmp_path):
    """End to end through the real CLI: a baseline demanding impossible
    throughput must exit nonzero; the honest baseline must pass."""
    run = tmp_path / "BENCH_multi_tenant.json"
    emit.emit(_result(tokens_per_s_batched=500.0), str(run))
    doctored = tmp_path / "baseline.json"
    json.dump({"multi_tenant": {"gate": {"tokens_per_s_batched": 1e9}}},
              open(doctored, "w"))
    cli = os.path.join(BENCH_DIR, "check_regression.py")
    r = subprocess.run([sys.executable, cli, str(run),
                        "--baseline", str(doctored)],
                       capture_output=True, text=True)
    assert r.returncode == 1, r.stdout + r.stderr
    assert "REGRESSION GATE TRIPPED" in r.stdout
    honest = tmp_path / "ok.json"
    json.dump({"multi_tenant": {"gate": {"tokens_per_s_batched": 400.0}}},
              open(honest, "w"))
    r = subprocess.run([sys.executable, cli, str(run),
                        "--baseline", str(honest)],
                       capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr
