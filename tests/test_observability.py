"""Trace-driven observability: tracer, replay cost model, autotune cache,
load generator (src/repro/analysis/{trace,replay,autotune}.py +
src/repro/serving/loadgen.py).

The contracts pinned here are the subsystem's acceptance criteria:

  * tracing OFF is a no-op — the hooks return a shared null singleton and
    the per-call cost is far under 1% of a decode step;
  * a traced paged-engine run decomposes: >= 90% of run() wall time lands
    in spans and ``step_timeline`` reproduces the scheduling loop;
  * ``plan_tiles`` consults the autotune cache — a cache hit changes the
    chosen (bm, kc) with kernel parity intact, an over-budget cached plan
    is rejected back to the static heuristic;
  * ``plan_tiles`` never plans over the VMEM budget (property test);
  * the load generator is deterministic per seed and drives the paged
    engine to completion with wall-clock latency/TTFT stamps.
"""
import importlib
import json
import os
import time
import timeit

import numpy as np
import pytest

from repro.analysis import autotune, replay, trace

SD = importlib.import_module("repro.kernels.sidedelta")
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def _clean_tracer_and_cache():
    trace.uninstall()
    SD.clear_plan_cache()
    yield
    trace.uninstall()
    SD.clear_plan_cache()


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------

def test_span_nesting_and_args():
    tr = trace.install()
    with trace.span("outer", cat="t", a=1):
        time.sleep(0.001)
        with trace.span("inner", cat="t") as sp:
            sp.set(found=42)
        trace.instant("marker", cat="t", note="x")
        trace.counter("gauge", 7.0, cat="t")
    evs = tr.events()
    by = {e["name"]: e for e in evs}
    assert by["outer"]["depth"] == 0 and by["inner"]["depth"] == 1
    assert by["outer"]["args"] == {"a": 1}
    assert by["inner"]["args"] == {"found": 42}
    assert by["marker"]["ph"] == "i" and by["gauge"]["ph"] == "C"
    assert by["gauge"]["args"]["value"] == 7.0
    # inner nests inside outer's interval
    o, i = by["outer"], by["inner"]
    assert o["ts"] <= i["ts"] and i["ts"] + i["dur"] <= o["ts"] + o["dur"]
    assert o["dur"] >= 1000.0          # the 1ms sleep, in microseconds


def test_ring_buffer_drops_oldest():
    tr = trace.install(trace.Tracer(capacity=4))
    for k in range(10):
        trace.instant(f"e{k}")
    assert len(tr) == 4 and tr.dropped == 6
    assert [e["name"] for e in tr.events()] == ["e6", "e7", "e8", "e9"]


def test_jsonl_and_chrome_export(tmp_path):
    tr = trace.install()
    with trace.span("work", cat="c", k=1):
        trace.instant("tick")
    jsonl = tr.to_jsonl(str(tmp_path / "t.jsonl"))
    loaded = replay.load_trace(jsonl)
    assert [e["name"] for e in loaded] == [e["name"] for e in tr.events()]
    chrome = json.load(open(tr.to_chrome(str(tmp_path / "t.json"))))
    phs = {e["name"]: e for e in chrome["traceEvents"]}
    assert phs["work"]["ph"] == "X" and "dur" in phs["work"]
    assert phs["tick"]["ph"] == "i" and phs["tick"]["s"] == "t"


def test_disabled_tracing_is_noop():
    assert not trace.enabled()
    s1 = trace.span("x", arg=1)
    s2 = trace.span("y")
    assert s1 is s2                        # shared null singleton: no alloc
    with s1 as s:
        assert s.set(a=1) is s             # .set is a no-op, chains fine
    trace.instant("z")
    trace.counter("c", 1.0)
    tr = trace.install()
    assert len(tr) == 0                    # nothing leaked into the buffer


def test_disabled_call_cost_is_negligible():
    """The acceptance bar is <1% overhead on a traced workload with
    tracing off. A decode step is milliseconds; pin the disabled hook at
    <20us/call (it is ~100ns — the bound is generous to stay unflaky)."""
    assert not trace.enabled()

    def hot():
        with trace.span("step", engine="paged"):
            pass

    per_call = min(timeit.repeat(hot, number=1000, repeat=5)) / 1000
    assert per_call < 20e-6, f"disabled span() costs {per_call * 1e6:.1f}us"


# ---------------------------------------------------------------------------
# Replay cost model (synthetic traces)
# ---------------------------------------------------------------------------

def _ev(name, ts, dur, depth=0, cat="serving", **args):
    return {"ph": "X", "name": name, "cat": cat, "ts": ts, "dur": dur,
            "depth": depth, "args": args}


def test_attribute_self_time_and_coverage():
    # step [0, 100) wraps decode [10, 70): decode self 60, step self 40
    evs = [_ev("step", 0, 100, step=1), _ev("decode", 10, 60, depth=1)]
    att = replay.attribute(evs, wall_us=200.0)
    assert att["by_name"]["step"] == pytest.approx(40.0)
    assert att["by_name"]["decode"] == pytest.approx(60.0)
    assert att["coverage"] == pytest.approx(0.5)   # 100 of 200 top-level
    ranked = replay.critical_path(evs)
    assert ranked[0]["name"] == "decode"
    assert ranked[0]["frac"] == pytest.approx(0.6)


def test_step_timeline_reconstructs_loop():
    evs = [_ev("step", 0, 100, step=1), _ev("decode", 10, 60, depth=1),
           _ev("step", 120, 80, step=2), _ev("prefill_chunk", 125, 30,
                                             depth=1)]
    tl = replay.step_timeline(evs)
    assert [r["step"] for r in tl] == [1, 2]
    assert tl[0]["phases"] == {"decode": 60}
    assert tl[1]["phases"] == {"prefill_chunk": 30}


def test_what_if_overlap_and_scale():
    # 100us wall: decode self 60, table_rebuild self 30, 10 uncovered
    evs = [_ev("decode", 0, 60), _ev("table_rebuild", 60, 30)]
    wi = replay.what_if(evs, overlap=("table_rebuild",), under="decode",
                        wall_us=100.0)
    # rebuild (30) hides fully under decode (60): 100 -> 70
    assert wi["baseline_us"] == pytest.approx(100.0)
    assert wi["hidden_us"] == pytest.approx(30.0)
    assert wi["replayed_us"] == pytest.approx(70.0)
    # a 2x faster decode: 60 -> 30, hiding capped at the new budget
    wi = replay.what_if(evs, overlap=("table_rebuild",), under="decode",
                        scale={"decode": 0.5}, wall_us=100.0)
    assert wi["replayed_us"] == pytest.approx(30 + 30 + 10 - 30)
    # nothing hidden without overlap: only scaling applies
    wi = replay.what_if(evs, scale={"decode": 0.5}, wall_us=100.0)
    assert wi["replayed_us"] == pytest.approx(70.0)
    assert wi["speedup"] == pytest.approx(100.0 / 70.0)


def test_join_costs_roofline_ratio():
    from repro.analysis.roofline import HW
    hw = HW()
    evs = [_ev("decode", 0, 1000), _ev("decode", 1000, 3000)]
    cost = {"flops": 1e9, "bytes_accessed": 1e6}
    out = replay.join_costs(evs, {"decode": cost}, hw)["decode"]
    assert out["count"] == 2 and out["measured_us_mean"] == 2000
    model = max(1e9 / hw.peak_flops, 1e6 / hw.hbm_bw) * 1e6
    assert out["model_us"] == pytest.approx(model)
    assert out["ratio"] == pytest.approx(2000 / model)


# ---------------------------------------------------------------------------
# Autotune plan cache
# ---------------------------------------------------------------------------

def test_plan_cache_hit_changes_plan_with_parity():
    """plan_tiles consults the cache; a hit changes the chosen (bm, kc)
    and the kernel's output is bit-identical under either plan."""
    import jax
    import jax.numpy as jnp
    S, n, m, K = 4, 256, 256, 300
    static = SD.plan_tiles(S, n, m, K)
    alt = (128, 128)
    assert alt != static
    assert SD.plan_is_valid(S, n, m, K, *alt,
                            vmem_budget=SD.DEFAULT_VMEM_BUDGET, x_itemsize=4)

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, S, n)), jnp.float32)
    rows = jnp.asarray(rng.integers(0, n, (2, K)), jnp.int32)
    cols = jnp.asarray(rng.integers(0, m, (2, K)), jnp.int32)
    vals = jnp.asarray(rng.standard_normal((2, K)), jnp.float32)
    ids = jnp.asarray([0, 1], jnp.int32)

    want = jax.device_get(SD.sidedelta_rows(x, rows, cols, vals, ids, m))
    assert SD.plan_cache_stats["misses"] >= 1

    key = SD.plan_cache_key(S, n, m, K)
    SD.install_plan_cache({key: alt})
    assert SD.plan_tiles(S, n, m, K) == alt        # the hit changes the plan
    assert SD.plan_cache_stats["hits"] >= 1
    got = jax.device_get(SD.sidedelta_rows(x, rows, cols, vals, ids, m))
    np.testing.assert_allclose(got, want, rtol=0, atol=1e-5)

    SD.clear_plan_cache()
    assert SD.plan_tiles(S, n, m, K) == static     # and clears back


def test_plan_cache_rejects_invalid_entries():
    S, n, m, K = 4, 256, 256, 300
    static = SD.plan_tiles(S, n, m, K)
    key = SD.plan_cache_key(S, n, m, K)
    # over-budget and misaligned entries fall back to the heuristic
    for bad in ((1 << 20, 1 << 20), (100, 128), (0, 0)):
        SD.clear_plan_cache()
        SD.install_plan_cache({key: bad})
        assert SD.plan_tiles(S, n, m, K) == static
        assert SD.plan_cache_stats["rejected"] == 1
        assert SD.plan_cache_stats["hits"] == 0


def test_autotune_save_load_install_roundtrip(tmp_path):
    key = SD.plan_cache_key(4, 256, 256, 300)
    plans = {key: (128, 128)}
    path = autotune.save_cache(plans, str(tmp_path / "plans.json"),
                               meta={"host": "test"})
    loaded = autotune.load_cache(path)
    assert loaded == {key: (128, 128)}
    assert autotune.install(loaded) == 1
    assert SD.plan_cache() == {key: (128, 128)}
    assert autotune.maybe_install_file(str(tmp_path / "absent.json")) == 0


def test_observe_records_shape_classes():
    autotune.clear_observed()
    with autotune.observe():
        SD.plan_tiles(4, 256, 256, 300)
        SD.plan_tiles(4, 256, 256, 300)
        SD.plan_tiles(1, 64, 64, 80)
    shapes = autotune.observed_shapes()
    assert shapes[0] == SD.plan_cache_key(4, 256, 256, 300)  # most requested
    assert len(shapes) == 2
    autotune.clear_observed()
    assert autotune.observed_shapes() == []


def test_checked_in_plan_cache_changes_a_plan():
    """benchmarks/plan_cache.json must contain >= 1 measured plan that
    differs from the static heuristic — otherwise the autotune tier is
    decorative. Every entry must be valid for its budget."""
    path = os.path.join(REPO, "benchmarks", "plan_cache.json")
    plans = autotune.load_cache(path)
    assert plans, "plan cache is empty"
    changed = 0
    for key, (bm, kc) in plans.items():
        S, n, m, K, budget, isize = key
        assert SD.plan_is_valid(S, n, m, K, bm, kc, vmem_budget=budget,
                                x_itemsize=isize), key
        if (bm, kc) != SD.plan_tiles(S, n, m, K, vmem_budget=budget,
                                     x_itemsize=isize):
            changed += 1
    assert changed >= 1


def test_plan_tiles_budget_random_sweep():
    """Seeded fallback for the hypothesis property below: the invariant
    still runs where hypothesis is not installed."""
    rng = np.random.default_rng(0)
    for _ in range(200):
        S = int(rng.integers(1, 65))
        n = int(rng.integers(1, 4097))
        m = int(rng.integers(1, 8193))
        K = int(rng.integers(1, 65537))
        budget = int(rng.integers(1 << 18, 1 << 24))
        bm, kc = SD.plan_tiles(S, n, m, K, vmem_budget=budget, x_itemsize=4)
        assert bm % SD._LANE == 0 and kc % SD._LANE == 0
        if (bm, kc) != (SD._LANE, SD._LANE):
            assert SD.vmem_estimate(S, n, m, K, bm, kc) <= budget


def test_plan_tiles_respects_vmem_budget_property():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(S=st.integers(1, 64), n=st.integers(1, 4096),
           m=st.integers(1, 8192), K=st.integers(1, 65536),
           budget=st.integers(1 << 18, 1 << 24),
           cached=st.booleans())
    def prop(S, n, m, K, budget, cached):
        SD.clear_plan_cache()
        if cached:     # a hostile cache must never break the budget either
            SD.install_plan_cache(
                {SD.plan_cache_key(S, n, m, K, budget, 4): (1 << 17, 512)})
        bm, kc = SD.plan_tiles(S, n, m, K, vmem_budget=budget, x_itemsize=4)
        assert bm % SD._LANE == 0 and kc % SD._LANE == 0
        assert bm >= SD._LANE and kc >= SD._LANE
        # best-effort floor: (128, 128) may exceed a tiny budget, anything
        # larger must fit
        if (bm, kc) != (SD._LANE, SD._LANE):
            assert SD.vmem_estimate(S, n, m, K, bm, kc) <= budget

    prop()
    SD.clear_plan_cache()


# ---------------------------------------------------------------------------
# Load generator
# ---------------------------------------------------------------------------

def test_loadgen_schedule_deterministic_and_shaped():
    from repro.serving import loadgen
    phases = [loadgen.Phase(1.0, 10.0), loadgen.Phase(1.0, 80.0, burst=3.0),
              loadgen.Phase(1.0, 10.0)]
    gen = loadgen.LoadGen(adapters=["a", "b", "c"], vocab=100, seed=3,
                          zipf_s=1.5, phases=phases, shared_prefix=4)
    r1, r2 = gen.schedule(), gen.schedule()
    assert len(r1) == len(r2) and all(
        a.t == b.t and a.adapter == b.adapter
        and np.array_equal(a.prompt, b.prompt) for a, b in zip(r1, r2))
    # arrival times are monotone and inside the trace window
    ts = [r.t for r in r1]
    assert ts == sorted(ts) and 0 <= ts[0] and ts[-1] < 3.0
    # the overload phase dominates arrivals
    per_phase = {p: sum(r.phase == p for r in r1) for p in (0, 1, 2)}
    assert per_phase[1] > per_phase[0] and per_phase[1] > per_phase[2]
    # Zipf: the rank-0 adapter is the most popular
    counts = {a: sum(r.adapter == a for r in r1) for a in "abc"}
    assert counts["a"] == max(counts.values())
    # every prompt opens with the shared prefix
    p0 = r1[0].prompt[:4]
    assert all(np.array_equal(r.prompt[:4], p0) for r in r1)


def test_zipf_probs():
    from repro.serving.loadgen import zipf_probs
    p = zipf_probs(5, 1.2)
    assert p.sum() == pytest.approx(1.0)
    assert all(p[i] > p[i + 1] for i in range(4))


# ---------------------------------------------------------------------------
# Traced serving run: attribution coverage + timeline (the subsystem's
# end-to-end acceptance test)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def paged_setup():
    import jax
    from repro.configs import get_smoke_config
    from repro.launch.serve import make_adapters
    from repro.models import lm
    cfg = get_smoke_config("starcoder2-7b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    packs = make_adapters(cfg, params, 2, jax.random.PRNGKey(7),
                          multi_tenant=True)
    return cfg, params, packs


def _paged_engine(cfg, params, packs):
    from repro.hub import PagedServingEngine
    engine = PagedServingEngine(cfg, params, slots=2, num_pages=33,
                                page_size=2, max_len=24, chunk_size=4)
    for p in packs:
        engine.register(p)
    return engine


def test_traced_paged_run_coverage_and_timeline(paged_setup):
    import jax
    cfg, params, packs = paged_setup
    engine = _paged_engine(cfg, params, packs)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, rng.integers(3, 8))
               for _ in range(5)]
    # warmup compiles prefill/decode so the traced window measures the
    # serving loop, not XLA compilation
    engine.submit(prompts[0], packs[0].name, max_tokens=2)
    engine.run()

    tr = trace.install()
    futs = [engine.submit(p, packs[i % 2].name, max_tokens=3)
            for i, p in enumerate(prompts)]
    wall = engine.run()
    trace.uninstall()
    assert all(f.done() for f in futs)
    assert all(f.finish_time is not None and f.finish_time > f.submit_time
               for f in futs)

    names = {e["name"] for e in tr.events()}
    assert {"step", "admit", "prefill_chunk", "decode"} <= names
    att = replay.attribute(tr, wall_us=wall * 1e6)
    assert att["coverage"] >= 0.90, \
        f"spans cover only {att['coverage']:.1%} of run() wall"
    # the timeline reproduces the engine's scheduling loop tick by tick
    tl = replay.step_timeline(tr)
    counted = [r for r in tl if r["step"] is not None]
    assert [r["step"] for r in counted] == \
        list(range(counted[0]["step"], counted[0]["step"] + len(counted)))
    assert counted[-1]["step"] == engine.step_count
    assert any("decode" in r["phases"] for r in counted)
    assert any("prefill_chunk" in r["phases"] for r in counted)
    del jax


def test_loadgen_drives_paged_engine(paged_setup):
    from repro.serving import loadgen
    cfg, params, packs = paged_setup
    engine = _paged_engine(cfg, params, packs)
    gen = loadgen.LoadGen(adapters=[p.name for p in packs],
                          vocab=cfg.vocab_size, seed=1,
                          phases=[loadgen.Phase(0.2, 40.0, burst=2.0)],
                          prompt_len=(3, 6), max_tokens=(2, 4),
                          shared_prefix=2)
    reqs = gen.schedule()
    assert reqs
    rep = loadgen.run(engine, reqs, slo_ms=60_000.0)
    assert rep.completed == rep.offered == len(reqs)
    assert len(rep.latencies_ms) == rep.completed
    assert len(rep.ttfts_ms) == rep.completed
    assert all(np.isfinite(x) and x >= 0 for x in rep.latencies_ms)
    assert rep.tokens_out > 0 and rep.steps > 0
    assert rep.goodput_tok_s <= rep.tokens_per_s + 1e-9
    assert rep.slo_violation_rate == 0.0       # SLO is 60s: all met
