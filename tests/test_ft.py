"""Fault tolerance: checkpointing, preemption recovery, stragglers,
elastic re-mesh restore."""
import json
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_tree, save_tree
from repro.configs import AdapterConfig, RunConfig, TrainConfig, get_smoke_config
from repro.configs.base import ShapeSpec
from repro.runtime import SimulatedPreemption, StragglerMonitor, Trainer
from repro.runtime.trainer import TrainerConfig


def test_checkpoint_keep_k_and_commit_marker():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        tree = {"x": jnp.arange(4.0)}
        for s in (10, 20, 30, 40):
            mgr.save(s, {"state": tree})
        assert mgr.steps() == [30, 40]
        # an uncommitted dir must be invisible
        os.makedirs(os.path.join(d, "step_00000099"))
        assert mgr.latest_step() == 40


def test_checkpoint_restore_dtype_and_shape_guard():
    with tempfile.TemporaryDirectory() as d:
        tree = {"w": jnp.ones((4, 4), jnp.float32)}
        save_tree(tree, d)
        out = restore_tree({"w": jnp.zeros((4, 4), jnp.float32)}, d)
        np.testing.assert_array_equal(np.asarray(out["w"]), np.ones((4, 4)))
        with pytest.raises(ValueError):
            restore_tree({"w": jnp.zeros((2, 2), jnp.float32)}, d)
        with pytest.raises(KeyError):
            restore_tree({"nope": jnp.zeros((4, 4))}, d)


def _mk_run(steps=20):
    return RunConfig(
        model=get_smoke_config("starcoder2-7b"),
        shape=ShapeSpec("tiny", 64, 4, "train"),
        adapter=AdapterConfig(kind="shira", mask="wm", sparsity=0.95),
        train=TrainConfig(learning_rate=5e-3, total_steps=steps,
                          warmup_steps=2))


def test_preemption_recovery_is_deterministic():
    run = _mk_run()
    with tempfile.TemporaryDirectory() as d1:
        t1 = Trainer(run, TrainerConfig(ckpt_dir=d1, ckpt_every=5,
                                        log_every=1000))
        clean = t1.fit(12, log=None)
    hits = {"n": 0}

    def injector(s):
        if s == 8 and hits["n"] == 0:
            hits["n"] += 1
            raise SimulatedPreemption()

    with tempfile.TemporaryDirectory() as d2:
        t2 = Trainer(run, TrainerConfig(ckpt_dir=d2, ckpt_every=5,
                                        log_every=1000))
        resumed = t2.fit(12, fault_injector=injector, log=None)
    assert hits["n"] == 1
    assert abs(clean["history"][-1]["loss"]
               - resumed["history"][-1]["loss"]) < 1e-6


def test_straggler_monitor_flags_and_rebalances():
    mon = StragglerMonitor(n_hosts=8, z_thresh=2.0, min_ratio=1.2)
    for step in range(10):
        for h in range(8):
            mon.record(h, 1.0 if h != 3 else 3.0)  # host 3 is 3x slower
        rep = mon.end_step()
    assert rep.stragglers == [3]
    plan = mon.rebalance_plan(rep, shards_per_host=4)
    assert sum(plan.values()) == 32
    assert plan[3] < plan[0], "straggler must get less work"


def test_straggler_monitor_quiet_on_healthy_fleet():
    mon = StragglerMonitor(n_hosts=8)
    rng = np.random.RandomState(0)
    for step in range(10):
        for h in range(8):
            mon.record(h, 1.0 + rng.rand() * 0.05)
        rep = mon.end_step()
    assert rep.healthy


def test_bounded_barrier():
    from repro.runtime.ft import BoundedBarrier
    b = BoundedBarrier(timeout_s=10.0, grace_ratio=5.0)
    assert not b.should_abort(waited_s=2.0, fleet_mean_step_s=1.0)
    assert b.should_abort(waited_s=6.0, fleet_mean_step_s=1.0)
    assert b.should_abort(waited_s=11.0, fleet_mean_step_s=100.0)


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, tempfile, json
import jax, jax.numpy as jnp, numpy as np
sys.path.insert(0, "src")
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import save_tree, restore_tree
from repro.launch.mesh import make_mesh

d = sys.argv[1]
tree = {"w": jnp.arange(64.0).reshape(8, 8)}
# save from a (4,2) mesh
m1 = make_mesh((4, 2), ("data", "model"))
t1 = jax.device_put(tree, NamedSharding(m1, P("data", "model")))
save_tree(t1, d)
# restore onto a DIFFERENT (2,4) mesh -> elastic re-scale
m2 = make_mesh((2, 4), ("data", "model"))
sh = {"w": NamedSharding(m2, P("data", "model"))}
out = restore_tree(tree, d, shardings=sh)
assert out["w"].sharding.mesh.shape["model"] == 4
np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(64.0).reshape(8, 8))
print("ELASTIC_OK")
"""


def test_elastic_remesh_restore():
    """Save on mesh (4,2), restore onto mesh (2,4) — in a subprocess so the
    forced device count never leaks into this test session."""
    with tempfile.TemporaryDirectory() as d:
        out = subprocess.run(
            [sys.executable, "-c", ELASTIC_SCRIPT, d],
            capture_output=True, text=True, cwd=os.path.dirname(
                os.path.dirname(os.path.abspath(__file__))))
        assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]


def test_adapter_only_checkpoint_is_small():
    run = _mk_run()
    t = Trainer(run, TrainerConfig())
    state = t.init_state()
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(0, {"adapter": state["trainable"]})
        adapter_bytes = sum(
            os.path.getsize(os.path.join(r, f))
            for r, _, fs in os.walk(d) for f in fs)
    model_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree.leaves(t.base))
    assert adapter_bytes < 0.25 * model_bytes
