"""Model-layer numerics: SSD vs naive recurrence, chunked attention vs
dense reference, MLA absorption equivalence, RoPE properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import attention as A
from repro.models import mamba2
from repro.models.layers import apply_rope


def ssd_naive(x, dt, Ah, B, C):
    """Step-by-step linear recurrence oracle for SSD."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hg = h // g
    Bh = np.repeat(np.asarray(B, np.float64), hg, axis=2)   # (b,s,h,n)
    Ch = np.repeat(np.asarray(C, np.float64), hg, axis=2)
    xf = np.asarray(x, np.float64)
    dtf = np.asarray(dt, np.float64)
    state = np.zeros((b, h, p, n))
    ys = np.zeros((b, s, h, p))
    for t in range(s):
        dA = np.exp(dtf[:, t] * np.asarray(Ah, np.float64)[None])  # (b,h)
        state = state * dA[..., None, None] + \
            (xf[:, t] * dtf[:, t][..., None])[..., None] * Bh[:, t][:, :, None, :]
        ys[:, t] = np.einsum("bhpn,bhn->bhp", state, Ch[:, t])
    return ys, state


@pytest.mark.parametrize("s,chunk", [(32, 8), (40, 16), (64, 64)])
def test_ssd_chunked_matches_naive(s, chunk):
    rng = np.random.RandomState(0)
    b, h, p, g, n = 2, 4, 8, 2, 16
    x = jnp.asarray(rng.randn(b, s, h, p), jnp.float32)
    dt = jnp.asarray(rng.rand(b, s, h) * 0.5 + 0.01, jnp.float32)
    Ah = -jnp.asarray(rng.rand(h) + 0.1, jnp.float32)
    B = jnp.asarray(rng.randn(b, s, g, n), jnp.float32)
    C = jnp.asarray(rng.randn(b, s, g, n), jnp.float32)
    y, final = mamba2.ssd_chunked(x, dt, Ah, B, C, chunk)
    y_ref, final_ref = ssd_naive(x, dt, Ah, B, C)
    np.testing.assert_allclose(np.asarray(y, np.float32), y_ref,
                               rtol=5e-2, atol=5e-2)
    np.testing.assert_allclose(np.asarray(final), final_ref,
                               rtol=1e-3, atol=1e-3)


@pytest.mark.slow
def test_ssd_initial_state_continuation():
    """Running [first half] then [second half with carried state] must equal
    one full pass — the invariant prefill/decode rely on."""
    rng = np.random.RandomState(1)
    b, s, h, p, g, n, chunk = 1, 64, 2, 4, 1, 8, 16
    x = jnp.asarray(rng.randn(b, s, h, p), jnp.float32)
    dt = jnp.asarray(rng.rand(b, s, h) * 0.5 + 0.01, jnp.float32)
    Ah = -jnp.asarray(rng.rand(h) + 0.1, jnp.float32)
    B = jnp.asarray(rng.randn(b, s, g, n), jnp.float32)
    C = jnp.asarray(rng.randn(b, s, g, n), jnp.float32)
    y_full, fin_full = mamba2.ssd_chunked(x, dt, Ah, B, C, chunk)
    half = s // 2
    y1, st = mamba2.ssd_chunked(x[:, :half], dt[:, :half], Ah, B[:, :half],
                                C[:, :half], chunk)
    y2, fin2 = mamba2.ssd_chunked(x[:, half:], dt[:, half:], Ah, B[:, half:],
                                  C[:, half:], chunk, initial_state=st)
    np.testing.assert_allclose(np.asarray(y_full[:, half:]), np.asarray(y2),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(fin_full), np.asarray(fin2),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("Sq,q_chunk", [(64, 16), (64, 64), (100, 32)])
def test_chunked_attention_matches_dense(Sq, q_chunk):
    rng = np.random.RandomState(2)
    B, H, KV, D = 2, 4, 2, 16
    q = jnp.asarray(rng.randn(B, Sq, H, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, Sq, KV, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, Sq, KV, D), jnp.float32)
    out = A.chunked_attention(q, k, v, causal=True, q_chunk=q_chunk)
    # dense reference
    G = H // KV
    qg = np.asarray(q).reshape(B, Sq, KV, G, D)
    s = np.einsum("bqkgd,bskd->bkgqs", qg, np.asarray(k)) / np.sqrt(D)
    mask = np.tril(np.ones((Sq, Sq), bool))
    s = np.where(mask[None, None, None], s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    want = np.einsum("bkgqs,bskd->bqkgd", p, np.asarray(v)).reshape(
        B, Sq, H, D)
    np.testing.assert_allclose(np.asarray(out, np.float32), want,
                               rtol=3e-2, atol=3e-2)


def test_prefix_lm_attention_sees_prefix():
    """Prefix tokens must be visible to all positions (paligemma)."""
    rng = np.random.RandomState(3)
    B, S, H, D, P = 1, 16, 2, 8, 4
    q = jnp.asarray(rng.randn(B, S, H, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, H, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, H, D), jnp.float32)
    causal = A.chunked_attention(q, k, v, causal=True, prefix_len=0)
    prefix = A.chunked_attention(q, k, v, causal=True, prefix_len=P)
    # position 0 attends to the whole prefix under prefix-LM but only itself
    # under causal -> outputs must differ
    assert not np.allclose(np.asarray(causal[:, 0]), np.asarray(prefix[:, 0]))


@pytest.mark.slow
def test_mla_decode_absorption_equivalence():
    cfg = get_smoke_config("deepseek-v2-lite-16b")
    params = A.init_mla(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S + 1, cfg.d_model)) \
        .astype(jnp.bfloat16)
    full = A.mla_train(params, cfg, x)
    _, cache = A.mla_prefill(params, cfg, x[:, :S], S + 2)
    dec, _ = A.mla_decode(params, cfg, x[:, S:S + 1], cache, S)
    np.testing.assert_allclose(np.asarray(full[:, S:], np.float32),
                               np.asarray(dec, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_rope_relative_property():
    """<rope(q,i), rope(k,j)> depends only on i-j."""
    rng = np.random.RandomState(4)
    D = 32
    q = jnp.asarray(rng.randn(1, 1, 1, D), jnp.float32)
    k = jnp.asarray(rng.randn(1, 1, 1, D), jnp.float32)

    def score(i, j):
        qi = apply_rope(q, jnp.array([i]), 10000.0)
        kj = apply_rope(k, jnp.array([j]), 10000.0)
        return float(jnp.sum(qi * kj))

    assert abs(score(5, 3) - score(10, 8)) < 1e-3
    assert abs(score(0, 0) - score(7, 7)) < 1e-3


def test_moe_routing_topk_and_aux():
    from repro.models.moe import moe_ffn, init_moe
    cfg = get_smoke_config("granite-moe-1b-a400m")
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) \
        .astype(jnp.bfloat16)
    y, aux = moe_ffn(p, cfg, x)
    assert y.shape == x.shape
    assert float(aux) > 0.9  # perfectly balanced would be ~1.0 (E*sum f*P)
