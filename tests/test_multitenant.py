"""Multi-tenant serving: per-request batched SHiRA deltas in one batch.

Parity contract: a mixed-adapter batch served by MultiTenantEngine (one
forward pass, per-request side-deltas) must match serving each request
alone after SwitchEngine-switching to its adapter. Run in f32 compute —
the two paths evaluate the delta in different orders, so bf16 would bury
the comparison in rounding noise.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.configs import AdapterConfig, get_smoke_config
from repro.core.adapters import AdapterPack
from repro.core.switching import FusedLRU
from repro.models import layers, lm
from repro.serving import MultiTenantEngine
from repro.serving.multitenant import switch_per_request_reference

TARGETS = ("wq", "wk", "wv", "wo", "w_up", "w_gate", "w_down", "out_proj")


def make_packs(cfg, params, n, seed=7, scale=0.05):
    acfg = AdapterConfig(kind="shira", mask="rand", sparsity=0.98,
                         target_modules=TARGETS)
    packs = []
    for i in range(n):
        sub = jax.random.fold_in(jax.random.PRNGKey(seed), i)
        values, aux = core.init_adapter(sub, params, acfg)
        values = jax.tree.map(
            lambda v: None if v is None
            else scale * jax.random.normal(sub, v.shape), values,
            is_leaf=lambda x: x is None)
        packs.append(core.pack_from_shira(f"a{i}", values, aux))
    return packs


def sequential_reference(cfg, params, packs, toks, names, tokens):
    out, logits, _ = switch_per_request_reference(cfg, params, packs, toks,
                                                  names, tokens)
    return out, logits


@pytest.fixture(scope="module")
def dense_setup():
    with layers.compute_precision(jnp.float32):
        cfg = get_smoke_config("starcoder2-7b")
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        packs = make_packs(cfg, params, 3)
        yield cfg, params, packs


def test_batched_matches_sequential_switching(dense_setup):
    """≥3 distinct adapters + base traffic in ONE batch must reproduce the
    sequential switch-per-request outputs (greedy tokens + fp32 logits)."""
    with layers.compute_precision(jnp.float32):
        cfg, params, packs = dense_setup
        engine = MultiTenantEngine(cfg, params)
        for p in packs:
            engine.register(p)
        B, S, T = 5, 8, 4
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                  cfg.vocab_size)
        names = ["a0", "a2", None, "a1", "a0"]
        assert len({n for n in names if n}) >= 3
        out_mt, _ = engine.generate({"tokens": toks}, names, T)
        out_seq, logits_seq = sequential_reference(
            cfg, params, packs, np.asarray(toks), names, T)
        np.testing.assert_array_equal(np.asarray(out_mt), out_seq)

        # logits parity at the last step, batched path
        from repro.serving.multitenant import greedy_decode
        ids = engine.ids_for(names)
        p = engine.wrapped_params(ids)
        _, logits = greedy_decode(
            cfg, {"tokens": toks}, T,
            lambda b: engine._prefill(p, b, S + T + 8),
            lambda t, c, pos: engine._decode(p, t, c, pos))
        np.testing.assert_allclose(np.asarray(logits, np.float32),
                                   logits_seq, atol=1e-3)


def test_multitenant_mamba_arch():
    """The scan-sliced side-delta bundle must also work for ssm stacks
    (out_proj adapters ride inside the mamba mixer)."""
    with layers.compute_precision(jnp.float32):
        cfg = get_smoke_config("mamba2-780m")
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        packs = make_packs(cfg, params, 3)
        engine = MultiTenantEngine(cfg, params)
        for p in packs:
            engine.register(p)
        B, S, T = 4, 8, 3
        toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                  cfg.vocab_size)
        names = ["a0", "a1", "a2", None]
        out_mt, _ = engine.generate({"tokens": toks}, names, T)
        out_seq, _ = sequential_reference(cfg, params, packs,
                                          np.asarray(toks), names, T)
        np.testing.assert_array_equal(np.asarray(out_mt), out_seq)


def test_multitenant_moe_mla_arch():
    """MoE shared experts consume flattened (B*S, d) tokens — the side-delta
    path must recover the request axis; MLA's w_dkv/wq projections ride the
    normal 3D path (w_uk/w_uv stay excluded)."""
    with layers.compute_precision(jnp.float32):
        cfg = get_smoke_config("deepseek-v2-lite-16b")
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        targets = ("wq", "wq_a", "wq_b", "wo", "w_up", "w_gate", "w_down",
                   "w_dkv")
        acfg = AdapterConfig(kind="shira", mask="rand", sparsity=0.98,
                             target_modules=targets)
        packs = []
        for i in range(3):
            sub = jax.random.fold_in(jax.random.PRNGKey(9), i)
            values, aux = core.init_adapter(sub, params, acfg)
            values = jax.tree.map(
                lambda v: None if v is None
                else 0.05 * jax.random.normal(sub, v.shape), values,
                is_leaf=lambda x: x is None)
            packs.append(core.pack_from_shira(f"a{i}", values, aux))
        engine = MultiTenantEngine(cfg, params)
        for p in packs:
            engine.register(p)
        B, S, T = 4, 8, 3
        toks = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0,
                                  cfg.vocab_size)
        names = ["a0", "a1", "a2", None]
        out_mt, _ = engine.generate({"tokens": toks}, names, T)
        out_seq, _ = sequential_reference(cfg, params, packs,
                                          np.asarray(toks), names, T)
        np.testing.assert_array_equal(np.asarray(out_mt), out_seq)


def test_scheduler_promotion_preserves_outputs(dense_setup):
    """Fusing the hot adapter into the shared base (and serving the others
    with diff packs) must not change any tenant's output."""
    with layers.compute_precision(jnp.float32):
        cfg, params, packs = dense_setup
        plain = MultiTenantEngine(cfg, params)
        sched = MultiTenantEngine(
            cfg, params, scheduler=FusedLRU(promote_at=0.5, decay=0.0))
        for p in packs:
            plain.register(p)
            sched.register(p)
        B, S, T = 4, 8, 3
        toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                                  cfg.vocab_size)
        names = ["a1", "a1", "a1", None]       # a1-dominated traffic
        want, _ = plain.generate({"tokens": toks}, names, T)
        got, _ = sched.generate({"tokens": toks}, names, T)
        assert sched.fused == "a1"
        assert sched.fuse_transitions == 1
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

        # traffic spreads out (nobody reaches promote_at) -> demotion
        # restores the un-fused base
        names2 = ["a0", "a2", None, None]
        want2, _ = plain.generate({"tokens": toks}, names2, T)
        got2, _ = sched.generate({"tokens": toks}, names2, T)
        assert sched.fused is None
        np.testing.assert_array_equal(np.asarray(got2), np.asarray(want2))
        for a, b in zip(jax.tree.leaves(sched.shared),
                        jax.tree.leaves(params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)


def test_reregister_fused_adapter_demotes_first(dense_setup):
    """Replacing the pack of the currently-fused adapter must un-fuse the
    OLD delta first, or the base is corrupted forever."""
    with layers.compute_precision(jnp.float32):
        cfg, params, packs = dense_setup
        engine = MultiTenantEngine(
            cfg, params, scheduler=FusedLRU(promote_at=0.5, decay=0.0))
        for p in packs:
            engine.register(p)
        B, S, T = 2, 8, 2
        toks = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0,
                                  cfg.vocab_size)
        engine.generate({"tokens": toks}, ["a0", "a0"], T)
        assert engine.fused == "a0"
        v2 = make_packs(cfg, params, 1, seed=33, scale=0.07)[0]  # new "a0"
        engine.register(v2)
        assert engine.fused is None            # old delta scattered back out
        for a, b in zip(jax.tree.leaves(engine.shared),
                        jax.tree.leaves(params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)
        # and the new pack really serves (parity vs sequential)
        out, _ = engine.generate({"tokens": toks}, ["a0", None], T)
        want, _ = sequential_reference(cfg, params, [v2] + packs[1:],
                                       np.asarray(toks), ["a0", None], T)
        np.testing.assert_array_equal(np.asarray(out), want)


def test_fused_lru_policy():
    s = FusedLRU(promote_at=0.5, demote_at=0.2, decay=0.0, max_idle=2)
    d = s.observe(["a", "a", "b", None])        # a at 50% -> promote
    assert d.promote == "a" and s.fused == "a"
    d = s.observe(["a", "a", "a", "a"])
    assert d.promote is None and d.demote is None and s.fused == "a"
    d = s.observe(["b", "b", "b", "b"])         # b hot -> swap fused state
    assert d.promote == "b" and d.demote == "a" and s.fused == "b"
    d = s.observe([None, None, None, None])     # b share crashes -> demote
    assert d.demote == "b" and s.fused is None
    # LRU/idle demotion: promote c, then starve it below demote_at=0 share
    s2 = FusedLRU(promote_at=0.5, demote_at=0.0, decay=1.0, max_idle=2)
    s2.share["c"] = 1.0
    s2.observe(["c", "c"])
    assert s2.fused == "c"
    s2.observe(["d"])
    d = s2.observe(["d"])                       # idle for max_idle steps
    assert d.demote == "c" and s2.fused is None


def test_fused_lru_tie_break_deterministic():
    """Equal shares must promote the lexicographically-first adapter, not
    whichever dict insertion order happens to yield (regression: promotion
    used to depend on the order tenants were first observed)."""
    for first_seen in (["b", "b", "a", "a"], ["a", "a", "b", "b"]):
        s = FusedLRU(promote_at=0.5, demote_at=0.2, decay=0.0)
        d = s.observe(first_seen)           # both at exactly 50%
        assert d.promote == "a", first_seen
        assert s.fused == "a"


def test_fused_lru_capacity_groups():
    """capacity=2 promotes a hot adapter *stack* as a group; capacity=1
    never does, even when the stack dominates traffic."""
    hot = [("a0", "a1")] * 3 + ["a2"]
    s1 = FusedLRU(promote_at=0.5, decay=0.0, capacity=1)
    d = s1.observe(hot)
    assert d.promote is None and s1.fused is None
    s2 = FusedLRU(promote_at=0.5, decay=0.0, capacity=2)
    d = s2.observe(hot)
    assert d.promote == ("a0", "a1") and s2.fused == ("a0", "a1")
    # normalization: member order within a stack does not split traffic
    s3 = FusedLRU(promote_at=0.5, decay=0.0, capacity=2)
    d = s3.observe([("a1", "a0"), ("a0", "a1"), ("a1", "a0"), "a2"])
    assert d.promote == ("a0", "a1")
    # demotion restores the un-fused state
    d = s2.observe(["a2", "a2", "a2", "a2"])
    assert d.demote == ("a0", "a1")


def test_group_fusion_preserves_outputs(dense_setup):
    """Fusing a hot STACK into the shared base (capacity=2) must not change
    any tenant's output: stack members, other adapters, and base traffic
    are all served off group-aware diff packs."""
    with layers.compute_precision(jnp.float32):
        cfg, params, packs = dense_setup
        plain = MultiTenantEngine(cfg, params)
        sched = MultiTenantEngine(
            cfg, params,
            scheduler=FusedLRU(promote_at=0.5, decay=0.0, capacity=2))
        for p in packs:
            plain.register(p)
            sched.register(p)
        B, S, T = 4, 8, 3
        toks = jax.random.randint(jax.random.PRNGKey(6), (B, S), 0,
                                  cfg.vocab_size)
        names = [("a0", "a1"), ("a1", "a0"), "a2", None]  # stack-dominated
        want, _ = plain.generate({"tokens": toks}, names, T)
        got, _ = sched.generate({"tokens": toks}, names, T)
        assert sched.fused == ("a0", "a1")
        assert sched.fuse_transitions == 1
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

        # spread traffic -> demote; the base must return to pristine
        names2 = ["a0", "a2", None, None]
        want2, _ = plain.generate({"tokens": toks}, names2, T)
        got2, _ = sched.generate({"tokens": toks}, names2, T)
        assert sched.fused is None
        np.testing.assert_array_equal(np.asarray(got2), np.asarray(want2))
        for a, b in zip(jax.tree.leaves(sched.shared),
                        jax.tree.leaves(params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)


def test_int8_device_tables_serving(dense_setup):
    """int8 device-side tables (store QuantPack -> engine tables with no
    f32 round trip) must reproduce the sequential reference served with
    the dequantized packs: same greedy tokens, logits within 1e-2. The
    int8 ``vals`` tables must be >=3x smaller than the f32 ones (int8 vs
    f32 values) and the whole table set >=2x smaller (int16 indices)."""
    import tempfile

    from repro.hub import AdapterStore
    with layers.compute_precision(jnp.float32):
        cfg, params, packs = dense_setup
        store = AdapterStore(tempfile.mkdtemp(prefix="mt-int8-"))
        for p in packs:
            store.add(p, values="int8")
        eng8 = MultiTenantEngine(cfg, params, store=store,
                                 table_dtype="int8")
        engf = MultiTenantEngine(cfg, params, store=store,
                                 table_dtype="f32")
        for p in packs:
            eng8.register(p.name)
            engf.register(p.name)
        # the quantized resident form reached the engine un-dequantized
        assert set(eng8._qpacks) == {p.name for p in packs}
        B, S, T = 5, 8, 4
        toks = jax.random.randint(jax.random.PRNGKey(8), (B, S), 0,
                                  cfg.vocab_size)
        names = ["a0", "a2", None, "a1", "a0"]
        out8, _ = eng8.generate({"tokens": toks}, names, T)
        dq = [store.get(p.name) for p in packs]   # what int8 really serves
        out_seq, logits_seq = sequential_reference(
            cfg, params, dq, np.asarray(toks), names, T)
        np.testing.assert_array_equal(np.asarray(out8), out_seq)

        from repro.serving.multitenant import greedy_decode
        ids = eng8.ids_for(names)
        p8 = eng8.wrapped_params(ids)
        _, logits8 = greedy_decode(
            cfg, {"tokens": toks}, T,
            lambda b: eng8._prefill(p8, b, S + T + 8),
            lambda t, c, pos: eng8._decode(p8, t, c, pos))
        np.testing.assert_allclose(np.asarray(logits8, np.float32),
                                   logits_seq, atol=1e-2)

        nb8, nbf = eng8.table_nbytes(), engf.table_nbytes()
        assert nbf["vals"] >= 3 * nb8["vals"], (nbf, nb8)
        assert nbf["total"] >= 2 * nb8["total"], (nbf, nb8)
        # int8 tables really are int8/int16 on device
        t = next(iter(eng8._tables.values()))
        assert t["vals"].dtype == jnp.int8
        assert t["rows"].dtype == jnp.int16
        assert "scale" in t


def test_int8_tables_skip_f32_roundtrip(dense_setup):
    """An adapter registered from an int8 store must land in the device
    tables with its ORIGINAL quantized values — one rounding at pack time,
    not a second quantization of the dequantized f32 form."""
    import tempfile

    from repro.hub import AdapterStore
    cfg, params, packs = dense_setup
    store = AdapterStore(tempfile.mkdtemp(prefix="mt-rt-"))
    store.add(packs[0], values="int8")
    engine = MultiTenantEngine(cfg, params, store=store, table_dtype="int8")
    engine.register(packs[0].name)
    engine._rebuild()
    qp = store.get_raw(packs[0].name)
    qtables = qp.int8_tables()
    path = next(iter(qtables))
    idx, vq, scale = qtables[path]
    t = engine._tables[path]
    k = idx.shape[-1]
    vals_dev = np.asarray(t["vals"]).reshape(-1, 1, t["vals"].shape[-1])
    np.testing.assert_array_equal(vals_dev[:, 0, :k],
                                  np.asarray(vq).reshape(vals_dev.shape[0],
                                                         -1))
    np.testing.assert_allclose(
        np.asarray(t["scale"]).reshape(-1)[0], scale * qp.alpha)


def test_forced_compiled_mode_on_cpu(dense_setup):
    """interpret=False threaded through the engine -> pdot -> kernel must
    serve correctly under JAX_PLATFORMS=cpu (the compiled tile-plan
    dispatch) and match the default interpret-mode engine exactly."""
    with layers.compute_precision(jnp.float32):
        cfg, params, packs = dense_setup
        default = MultiTenantEngine(cfg, params)
        compiled = MultiTenantEngine(cfg, params, interpret=False)
        for p in packs:
            default.register(p)
            compiled.register(p)
        B, S, T = 4, 8, 3
        toks = jax.random.randint(jax.random.PRNGKey(9), (B, S), 0,
                                  cfg.vocab_size)
        names = ["a0", "a1", "a2", None]
        want, _ = default.generate({"tokens": toks}, names, T)
        got, _ = compiled.generate({"tokens": toks}, names, T)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sidedelta_backend_context():
    """layers.sidedelta_backend must set the trace-time flag and restore
    the previous value on exit (incl. the auto default off-TPU)."""
    assert layers.sidedelta_interpret() == (jax.default_backend() != "tpu")
    with layers.sidedelta_backend(False):
        assert layers.sidedelta_interpret() is False
        with layers.sidedelta_backend(True):
            assert layers.sidedelta_interpret() is True
        assert layers.sidedelta_interpret() is False
    assert layers.sidedelta_interpret() == (jax.default_backend() != "tpu")


def test_unsupported_target_rejected():
    cfg = get_smoke_config("starcoder2-7b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    engine = MultiTenantEngine(cfg, params)
    bad = AdapterPack("bad", {"stages/0/attn/w_uk": (
        jnp.zeros((2, 4), jnp.int32), jnp.zeros((2, 4), jnp.float32))})
    with pytest.raises(ValueError, match="w_uk"):
        engine.register(bad)
    unknown = AdapterPack("unknown", {"no/such/wq": (
        jnp.zeros((2, 4), jnp.int32), jnp.zeros((2, 4), jnp.float32))})
    with pytest.raises(KeyError):
        engine.register(unknown)
