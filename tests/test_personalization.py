"""The personalization loop's serving half: versioned publish + hot-swap.

Acceptance bars pinned here:

  * ``AdapterStore.publish`` assigns monotonically increasing ``name@v``
    ids; bare names resolve newest-wins, concrete ids resolve to
    themselves; ``pin_use`` refcounts make a version eviction-proof.
  * ``CheckpointManager.save_adapter``/``restore_adapter`` round-trip a
    pack bit-exactly, and keep-K GC prunes per-step ``adapter_*.shpk``
    artifacts — including orphaned uncommitted step dirs from a save
    preempted between ``save_adapter`` and ``save``.
  * Live hot-swap under load: a publish mid-stream moves NEW submissions
    to the new version while in-flight requests finish on the old one
    with zero token divergence; the superseded version is evicted from
    the engine tables and the store's resident tier only after its last
    request drains.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.adapters import AdapterPack
from repro.core.switching import split_version, versioned_id
from repro.hub import AdapterStore, PagedServingEngine, ServingEngine
from repro.models import lm

from test_hub import synth_pack
from test_multitenant import make_packs


# ---------------------------------------------------------------------------
# Versioned ids + store publish/resolve
# ---------------------------------------------------------------------------

def test_split_version():
    assert split_version("p@3") == ("p", 3)
    assert split_version("p") == ("p", None)
    assert split_version("p@x") == ("p@x", None)
    assert split_version("a@b@2") == ("a@b", 2)
    assert split_version("@2") == ("@2", None)
    assert versioned_id("p", 4) == "p@4"


def test_store_publish_and_resolve(tmp_path):
    store = AdapterStore(str(tmp_path / "s"))
    assert store.resolve("p") == "p"          # unpublished: identity
    v1 = store.publish(synth_pack(name="p", seed=0))
    v2 = store.publish(synth_pack(name="p", seed=1))
    assert (v1, v2) == ("p@1", "p@2")
    assert store.resolve("p") == "p@2"        # newest wins
    assert store.resolve("p@1") == "p@1"      # concrete ids are sticky
    assert store.latest_version("p") == 2
    assert store.versions("p") == ["p@1", "p@2"]
    assert "p" in store and "p@1" in store and "p@3" not in store
    # bare-name lookups land on the newest version's values
    np.testing.assert_array_equal(
        np.asarray(store.get("p").entries["embed/emb"][1]),
        np.asarray(store.get("p@2").entries["embed/emb"][1]))
    # publishing a pack whose name is already versioned strips the suffix
    v3 = store.publish(synth_pack(name="p@1", seed=2))
    assert v3 == "p@3"
    store.shutdown()


def test_store_pin_use_blocks_eviction(tmp_path):
    store = AdapterStore(str(tmp_path / "s"))
    store.publish(synth_pack(name="p", seed=0))
    store.get("p")                            # make the pack resident
    pinned = store.pin_use("p")               # resolves before pinning
    assert pinned == "p@1"
    assert not store.evict("p@1")             # refused while pinned
    store.unpin_use(pinned)
    assert store.evict("p@1")
    assert not store.is_resident("p@1")
    # the file stays registered: lookups reload from disk
    assert store.get("p").name == "p@1"
    store.shutdown()


def test_store_register_file_notes_versions(tmp_path):
    from repro.hub.packio import save_pack
    path = save_pack(synth_pack(name="q@5", seed=3), str(tmp_path / "q5.shpk"))
    store = AdapterStore(str(tmp_path / "s"))
    store.register_file(path)
    assert store.latest_version("q") == 5
    assert store.resolve("q") == "q@5"
    assert store.publish(synth_pack(name="q", seed=4)) == "q@6"
    store.shutdown()


# ---------------------------------------------------------------------------
# Checkpointed adapter artifacts
# ---------------------------------------------------------------------------

def test_checkpoint_adapter_roundtrip(tmp_path):
    from repro.checkpoint import CheckpointManager
    ckpt = CheckpointManager(str(tmp_path / "ck"), keep=2)
    pack = synth_pack(name="p@1", seed=0)
    ckpt.save_adapter(7, pack)
    ckpt.save(7, {"state": {"x": np.arange(3.0)}})
    assert ckpt.adapters(7) == ["p@1"]
    back = ckpt.restore_adapter("p@1")        # latest committed step
    assert back.name == "p@1" and back.alpha == pack.alpha
    for path in pack.entries:
        np.testing.assert_array_equal(np.asarray(back.entries[path][0]),
                                      np.asarray(pack.entries[path][0]))
        np.testing.assert_array_equal(np.asarray(back.entries[path][1]),
                                      np.asarray(pack.entries[path][1]))
    # int8 values survive the round trip within a quantum; the gap-stream
    # encoding re-sorts indices, so compare scatter (dense) forms
    from test_hub import dense_of
    ckpt.save_adapter(8, pack, values="int8")
    ckpt.save(8, {"state": {"x": np.arange(3.0)}})
    q = ckpt.restore_adapter("p@1", step=8)
    assert q.alpha == pack.alpha
    for path in pack.entries:
        want = dense_of(pack, path)
        tol = float(np.abs(np.asarray(pack.entries[path][1])).max()) / 127
        np.testing.assert_allclose(dense_of(q, path), want, atol=tol)


def test_checkpoint_gc_covers_adapter_artifacts(tmp_path):
    import os
    from repro.checkpoint import CheckpointManager
    ckpt = CheckpointManager(str(tmp_path / "ck"), keep=2)
    # an orphan: save_adapter ran, the committing save was preempted
    ckpt.save_adapter(0, synth_pack(name="orphan@1", seed=9))
    for s in (1, 2, 3, 4):
        ckpt.save_adapter(s, synth_pack(name=f"p@{s}", seed=s))
        ckpt.save(s, {"state": {"x": np.arange(3.0)}})
    assert ckpt.steps() == [3, 4]
    assert ckpt.adapters(3) == ["p@3"] and ckpt.adapters(4) == ["p@4"]
    # pruned: committed steps past keep AND the stale uncommitted orphan
    dirs = sorted(d for d in os.listdir(ckpt.root) if d.startswith("step_"))
    assert dirs == ["step_00000003", "step_00000004"]
    with pytest.raises(FileNotFoundError):
        ckpt.restore_adapter("p@1", step=1)


# ---------------------------------------------------------------------------
# Hot-swap under load
# ---------------------------------------------------------------------------

ENGINES = [
    pytest.param(ServingEngine, dict(cache_size=64), id="lane"),
    pytest.param(PagedServingEngine, dict(num_pages=32, page_size=8),
                 id="paged", marks=pytest.mark.slow),
]


def _setup(tmp_path):
    cfg = get_smoke_config("starcoder2-7b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    p1, p2 = make_packs(cfg, params, 2, seed=7)
    v1 = AdapterPack("p", p1.entries, p1.alpha)
    v2 = AdapterPack("p", p2.entries, p2.alpha)
    rng = np.random.default_rng(0)
    t1 = rng.integers(1, cfg.vocab_size, (6,))
    t2 = rng.integers(1, cfg.vocab_size, (5,))
    return cfg, params, v1, v2, t1, t2


def _ref_tokens(Engine, cfg, params, pack, toks, n, tmp, **kw):
    """Single-version reference: what a request on this pack alone emits."""
    store = AdapterStore(str(tmp))
    store.publish(pack)
    eng = Engine(cfg, params, slots=2, store=store, **kw)
    f = eng.submit(toks, "p", max_tokens=n)
    eng.run()
    eng.shutdown(include_store=True)
    return list(f.tokens)


@pytest.mark.parametrize("Engine,kw", ENGINES)
def test_hot_swap_under_load(tmp_path, Engine, kw):
    cfg, params, v1, v2, t1, t2 = _setup(tmp_path)
    r1 = _ref_tokens(Engine, cfg, params, v1, t1, 12, tmp_path / "r1", **kw)
    r2 = _ref_tokens(Engine, cfg, params, v2, t2, 8, tmp_path / "r2", **kw)

    store = AdapterStore(str(tmp_path / "live"))
    assert store.publish(v1) == "p@1"
    eng = Engine(cfg, params, slots=2, store=store, **kw)
    f1 = eng.submit(t1, "p", max_tokens=12)
    assert f1.adapter == "p@1"
    for _ in range(4):
        eng.step()
    assert not f1.done()

    # publish v2 mid-stream: new submissions land on it, f1 stays pinned
    assert store.publish(v2) == "p@2"
    f2 = eng.submit(t2, "p", max_tokens=8)
    assert f2.adapter == "p@2"
    eng.step()
    assert "p@1" in eng.engine.packs         # pinned by in-flight f1
    assert eng._vpins.get("p@1", 0) == 1

    eng.run()
    assert list(f1.tokens) == r1             # zero divergence through swap
    assert list(f2.tokens) == r2
    # drained: the superseded version is retired everywhere
    assert "p@1" not in eng.engine.packs and "p@2" in eng.engine.packs
    assert not store.is_resident("p@1")
    assert eng._vpins == {}

    # explicit old ids still work (reload from the store's file tier)...
    f3 = eng.submit(t1, "p@1", max_tokens=12)
    assert f3.adapter == "p@1"
    # ...and a queued request that is cancelled releases its pin
    f4 = eng.submit(t2, "p", max_tokens=8)
    f5 = eng.submit(t2, "p", max_tokens=8)   # 2 slots: f5 queues
    assert eng.cancel(f5) and f5.cancelled
    eng.run()
    assert list(f3.tokens) == r1
    assert list(f4.tokens) == r2
    assert eng._vpins == {}
    assert "p@1" not in eng.engine.packs     # re-evicted after f3 drained
    eng.shutdown(include_store=True)


def test_multitenant_unregister_and_resolve(tmp_path):
    from repro.serving import MultiTenantEngine
    cfg = get_smoke_config("starcoder2-7b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    packs = make_packs(cfg, params, 2, seed=7)
    store = AdapterStore(str(tmp_path / "s"))
    vid = store.publish(AdapterPack("p", packs[0].entries, packs[0].alpha))
    eng = MultiTenantEngine(cfg, params, store=store)
    assert eng.resolve("p") == vid == "p@1"
    assert eng.resolve(("p", "q")) == ("p@1", "q")
    assert eng.resolve(None) is None
    for pk in packs:
        eng.register(pk)
    rng = np.random.default_rng(1)
    toks = rng.integers(1, cfg.vocab_size, (2, 5))
    out0, _ = eng.generate({"tokens": np.asarray(toks)}, ["a0", "a1"], 4)
    assert eng.unregister("a0")
    assert not eng.unregister("a0")          # already gone
    assert "a0" not in eng.packs
    with pytest.raises(KeyError):
        eng.ids_for(["a0"])
    # the survivor still serves, token-identical to its pre-removal output
    # (row 1: same prompt, same adapter, before vs after the removal)
    out1, _ = eng.generate({"tokens": np.asarray(toks)}, ["a1", "a1"], 4)
    np.testing.assert_array_equal(np.asarray(out1[1]), np.asarray(out0[1]))
    eng.shutdown()
