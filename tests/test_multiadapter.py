"""MultiAdapterTrainer: A concurrent finetunes in one jitted step.

Acceptance bars pinned here:

  * Sequential equivalence — adapter ``a`` of a 3-adapter concurrent run
    tracks its single-adapter ``Trainer`` twin (same init key, same task
    stream, same base) step for step under f32 compute precision.
  * Quantized optimizer moments — the fused kernel's inline dequant
    matches the pure-jnp reference exactly, and int8/bf16 moment storage
    stays within the documented tolerance of the f32 oracle.
  * The batched fused kernel IS the single-adapter AdamW math (unit
    parity against ``optim.adamw_update``'s update rule).
  * ``multi_batch_iterator`` row blocks are bit-identical to the
    per-task single streams (what the equivalence contract rides on).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import AdapterConfig, RunConfig, TrainConfig, get_smoke_config
from repro.configs.base import ShapeSpec
from repro.data import TaskSpec, batch_iterator
from repro.models import layers
from repro.runtime import Trainer
from repro.runtime.trainer import TrainerConfig
from repro.training import MultiAdapterTrainer, multi_batch_iterator, qstate

SHAPE = ShapeSpec("tiny", 8, 8, "train")


def mk_run(steps=6, lr=1e-2, sparsity=0.95):
    return RunConfig(model=get_smoke_config("starcoder2-7b"), shape=SHAPE,
                     adapter=AdapterConfig(kind="shira", mask="rand",
                                           sparsity=sparsity),
                     train=TrainConfig(learning_rate=lr, total_steps=steps,
                                       warmup_steps=2))


# ---------------------------------------------------------------------------
# Data routing
# ---------------------------------------------------------------------------

def test_multi_batch_iterator_blocks_match_single_streams():
    run = mk_run()
    A, n = 3, SHAPE.global_batch
    tasks = [TaskSpec(a) for a in range(A)]
    multi = multi_batch_iterator(run.model, SHAPE, 0, tasks)
    singles = [batch_iterator(run.model, SHAPE, seed=0, task=t)
               for t in tasks]
    for _ in range(3):
        mb = next(multi)
        np.testing.assert_array_equal(
            mb["ids"], np.repeat(np.arange(A), n))
        for a, it in enumerate(singles):
            sb = next(it)
            for k in sb:
                np.testing.assert_array_equal(mb[k][a * n:(a + 1) * n], sb[k])


# ---------------------------------------------------------------------------
# Sequential equivalence (the tentpole contract)
# ---------------------------------------------------------------------------

def test_three_adapter_parity_vs_sequential_trainers():
    """Adapter a of the concurrent run == Trainer(init_key=a) on task a,
    step for step. f32 compute precision isolates the math from bf16
    matmul noise; tolerance covers float summation-order differences."""
    steps, A = 6, 3
    run = mk_run(steps=steps)
    with layers.compute_precision(jnp.float32):
        mt = MultiAdapterTrainer(run, [f"a{a}" for a in range(A)],
                                 init_key=0)
        out = mt.fit(steps, log=None)
        for a in range(A):
            tr = Trainer(run, TrainerConfig(), init_key=a,
                         base_params=mt.base)
            ref = tr.fit(steps, log=None, batches=batch_iterator(
                run.model, SHAPE, seed=run.train.seed, task=TaskSpec(a)))
            np.testing.assert_allclose(
                [h[f"loss:a{a}"] for h in out["history"]],
                [h["loss"] for h in ref["history"]],
                rtol=5e-3, atol=5e-3)
            # final packed values agree leaf for leaf
            pack = mt.export_packs(out["state"])[a]
            ref_pack = tr.export_pack(ref["state"], name=f"a{a}")
            assert set(pack.entries) == set(ref_pack.entries)
            for path in pack.entries:
                np.testing.assert_allclose(
                    np.asarray(pack.entries[path][1]),
                    np.asarray(ref_pack.entries[path][1]),
                    rtol=5e-3, atol=5e-3)
        # the concurrent run actually learns
        assert out["history"][-1]["loss"] < out["history"][0]["loss"]


# ---------------------------------------------------------------------------
# Quantized optimizer state
# ---------------------------------------------------------------------------

def _final_values(run, steps, moments, fused):
    with layers.compute_precision(jnp.float32):
        mt = MultiAdapterTrainer(run, ["a0", "a1"], init_key=0,
                                 moments=moments, fused=fused)
        out = mt.fit(steps, log=None)
    flat = [np.asarray(v) for v in jax.tree.leaves(out["state"]["values"])]
    return np.concatenate([v.ravel() for v in flat])


def test_quantized_moments_fused_matches_reference_and_oracle():
    """Documented tolerances (see training/README.md): fused == reference
    bit-tight per mode; int8 within 2e-2 and bf16 within 1e-2 of the f32
    oracle after 8 tiny-scale steps."""
    steps = 8
    run = mk_run(steps=steps)
    oracle = _final_values(run, steps, "f32", fused=True)
    ref_f32 = _final_values(run, steps, "f32", fused=False)
    np.testing.assert_allclose(oracle, ref_f32, rtol=1e-5, atol=1e-6)
    # Quantized modes: single-STEP fused == reference is pinned by the
    # kernel unit tests below; end-to-end trajectories may differ by
    # amplified rint-boundary flips, so the multi-step contract is
    # closeness to the f32 oracle for BOTH implementations.
    for mode, tol in [("int8", 2e-2), ("bf16", 1e-2)]:
        fused = _final_values(run, steps, mode, fused=True)
        ref = _final_values(run, steps, mode, fused=False)
        np.testing.assert_allclose(fused, oracle, rtol=tol, atol=tol)
        np.testing.assert_allclose(ref, oracle, rtol=tol, atol=tol)


def test_qstate_roundtrip_and_bytes():
    rng = np.random.default_rng(0)
    m = jnp.asarray(rng.standard_normal((5, 64)), jnp.float32) * 1e-3
    nu = jnp.square(m)
    # f32 is the identity; bf16 keeps 8 mantissa bits
    st, sc = qstate.encode(m, "f32")
    assert st is m and sc is None
    st, sc = qstate.encode(m, "bf16")
    assert st.dtype == jnp.bfloat16 and sc is None
    np.testing.assert_allclose(np.asarray(qstate.decode(st, sc, "bf16")),
                               np.asarray(m), rtol=1 / 128, atol=0)
    # int8: the symmetric-quantization guarantee is abs error <= half a
    # quantum (scale/2) per row, NOT a relative bound
    st, sc = qstate.encode(m, "int8")
    assert st.dtype == jnp.int8
    err = np.abs(np.asarray(qstate.decode(st, sc, "int8")) - np.asarray(m))
    assert np.all(err <= np.asarray(sc)[:, None] * 0.5 + 1e-12)
    # nu rides the sqrt domain: sqrt(decoded) is within half a quantum of
    # sqrt(nu) = |m|
    st, sc = qstate.encode(nu, "int8", sqrt_domain=True)
    back = np.asarray(qstate.decode(st, sc, "int8", sqrt_domain=True))
    assert np.all(back >= 0)
    err = np.abs(np.sqrt(back) - np.abs(np.asarray(m)))
    assert np.all(err <= np.asarray(sc)[:, None] * 0.5 + 1e-12)
    # all-zero rows decode to exact zeros under int8
    z, s = qstate.encode(jnp.zeros((3, 8)), "int8")
    assert np.all(np.asarray(s) == 1.0)
    assert np.all(np.asarray(qstate.decode(z, s, "int8")) == 0.0)
    # the memory claim the benchmark gates: int8 ~4x under f32
    assert qstate.moment_bytes_per_value("f32", 40) == 8.0
    assert qstate.moment_bytes_per_value("int8", 40) < 8.0 / 3


# ---------------------------------------------------------------------------
# Fused kernel unit parity
# ---------------------------------------------------------------------------

def test_sparse_adamw_batched_matches_adamw_math():
    """One kernel launch over (R, K) rows == the reference AdamW update
    rule applied row-wise (bias correction from the 1-based step)."""
    from repro.kernels import ops
    rng = np.random.default_rng(1)
    R, K = 6, 96                      # K not a multiple of block: pads
    v = jnp.asarray(rng.standard_normal((R, K)), jnp.float32)
    g = jnp.asarray(rng.standard_normal((R, K)), jnp.float32)
    m = jnp.asarray(rng.standard_normal((R, K)), jnp.float32) * 0.1
    u = jnp.asarray(np.abs(rng.standard_normal((R, K))), jnp.float32) * 0.01
    lr, b1, b2, eps, wd = 1e-2, 0.9, 0.999, 1e-8, 0.1
    for step in (1, 7):
        v2, m2, u2 = ops.sparse_adamw_batched(
            v, g, m, u, jnp.int32(step), lr=jnp.float32(lr), b1=b1, b2=b2,
            eps=eps, wd=wd, interpret=True)
        em = b1 * m + (1 - b1) * g
        eu = b2 * u + (1 - b2) * g * g
        mh = em / (1 - b1 ** step)
        uh = eu / (1 - b2 ** step)
        ev = v - lr * (mh / (jnp.sqrt(uh) + eps) + wd * v)
        np.testing.assert_allclose(np.asarray(v2), np.asarray(ev),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(m2), np.asarray(em),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(u2), np.asarray(eu),
                                   rtol=1e-6, atol=1e-6)


def test_sparse_adamw_batched_int8_dequant_in_kernel():
    """The kernel's inline int8 dequant == decode-then-update reference."""
    from repro.kernels import ops
    rng = np.random.default_rng(2)
    R, K = 4, 64
    v = jnp.asarray(rng.standard_normal((R, K)), jnp.float32)
    g = jnp.asarray(rng.standard_normal((R, K)), jnp.float32)
    mf = jnp.asarray(rng.standard_normal((R, K)), jnp.float32) * 0.1
    uf = jnp.asarray(np.abs(rng.standard_normal((R, K))), jnp.float32) * 0.01
    mq, ms = qstate.encode(mf, "int8")
    uq, us = qstate.encode(uf, "int8", sqrt_domain=True)
    v2, m2, u2 = ops.sparse_adamw_batched(
        v, g, mq, uq, jnp.int32(3), lr=jnp.float32(1e-2),
        mu_scale=ms, nu_scale=us, interpret=True)
    md = qstate.decode(mq, ms, "int8")
    ud = qstate.decode(uq, us, "int8", sqrt_domain=True)
    r2, rm, ru = ops.sparse_adamw_batched(
        v, g, md, ud, jnp.int32(3), lr=jnp.float32(1e-2), interpret=True)
    np.testing.assert_allclose(np.asarray(v2), np.asarray(r2),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(m2), np.asarray(rm),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(u2), np.asarray(ru),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Guardrails
# ---------------------------------------------------------------------------

def test_rejects_non_packed_adapters():
    run = mk_run()
    bad = RunConfig(model=run.model, shape=SHAPE,
                    adapter=AdapterConfig(kind="lora", rank=4),
                    train=run.train)
    with pytest.raises(ValueError, match="packed-SHiRA"):
        MultiAdapterTrainer(bad, ["a0"])
    with pytest.raises(ValueError, match="moments"):
        MultiAdapterTrainer(run, ["a0"], moments="fp4")
