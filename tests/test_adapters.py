"""SHiRA core: masks, adapters, switching, fusion (paper §3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.configs import AdapterConfig, get_smoke_config
from repro.core import masks as M
from repro.models import lm


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("starcoder2-7b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((2, 32), jnp.int32),
             "labels": jnp.ones((2, 32), jnp.int32)}
    return cfg, params, batch


MASKS = ["struct", "rand", "wm"]


@pytest.mark.parametrize("mask", MASKS)
def test_mask_sparsity_and_exact_budget(setup, mask):
    cfg, params, _ = setup
    acfg = AdapterConfig(kind="shira", mask=mask, sparsity=0.95)
    idx = core.make_packed_indices(params, acfg, jax.random.PRNGKey(1))
    for p, leaf in jax.tree_util.tree_flatten_with_path(
            idx, is_leaf=lambda x: x is None)[0]:
        if leaf is None:
            continue
        *lead, k = leaf.shape
        flat = np.asarray(leaf).reshape(-1, k)
        for row in flat:
            assert len(np.unique(row)) == k, "duplicate indices in mask"
        if mask != "struct":
            # exact per-matrix budget
            w = None
            assert k >= 1


def test_grad_and_snip_masks_need_grads(setup):
    cfg, params, batch = setup
    acfg = AdapterConfig(kind="shira", mask="snip", sparsity=0.95)
    with pytest.raises(ValueError):
        core.make_packed_indices(params, acfg, jax.random.PRNGKey(0))
    grads = jax.grad(lambda p: lm.train_loss(p, cfg, batch)[0])(params)
    idx = core.make_packed_indices(params, acfg, jax.random.PRNGKey(0),
                                   grads)
    assert any(l is not None for l in jax.tree.leaves(
        idx, is_leaf=lambda x: x is None))


def test_dense_mask_matches_packed(setup):
    cfg, params, _ = setup
    acfg = AdapterConfig(kind="shira", mask="wm", sparsity=0.9)
    key = jax.random.PRNGKey(3)
    idx = core.make_packed_indices(params, acfg, key)
    dm = core.make_dense_masks(params, acfg, key)
    for (pi, i), (pm, m) in zip(
            jax.tree_util.tree_flatten_with_path(idx, is_leaf=lambda x: x is None)[0],
            jax.tree_util.tree_flatten_with_path(dm, is_leaf=lambda x: x is None)[0]):
        if i is None:
            assert m is None
            continue
        *lead, k = i.shape
        assert float(jnp.sum(m)) == np.prod(lead or [1]) * k


def test_zero_init_is_identity_and_alpha_scales(setup):
    cfg, params, _ = setup
    acfg = AdapterConfig(kind="shira", mask="rand", sparsity=0.97)
    values, aux = core.init_adapter(jax.random.PRNGKey(0), params, acfg)
    eff0 = core.materialize(params, values, aux, acfg)
    for a, b in zip(jax.tree.leaves(eff0), jax.tree.leaves(params)):
        assert jnp.array_equal(a, b)
    vals = jax.tree.map(lambda v: v + 1.0, values)
    e1 = core.materialize(params, vals, aux, acfg, alpha=1.0)
    e2 = core.materialize(params, vals, aux, acfg, alpha=2.0)
    # alpha=2 delta is exactly twice alpha=1 delta (paper App. G)
    for w, a, b in zip(jax.tree.leaves(params), jax.tree.leaves(e1),
                       jax.tree.leaves(e2)):
        np.testing.assert_allclose(np.asarray(b - w), 2 * np.asarray(a - w),
                                   rtol=1e-5, atol=1e-6)


def test_pack_load_unload_roundtrip(setup):
    cfg, params, _ = setup
    acfg = AdapterConfig(kind="shira", mask="wm", sparsity=0.95)
    values, aux = core.init_adapter(jax.random.PRNGKey(0), params, acfg)
    values = jax.tree.map(lambda v: v + 0.05, values)
    pack = core.pack_from_shira("t", values, aux)
    eng = core.SwitchEngine(params)
    eng.load(pack)
    ch = core.switching.changed_fraction(params, eng.params)
    assert 0 < ch < 0.2, f"%C should be small, got {ch}"
    eng.unload()
    for a, b in zip(jax.tree.leaves(eng.params), jax.tree.leaves(params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_multi_adapter_fusion_equals_sequential(setup):
    cfg, params, _ = setup
    acfg = AdapterConfig(kind="shira", mask="rand", sparsity=0.97)
    packs = []
    for i in range(3):
        v, aux = core.init_adapter(jax.random.fold_in(jax.random.PRNGKey(5), i),
                                   params, acfg)
        v = jax.tree.map(lambda x: x + 0.01 * (i + 1), v)
        packs.append(core.pack_from_shira(f"p{i}", v, aux))
    seq = core.SwitchEngine(params)
    seq.load_fused(packs)
    fused = core.fuse_packs(packs)
    one = core.SwitchEngine(params)
    one.load(fused)
    for a, b in zip(jax.tree.leaves(seq.params), jax.tree.leaves(one.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_switch_engine_roundtrip_changed_fraction(setup):
    """load -> unload restores params within fp32 tolerance AND the paper's
    %C metric returns to ~0 (the rapid-switch invariant)."""
    cfg, params, _ = setup
    acfg = AdapterConfig(kind="shira", mask="rand", sparsity=0.98)
    values, aux = core.init_adapter(jax.random.PRNGKey(11), params, acfg)
    values = jax.tree.map(
        lambda v: None if v is None
        else 0.02 * jax.random.normal(jax.random.PRNGKey(12), v.shape),
        values, is_leaf=lambda x: x is None)
    pack = core.pack_from_shira("rt", values, aux)
    eng = core.SwitchEngine(params)
    eng.load(pack)
    ch_loaded = core.switching.changed_fraction(params, eng.params)
    assert ch_loaded > 0.001
    eng.unload()
    # %C back to ~0: only last-ulp residue of the float add/sub roundtrip
    # may remain (bitwise-differing but value-identical to 1e-6)
    ch_unloaded = core.switching.changed_fraction(params, eng.params)
    assert ch_unloaded < 0.2 * ch_loaded and ch_unloaded < 5e-3
    for a, b in zip(jax.tree.leaves(eng.params), jax.tree.leaves(params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_fuse_packs_merges_duplicate_coordinates(setup):
    """Two packs sharing coordinates (same mask) must merge by ADDITION in
    the fused pack: loading it == loading both sequentially."""
    cfg, params, _ = setup
    acfg = AdapterConfig(kind="shira", mask="wm", sparsity=0.97)
    v1, aux = core.init_adapter(jax.random.PRNGKey(6), params, acfg)
    # identical index sets (wm mask is deterministic), different values —
    # every coordinate is a duplicate between the two packs
    p1 = core.pack_from_shira("x", jax.tree.map(lambda v: v + 0.03, v1), aux,
                              alpha=1.0)
    p2 = core.pack_from_shira("y", jax.tree.map(lambda v: v - 0.01, v1), aux,
                              alpha=0.5)
    seq = core.SwitchEngine(params)
    seq.load(p1)
    seq.load(p2)
    fused = core.fuse_packs([p1, p2])
    # duplicate merging really happened: fused K == single-pack K
    for path, (idx, _) in fused.entries.items():
        assert idx.shape[-1] == p1.entries[path][0].shape[-1]
    one = core.SwitchEngine(params)
    one.load(fused)
    for a, b in zip(jax.tree.leaves(seq.params), jax.tree.leaves(one.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_fuse_packs_keeps_paths_unique_to_later_packs(setup):
    """A path covered only by the SECOND pack must survive fusion (diff
    packs for multi-tenant fused serving rely on this)."""
    cfg, params, _ = setup
    a_wq = AdapterConfig(kind="shira", mask="wm", sparsity=0.97,
                         target_modules=("wq",))
    a_wo = AdapterConfig(kind="shira", mask="wm", sparsity=0.97,
                         target_modules=("wo",))
    v1, x1 = core.init_adapter(jax.random.PRNGKey(21), params, a_wq)
    v2, x2 = core.init_adapter(jax.random.PRNGKey(22), params, a_wo)
    p1 = core.pack_from_shira("wq-only", jax.tree.map(lambda v: v + 0.1, v1),
                              x1)
    p2 = core.pack_from_shira("wo-only", jax.tree.map(lambda v: v - 0.2, v2),
                              x2)
    fused = core.fuse_packs([p1, p2], weights=[1.0, -1.0])
    assert set(fused.entries) == set(p1.entries) | set(p2.entries)
    seq = core.SwitchEngine(params)
    seq.load(p1)
    seq.load(core.adapters.AdapterPack(p2.name, p2.entries, alpha=-p2.alpha))
    one = core.SwitchEngine(params)
    one.load(fused)
    for a, b in zip(jax.tree.leaves(seq.params), jax.tree.leaves(one.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_overlap_lower_for_independent_rand_masks(setup):
    """§3.2: sparse masks ⇒ low interference. Random independent masks
    overlap ~(1-sparsity); LoRA-equivalent dense deltas overlap 100%."""
    cfg, params, _ = setup
    acfg = AdapterConfig(kind="shira", mask="rand", sparsity=0.97)
    v1, a1 = core.init_adapter(jax.random.PRNGKey(1), params, acfg)
    v2, a2 = core.init_adapter(jax.random.PRNGKey(2), params, acfg)
    p1 = core.pack_from_shira("a", jax.tree.map(lambda x: x + 1, v1), a1)
    p2 = core.pack_from_shira("b", jax.tree.map(lambda x: x + 1, v2), a2)
    ov = core.index_overlap(p1, p2)
    mean_ov = np.mean(list(ov.values()))
    assert mean_ov < 0.15, f"random 3% masks should barely overlap: {mean_ov}"


@pytest.mark.slow
@pytest.mark.parametrize("kind", ["lora", "dora", "shira-dora"])
def test_baseline_adapters_train_signal(setup, kind):
    cfg, params, batch = setup
    acfg = AdapterConfig(kind=kind, mask="wm", sparsity=0.95, rank=4)
    t, aux = core.init_adapter(jax.random.PRNGKey(0), params, acfg)

    def loss_fn(t):
        eff = core.materialize(params, t, aux, acfg)
        return lm.train_loss(eff, cfg, batch)[0]

    g = jax.grad(loss_fn)(t)
    gn = sum(float(jnp.sum(jnp.square(x))) for x in jax.tree.leaves(g))
    assert gn > 0, f"{kind}: no gradient signal"
    assert all(jnp.all(jnp.isfinite(x)) for x in jax.tree.leaves(g))


def test_shira_dora_changes_only_masked_entries(setup):
    cfg, params, _ = setup
    acfg = AdapterConfig(kind="shira-dora", mask="wm", sparsity=0.95, rank=4)
    t, aux = core.init_adapter(jax.random.PRNGKey(0), params, acfg)
    t = jax.tree.map(
        lambda x: x + 0.1 if isinstance(x, jnp.ndarray) else x, t)
    eff = core.materialize(params, t, aux, acfg)
    ch = core.switching.changed_fraction(params, eff)
    assert ch < 0.2, f"shira-dora must stay sparse in fused mode: %C={ch}"


def test_lora_engine_fuse_preserves_tuple_structure():
    """Regression: LoraEngine.fuse's tree walk returned a list for BOTH
    list and tuple nodes, corrupting the pytree structure of tuple-bearing
    param trees (jit caches and tree_maps then mismatch)."""
    params = {"stages": ({"wq": jnp.ones((4, 4))},
                         {"wq": jnp.ones((4, 4))}),
              "aux": [jnp.zeros((2, 2))]}
    lora = {"stages/0/wq": {"A": jnp.ones((4, 2)), "B": jnp.ones((2, 4))}}
    eng = core.LoraEngine(params)
    eng.fuse(lora, scale=0.5)
    assert (jax.tree_util.tree_structure(eng.params)
            == jax.tree_util.tree_structure(params))
    assert isinstance(eng.params["stages"], tuple)
    assert isinstance(eng.params["aux"], list)
    np.testing.assert_allclose(np.asarray(eng.params["stages"][0]["wq"]),
                               1.0 + 0.5 * 2.0)
    np.testing.assert_allclose(np.asarray(eng.params["stages"][1]["wq"]), 1.0)
    eng.unfuse()
    np.testing.assert_allclose(np.asarray(eng.params["stages"][0]["wq"]), 1.0)


def test_changed_fraction_single_jitted_reduction():
    """changed_fraction must stay correct after being batched into one
    jitted reduction (incl. mixed dtypes and tuple-bearing trees)."""
    base = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": (jnp.zeros((5,), jnp.int32), jnp.ones((2, 2), jnp.bfloat16))}
    switched = jax.tree.map(lambda x: x, base)
    assert core.switching.changed_fraction(base, switched) == 0.0
    switched = {"a": base["a"].at[0, 0].set(99.0),
                "b": (base["b"][0].at[2].set(7), base["b"][1])}
    got = core.switching.changed_fraction(base, switched)
    assert got == pytest.approx(2 / (12 + 5 + 4))
