"""SHiRA core: masks, adapters, switching, fusion (paper §3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.configs import AdapterConfig, get_smoke_config
from repro.core import masks as M
from repro.models import lm


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("starcoder2-7b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((2, 32), jnp.int32),
             "labels": jnp.ones((2, 32), jnp.int32)}
    return cfg, params, batch


MASKS = ["struct", "rand", "wm"]


@pytest.mark.parametrize("mask", MASKS)
def test_mask_sparsity_and_exact_budget(setup, mask):
    cfg, params, _ = setup
    acfg = AdapterConfig(kind="shira", mask=mask, sparsity=0.95)
    idx = core.make_packed_indices(params, acfg, jax.random.PRNGKey(1))
    for p, leaf in jax.tree_util.tree_flatten_with_path(
            idx, is_leaf=lambda x: x is None)[0]:
        if leaf is None:
            continue
        *lead, k = leaf.shape
        flat = np.asarray(leaf).reshape(-1, k)
        for row in flat:
            assert len(np.unique(row)) == k, "duplicate indices in mask"
        if mask != "struct":
            # exact per-matrix budget
            w = None
            assert k >= 1


def test_grad_and_snip_masks_need_grads(setup):
    cfg, params, batch = setup
    acfg = AdapterConfig(kind="shira", mask="snip", sparsity=0.95)
    with pytest.raises(ValueError):
        core.make_packed_indices(params, acfg, jax.random.PRNGKey(0))
    grads = jax.grad(lambda p: lm.train_loss(p, cfg, batch)[0])(params)
    idx = core.make_packed_indices(params, acfg, jax.random.PRNGKey(0),
                                   grads)
    assert any(l is not None for l in jax.tree.leaves(
        idx, is_leaf=lambda x: x is None))


def test_dense_mask_matches_packed(setup):
    cfg, params, _ = setup
    acfg = AdapterConfig(kind="shira", mask="wm", sparsity=0.9)
    key = jax.random.PRNGKey(3)
    idx = core.make_packed_indices(params, acfg, key)
    dm = core.make_dense_masks(params, acfg, key)
    for (pi, i), (pm, m) in zip(
            jax.tree_util.tree_flatten_with_path(idx, is_leaf=lambda x: x is None)[0],
            jax.tree_util.tree_flatten_with_path(dm, is_leaf=lambda x: x is None)[0]):
        if i is None:
            assert m is None
            continue
        *lead, k = i.shape
        assert float(jnp.sum(m)) == np.prod(lead or [1]) * k


def test_zero_init_is_identity_and_alpha_scales(setup):
    cfg, params, _ = setup
    acfg = AdapterConfig(kind="shira", mask="rand", sparsity=0.97)
    values, aux = core.init_adapter(jax.random.PRNGKey(0), params, acfg)
    eff0 = core.materialize(params, values, aux, acfg)
    for a, b in zip(jax.tree.leaves(eff0), jax.tree.leaves(params)):
        assert jnp.array_equal(a, b)
    vals = jax.tree.map(lambda v: v + 1.0, values)
    e1 = core.materialize(params, vals, aux, acfg, alpha=1.0)
    e2 = core.materialize(params, vals, aux, acfg, alpha=2.0)
    # alpha=2 delta is exactly twice alpha=1 delta (paper App. G)
    for w, a, b in zip(jax.tree.leaves(params), jax.tree.leaves(e1),
                       jax.tree.leaves(e2)):
        np.testing.assert_allclose(np.asarray(b - w), 2 * np.asarray(a - w),
                                   rtol=1e-5, atol=1e-6)


def test_pack_load_unload_roundtrip(setup):
    cfg, params, _ = setup
    acfg = AdapterConfig(kind="shira", mask="wm", sparsity=0.95)
    values, aux = core.init_adapter(jax.random.PRNGKey(0), params, acfg)
    values = jax.tree.map(lambda v: v + 0.05, values)
    pack = core.pack_from_shira("t", values, aux)
    eng = core.SwitchEngine(params)
    eng.load(pack)
    ch = core.switching.changed_fraction(params, eng.params)
    assert 0 < ch < 0.2, f"%C should be small, got {ch}"
    eng.unload()
    for a, b in zip(jax.tree.leaves(eng.params), jax.tree.leaves(params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_multi_adapter_fusion_equals_sequential(setup):
    cfg, params, _ = setup
    acfg = AdapterConfig(kind="shira", mask="rand", sparsity=0.97)
    packs = []
    for i in range(3):
        v, aux = core.init_adapter(jax.random.fold_in(jax.random.PRNGKey(5), i),
                                   params, acfg)
        v = jax.tree.map(lambda x: x + 0.01 * (i + 1), v)
        packs.append(core.pack_from_shira(f"p{i}", v, aux))
    seq = core.SwitchEngine(params)
    seq.load_fused(packs)
    fused = core.fuse_packs(packs)
    one = core.SwitchEngine(params)
    one.load(fused)
    for a, b in zip(jax.tree.leaves(seq.params), jax.tree.leaves(one.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_overlap_lower_for_independent_rand_masks(setup):
    """§3.2: sparse masks ⇒ low interference. Random independent masks
    overlap ~(1-sparsity); LoRA-equivalent dense deltas overlap 100%."""
    cfg, params, _ = setup
    acfg = AdapterConfig(kind="shira", mask="rand", sparsity=0.97)
    v1, a1 = core.init_adapter(jax.random.PRNGKey(1), params, acfg)
    v2, a2 = core.init_adapter(jax.random.PRNGKey(2), params, acfg)
    p1 = core.pack_from_shira("a", jax.tree.map(lambda x: x + 1, v1), a1)
    p2 = core.pack_from_shira("b", jax.tree.map(lambda x: x + 1, v2), a2)
    ov = core.index_overlap(p1, p2)
    mean_ov = np.mean(list(ov.values()))
    assert mean_ov < 0.15, f"random 3% masks should barely overlap: {mean_ov}"


@pytest.mark.parametrize("kind", ["lora", "dora", "shira-dora"])
def test_baseline_adapters_train_signal(setup, kind):
    cfg, params, batch = setup
    acfg = AdapterConfig(kind=kind, mask="wm", sparsity=0.95, rank=4)
    t, aux = core.init_adapter(jax.random.PRNGKey(0), params, acfg)

    def loss_fn(t):
        eff = core.materialize(params, t, aux, acfg)
        return lm.train_loss(eff, cfg, batch)[0]

    g = jax.grad(loss_fn)(t)
    gn = sum(float(jnp.sum(jnp.square(x))) for x in jax.tree.leaves(g))
    assert gn > 0, f"{kind}: no gradient signal"
    assert all(jnp.all(jnp.isfinite(x)) for x in jax.tree.leaves(g))


def test_shira_dora_changes_only_masked_entries(setup):
    cfg, params, _ = setup
    acfg = AdapterConfig(kind="shira-dora", mask="wm", sparsity=0.95, rank=4)
    t, aux = core.init_adapter(jax.random.PRNGKey(0), params, acfg)
    t = jax.tree.map(
        lambda x: x + 0.1 if isinstance(x, jnp.ndarray) else x, t)
    eff = core.materialize(params, t, aux, acfg)
    ch = core.switching.changed_fraction(params, eff)
    assert ch < 0.2, f"shira-dora must stay sparse in fused mode: %C={ch}"
