"""Async adapter prefetch pipeline: store tiers, async table builds, serving.

Acceptance bars pinned here:
  * While a ``PrefetchHandle`` is outstanding its adapter is immune to
    LRU eviction (the eviction-vs-prefetch race), and ``result()`` always
    returns a resident, correct pack — under concurrent budget pressure.
  * Duplicate prefetches of one name share a single disk read; cancel
    only skips the read when the handle holds the sole pin.
  * ``MultiTenantEngine`` background table builds produce byte-identical
    tables to the synchronous rebuild; stale builds (state moved on) are
    discarded, deferred fused transitions apply atomically at adoption.
  * ``async_prefetch=True`` serving (lane + paged, f32 + int8 tables)
    reproduces the synchronous path token-for-token on a mixed
    cold/hot/stack trace; queued requests can be cancelled.
  * ``replay.verify_overlap`` measures worker-span hiding exactly on a
    synthetic trace.
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import replay
from repro.configs import get_smoke_config
from repro.core.switching import FusedLRU
from repro.hub import AdapterStore, PagedServingEngine, ServingEngine
from repro.hub.packio import QuantPack
from repro.models import layers, lm
from repro.serving import MultiTenantEngine

from test_hub import synth_pack
from test_multitenant import make_packs


def wait_for(pred, timeout=20.0):
    t0 = time.monotonic()
    while not pred():
        if time.monotonic() - t0 > timeout:
            raise TimeoutError("condition not met")
        time.sleep(0.005)


# ---------------------------------------------------------------------------
# Store: prefetch handles, pins, tiers
# ---------------------------------------------------------------------------

def cold_store(tmp_path, n=4, **kw):
    store = AdapterStore(str(tmp_path / "store"), **kw)
    for i in range(n):
        store.add(synth_pack(name=f"t{i}", seed=i))
        store.evict(f"t{i}")
    return store


def test_prefetch_miss_then_hit(tmp_path):
    store = cold_store(tmp_path)
    h = store.prefetch("t0")
    assert h.cold
    p = h.result()
    assert p.name == "t0"
    assert store.is_resident("t0")
    assert store.prefetch_misses == 1
    h2 = store.prefetch("t0")
    assert h2.done() and not h2.cold
    assert store.prefetch_hits == 1
    np.testing.assert_array_equal(
        np.asarray(p.entries["embed/emb"][1]),
        np.asarray(h2.result().entries["embed/emb"][1]))
    store.shutdown()


def test_prefetch_dedup_single_disk_load(tmp_path):
    store = cold_store(tmp_path)
    hs = [store.prefetch("t1") for _ in range(4)]
    packs = [h.result() for h in hs]
    assert store.loads == 1
    assert all(p.name == "t1" for p in packs)
    store.shutdown()
    assert store.inflight_names() == []


def test_inflight_pin_blocks_eviction(tmp_path):
    """The bugfix contract: LRU pressure (or explicit evict) must never
    drop a pack that an outstanding PrefetchHandle is about to consume."""
    one = synth_pack(name="t0").nbytes()
    store = cold_store(tmp_path, n=4, budget_bytes=int(one * 1.5))
    h = store.prefetch("t0")
    wait_for(h.done)
    # t0 is pinned by the un-consumed handle: pounding the LRU with other
    # loads (budget fits ~1 pack) must evict those, never t0
    for i in (1, 2, 3):
        store.get(f"t{i}")
    assert store.is_resident("t0")
    assert not store.evict("t0")          # explicit evict refused too
    assert "t0" in store.inflight_names()
    p = h.result()
    assert p.name == "t0"
    # pin released: t0 is now ordinary LRU prey
    assert "t0" not in store.inflight_names()
    store.get("t1")
    store.get("t2")
    assert not store.is_resident("t0")
    assert store.evictions > 0
    store.shutdown()


def test_eviction_race_concurrent_prefetch(tmp_path):
    """Hammer the store from several threads under heavy budget pressure:
    every handle's result() must come back resident and correct."""
    n = 6
    one = synth_pack(name="t0").nbytes()
    store = cold_store(tmp_path, n=n, budget_bytes=int(one * 2.5), workers=3)
    errors = []

    def worker(seed):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(30):
                name = f"t{rng.integers(n)}"
                h = store.prefetch(name)
                p = h.result()
                if p.name != name:
                    errors.append(f"got {p.name} for {name}")
        except Exception as e:          # noqa: BLE001 - surface in main
            errors.append(repr(e))

    threads = [threading.Thread(target=worker, args=(s,)) for s in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    store.shutdown()
    assert store.inflight_names() == []


def test_prefetch_cancel_sole_vs_shared(tmp_path):
    store = cold_store(tmp_path)
    # shared future: cancelling one handle must not kill the other's load
    h1 = store.prefetch("t2")
    h2 = store.prefetch("t2")
    h1.cancel()
    assert h2.result().name == "t2"
    # sole handle: cancel is allowed to skip the read; either way the pin
    # drops and a later get() still loads correctly
    h3 = store.prefetch("t3")
    h3.cancel()
    store.shutdown()
    assert store.inflight_names() == []
    assert store.get("t3").name == "t3"


def test_staging_tier_caches_dequant(tmp_path):
    store = AdapterStore(str(tmp_path / "store"), staging_bytes=1 << 20)
    for i in range(2):
        store.add(synth_pack(name=f"t{i}", seed=i), values="int8")
        store.evict(f"t{i}")
    h = store.prefetch("t0", dequantize=True)
    p = h.result()
    assert not isinstance(p, QuantPack)
    assert "t0" in store.staged_names()   # decoded on the worker
    before = store.staging_hits
    store.get("t0")                       # hits staging, no second dequant
    assert store.staging_hits == before + 1
    store.shutdown()


# ---------------------------------------------------------------------------
# Replay: verify_overlap math
# ---------------------------------------------------------------------------

def _span(name, ts, dur, tid=0, cat="serving"):
    return {"ph": "X", "name": name, "cat": cat, "ts": float(ts),
            "dur": float(dur), "tid": tid, "depth": 0}


def test_verify_overlap_synthetic_exact():
    events = [
        _span("decode", 0, 100_000),
        _span("prefetch.disk", 20_000, 30_000, tid=1, cat="store"),
        # off the decode window: async work that hid nothing
        _span("prefetch.h2d", 150_000, 10_000, tid=1, cat="tables"),
    ]
    vo = replay.verify_overlap(events)
    assert vo["async_spans"] == 2
    assert vo["async_us"] == pytest.approx(40_000)
    assert vo["measured_hidden_us"] == pytest.approx(30_000)
    # self-contained bound: min(async, under budget)
    assert vo["predicted_hidden_us"] == pytest.approx(40_000)
    assert vo["realized_frac"] == pytest.approx(0.75)


def test_verify_overlap_against_sync_baseline():
    baseline = [
        _span("decode", 0, 100_000),
        _span("disk_load", 100_000, 30_000),
    ]
    events = [
        _span("decode", 0, 100_000),
        _span("prefetch.disk", 10_000, 20_000, tid=1, cat="store"),
    ]
    vo = replay.verify_overlap(events, baseline=baseline)
    # predicted comes from the serial what-if on the sync trace
    assert vo["predicted_hidden_us"] == pytest.approx(30_000)
    assert vo["measured_hidden_us"] == pytest.approx(20_000)
    assert vo["realized_frac"] == pytest.approx(2 / 3)


def test_verify_overlap_no_async_spans_is_vacuous():
    vo = replay.verify_overlap([_span("decode", 0, 50_000)])
    assert vo["async_spans"] == 0
    assert vo["measured_hidden_us"] == 0.0
    assert vo["realized_frac"] == 1.0     # nothing predicted, nothing owed


# ---------------------------------------------------------------------------
# Engine: async table builds
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    with layers.compute_precision(jnp.float32):
        cfg = get_smoke_config("starcoder2-7b")
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        packs = make_packs(cfg, params, 3)
        yield cfg, params, packs


def assert_tables_equal(ta, tb):
    assert sorted(ta) == sorted(tb)
    for path in ta:
        assert sorted(ta[path]) == sorted(tb[path])
        for k in ta[path]:
            np.testing.assert_array_equal(np.asarray(ta[path][k]),
                                          np.asarray(tb[path][k]))


def test_async_build_matches_sync_tables(setup):
    cfg, params, packs = setup
    sync = MultiTenantEngine(cfg, params)
    eng = MultiTenantEngine(cfg, params)
    for p in packs:
        sync.register(p)
        eng.register(p)
    sync._ensure_tables()
    assert eng.kick_async_build()
    wait_for(lambda: eng._build_fut is None or eng._build_fut[1].done())
    assert eng.poll_async_build()
    assert eng.async_adopted == 1 and not eng._dirty
    assert eng._slots == sync._slots
    assert_tables_equal(eng._tables, sync._tables)
    eng.shutdown()


def test_async_build_stale_discarded(setup):
    cfg, params, packs = setup
    eng = MultiTenantEngine(cfg, params)
    eng.register(packs[0])
    eng.kick_async_build()
    wait_for(lambda: eng._build_fut[1].done())
    eng.register(packs[1])                 # epoch moved on: build is stale
    eng.poll_async_build()
    assert eng.async_stale == 1 and eng._dirty
    eng._ensure_tables()                   # sync fallback covers both packs
    assert packs[1].name in eng._slots
    eng.shutdown()


def test_ids_covered_additive_vs_structural(setup):
    cfg, params, packs = setup
    eng = MultiTenantEngine(cfg, params)
    eng.register(packs[0])
    eng.ids_for([packs[0].name])           # builds tables
    assert eng.ids_covered([packs[0].name])
    eng.register(packs[1])                 # additive: old rows stay valid
    assert eng.ids_covered([packs[0].name])
    assert not eng.ids_covered([packs[1].name])
    eng.register(packs[0])                 # re-register: structural
    assert not eng.ids_covered([packs[0].name])
    eng.shutdown()


def test_deferred_transition_applies_at_adoption(setup):
    cfg, params, packs = setup
    hot = [packs[0].name] * 4 + [packs[1].name]
    # decay=0.5 EMA: one observe of an 80% share lands at 0.4, so a 0.3
    # threshold promotes on the first schedule call
    sync = MultiTenantEngine(cfg, params,
                             scheduler=FusedLRU(promote_at=0.3))
    eng = MultiTenantEngine(cfg, params, scheduler=FusedLRU(promote_at=0.3))
    for p in packs[:2]:
        sync.register(p)
        eng.register(p)
    sync.schedule(hot)                     # promotes packs[0] inline
    sync._ensure_tables()
    assert sync.fused == packs[0].name
    eng.schedule(hot, defer=True)          # stashes the decision
    assert eng.fused is None and eng._pending is not None
    assert eng.kick_async_build()
    wait_for(lambda: eng._build_fut[1].done())
    assert eng.poll_async_build()
    assert eng.fused == packs[0].name and eng._pending is None
    assert eng.async_adopted == 1
    assert eng._slots == sync._slots
    assert_tables_equal(eng._tables, sync._tables)
    eng.shutdown()
    sync.shutdown()


def test_slot_pad_keeps_shapes_and_values(setup):
    cfg, params, packs = setup
    exact = MultiTenantEngine(cfg, params)
    padded = MultiTenantEngine(cfg, params, slot_pad=4)
    for p in packs[:2]:
        exact.register(p)
        padded.register(p)
    exact._ensure_tables()
    padded._ensure_tables()
    assert exact._slots == padded._slots
    for path, t in exact._tables.items():
        tp = padded._tables[path]
        assert tp["vals"].shape[-2] == 4 and t["vals"].shape[-2] == 2
        for k in t:
            np.testing.assert_array_equal(
                np.asarray(t[k]), np.asarray(tp[k])[..., :2, :]
                if k != "scale" else np.asarray(tp[k])[..., :2])
        # padding is inert: zero values in the spare slots
        assert not np.asarray(tp["vals"])[..., 2:, :].any()
    # registering a third adapter within the padded capacity keeps shapes
    padded.register(packs[2])
    padded._ensure_tables()
    assert padded._tables[path]["vals"].shape[-2] == 4
    exact.shutdown()
    padded.shutdown()


# ---------------------------------------------------------------------------
# Serving: async-vs-sync parity on a mixed cold/hot trace
# ---------------------------------------------------------------------------

def _run_trace(engine, trace_reqs):
    futs = [engine.submit(prompt, adapter, max_tokens=mt)
            for prompt, adapter, mt in trace_reqs]
    engine.run()
    engine.shutdown(include_store=True)
    return futs


def _mixed_trace(cfg, rng, adapters):
    reqs = []
    for i, adapter in enumerate(adapters):
        prompt = rng.integers(0, cfg.vocab_size, 5 + (i % 3)).astype(np.int32)
        reqs.append((prompt, adapter, 4 + (i % 2)))
    return reqs


def _serving_stores(tmp_path, packs, values, two=True):
    out = []
    for tag in ("sync", "async") if two else ("solo",):
        store = AdapterStore(str(tmp_path / f"store-{tag}"))
        for p in packs:
            store.add(p, values=values)
            store.evict(p.name)
        out.append(store)
    return out


def test_lane_async_parity_mixed_cold_hot(tmp_path, setup):
    cfg, params, packs = setup
    rng = np.random.default_rng(0)
    # hot a0 (preregistered), cold singles, base traffic, and a cold stack
    adapters = ["a0", "a1", None, ("a1", "a2"), "a0", "a2"]
    reqs = _mixed_trace(cfg, rng, adapters)
    results = {}
    with layers.compute_precision(jnp.float32):
        for mode, store in zip((False, True),
                               _serving_stores(tmp_path, packs, "f32")):
            srv = ServingEngine(cfg, params, slots=2, cache_size=32,
                                store=store, async_prefetch=mode,
                                slot_pad=4)
            srv.register("a0")
            results[mode] = _run_trace(srv, reqs)
    for fs, fa in zip(results[False], results[True]):
        assert fs.done() and fa.done()
        np.testing.assert_array_equal(fs.result(), fa.result())
    # cold stamps: first touch of an unregistered adapter is cold; the
    # preregistered a0 and base traffic never are. (Repeat requests racing
    # an in-flight load may be stamped either way — not asserted.)
    cold = [f.cold for f in results[True]]
    assert cold[1] and cold[3]
    assert not cold[0] and not cold[2]


def test_paged_async_parity_mixed_cold_hot_int8(tmp_path, setup):
    cfg, params, packs = setup
    rng = np.random.default_rng(1)
    adapters = ["a0", "a1", None, "a2", "a1", "a0"]
    reqs = _mixed_trace(cfg, rng, adapters)
    results = {}
    with layers.compute_precision(jnp.float32):
        for mode, store in zip((False, True),
                               _serving_stores(tmp_path, packs, "int8")):
            srv = PagedServingEngine(cfg, params, slots=2, num_pages=41,
                                     page_size=2, max_len=16, chunk_size=4,
                                     store=store, table_dtype="int8",
                                     async_prefetch=mode, slot_pad=4)
            srv.register("a0")
            results[mode] = _run_trace(srv, reqs)
    for fs, fa in zip(results[False], results[True]):
        assert fs.done() and fa.done()
        np.testing.assert_array_equal(fs.result(), fa.result())
    cold = [f.cold for f in results[True]]
    assert cold[1] and cold[3]
    assert not cold[0] and not cold[2]


def test_async_cancel_queued_request(tmp_path, setup):
    cfg, params, packs = setup
    rng = np.random.default_rng(2)
    store = _serving_stores(tmp_path, packs, "f32", two=False)[0]
    with layers.compute_precision(jnp.float32):
        srv = ServingEngine(cfg, params, slots=1, cache_size=32,
                            store=store, async_prefetch=True, slot_pad=4)
        srv.register("a0")
        keep = srv.submit(rng.integers(0, cfg.vocab_size, 5), "a0",
                          max_tokens=3)
        dead = srv.submit(rng.integers(0, cfg.vocab_size, 5), "a1",
                          max_tokens=3)
        assert srv.cancel(dead)
        srv.run()
        srv.shutdown(include_store=True)
    assert keep.done() and len(keep.result()) == 3
    assert dead.cancelled
    with pytest.raises(RuntimeError, match="cancelled"):
        dead.result()
    assert store.inflight_names() == []
