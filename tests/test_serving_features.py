"""flash_prefill kernel, int8 KV cache, gradient accumulation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.serving import (cache_bytes, dequantize_kv, quant_cache_zeros,
                           quantize_kv, update_quant_cache)


@pytest.mark.parametrize("B,H,KV,D,Sq,bq,bkv", [
    (1, 2, 2, 64, 512, 256, 256),
    (2, 4, 2, 64, 512, 128, 256),
    (1, 8, 1, 128, 384, 128, 128),   # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_prefill_vs_ref(B, H, KV, D, Sq, bq, bkv, dtype):
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(B, Sq, H, D), dtype)
    k = jnp.asarray(rng.randn(B, Sq, KV, D), dtype)
    v = jnp.asarray(rng.randn(B, Sq, KV, D), dtype)
    out = ops.flash_prefill(q, k, v, bq=bq, bkv=bkv, interpret=True)
    want = ref.flash_prefill_ref(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_prefill_matches_model_chunked_attention():
    from repro.models.attention import chunked_attention
    rng = np.random.RandomState(1)
    B, Sq, H, KV, D = 1, 256, 4, 2, 32
    q = jnp.asarray(rng.randn(B, Sq, H, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, Sq, KV, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, Sq, KV, D), jnp.float32)
    model = chunked_attention(q, k, v, causal=True, q_chunk=64)
    kern = ops.flash_prefill(q, k, v, bq=64, bkv=64, interpret=True)
    np.testing.assert_allclose(np.asarray(kern, np.float32),
                               np.asarray(model, np.float32),
                               atol=3e-2, rtol=3e-2)


def test_int8_kv_roundtrip_error():
    rng = np.random.RandomState(2)
    k = jnp.asarray(rng.randn(2, 128, 4, 64), jnp.bfloat16)
    qk = quantize_kv(k)
    deq = dequantize_kv(qk)
    rel = float(jnp.max(jnp.abs(deq.astype(jnp.float32)
                                - k.astype(jnp.float32)))) / \
        float(jnp.max(jnp.abs(k.astype(jnp.float32))))
    assert rel < 0.02, rel
    assert qk.codes.dtype == jnp.int8


def test_int8_kv_attention_error_small():
    """End-to-end: attention over a quantized cache stays within 1%."""
    rng = np.random.RandomState(3)
    B, S, KV, G, D = 1, 256, 2, 2, 64
    q = jnp.asarray(rng.randn(B, KV, G, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, KV, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, KV, D), jnp.float32)
    exact = ref.flash_decode_ref(q, k, v, S)
    kq = dequantize_kv(quantize_kv(k)).astype(jnp.float32)
    vq = dequantize_kv(quantize_kv(v)).astype(jnp.float32)
    approx = ref.flash_decode_ref(q, kq, vq, S)
    rel = float(jnp.max(jnp.abs(approx - exact))) / \
        (float(jnp.max(jnp.abs(exact))) + 1e-9)
    assert rel < 0.01, rel


def test_quant_cache_update():
    cache = quant_cache_zeros((1, 16, 2, 8))
    new = jnp.ones((1, 1, 2, 8), jnp.bfloat16) * 3.0
    cache = update_quant_cache(cache, new, 5)
    deq = dequantize_kv(cache)
    np.testing.assert_allclose(np.asarray(deq[0, 5], np.float32), 3.0,
                               rtol=0.02)
    assert float(jnp.sum(jnp.abs(deq[0, :5].astype(jnp.float32)))) == 0.0


def test_quant_cache_halves_bytes():
    shape = (128, 32768, 40, 128)
    assert cache_bytes(shape, quant=True) < 0.52 * cache_bytes(shape, False)


@pytest.mark.slow
def test_grad_accumulation_matches_full_batch():
    """microbatch=4 must give (numerically) the same update as one batch."""
    from repro.configs import TrainConfig, get_smoke_config
    from repro.launch.steps import make_train_step, abstract_train_state
    from repro.models import lm
    cfg = get_smoke_config("starcoder2-7b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    state = {"trainable": params,
             "mu": jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params),
             "nu": jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params),
             "step": jnp.zeros((), jnp.int32)}
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                          cfg.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0,
                                          cfg.vocab_size)}
    s1, m1 = jax.jit(make_train_step(cfg, TrainConfig(microbatch=0)))(
        jax.tree.map(lambda x: x, state), batch)
    s4, m4 = jax.jit(make_train_step(cfg, TrainConfig(microbatch=4)))(
        jax.tree.map(lambda x: x, state), batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 5e-3
    for a, b in zip(jax.tree.leaves(s1["trainable"]),
                    jax.tree.leaves(s4["trainable"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-3, rtol=5e-2)