"""End-to-end behaviour of the paper's system (paper §4 at test scale):

  train adapters on two synthetic tasks -> export packs -> rapid-switch a
  deployed model between them -> each pack recovers ITS task's loss ->
  naive multi-adapter fusion keeps both tasks better than the base model.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.configs import AdapterConfig, RunConfig, TrainConfig, get_smoke_config
from repro.configs.base import ShapeSpec
from repro.data import TaskSpec, batch_iterator, make_batch
from repro.models import lm
from repro.runtime import Trainer
from repro.runtime.trainer import TrainerConfig

SHAPE = ShapeSpec("tiny", 64, 8, "train")
ARCH = "starcoder2-7b"
STEPS = 60


@pytest.fixture(scope="module")
def adapters_and_base():
    run = RunConfig(model=get_smoke_config(ARCH), shape=SHAPE,
                    adapter=AdapterConfig(kind="shira", mask="wm",
                                          sparsity=0.9),
                    train=TrainConfig(learning_rate=2e-2, total_steps=STEPS,
                                      warmup_steps=3))
    packs, base = {}, None
    for task in (1, 2):
        t = Trainer(run, TrainerConfig())
        out = t.fit(STEPS, batches=batch_iterator(
            run.model, SHAPE, seed=0, task=TaskSpec(task_id=task)), log=None)
        packs[task] = t.export_pack(out["state"], name=f"task{task}")
        base = t.base
    return get_smoke_config(ARCH), base, packs


def eval_loss(cfg, params, task: int) -> float:
    batch = {k: jnp.asarray(v) for k, v in
             make_batch(cfg, SHAPE, seed=123, step=999,
                        task=TaskSpec(task_id=task)).items()}
    return float(lm.train_loss(params, cfg, batch)[0])


@pytest.mark.slow
def test_adapter_switching_recovers_each_task(adapters_and_base):
    cfg, base, packs = adapters_and_base
    eng = core.SwitchEngine(base)
    base_l1 = eval_loss(cfg, eng.params, 1)
    base_l2 = eval_loss(cfg, eng.params, 2)

    eng.switch(packs[1])
    l1 = eval_loss(cfg, eng.params, 1)
    assert l1 < base_l1 - 0.05, (l1, base_l1)

    eng.switch(packs[2])   # rapid switch: unload 1, load 2
    l2 = eval_loss(cfg, eng.params, 2)
    assert l2 < base_l2 - 0.05, (l2, base_l2)

    # after unloading everything the base model is recovered
    eng.unload()
    for a, b in zip(jax.tree.leaves(eng.params), jax.tree.leaves(base)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


def test_multi_adapter_fusion_keeps_both_tasks(adapters_and_base):
    """Paper §4.3.2: naive fusion of sparse adapters keeps both concepts."""
    cfg, base, packs = adapters_and_base
    base_losses = {t: eval_loss(cfg, base, t) for t in (1, 2)}
    eng = core.SwitchEngine(base)
    eng.load_fused([packs[1], packs[2]])
    fused_losses = {t: eval_loss(cfg, eng.params, t) for t in (1, 2)}
    for t in (1, 2):
        assert fused_losses[t] < base_losses[t], (t, fused_losses, base_losses)


def test_pack_size_comparable_to_lora(adapters_and_base):
    """SHiRA packs are LoRA-sized on disk but patch only 1-2% of weights."""
    cfg, base, packs = adapters_and_base
    pack_bytes = packs[1].nbytes()
    model_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(base))
    assert pack_bytes < 0.35 * model_bytes
