# NOTE: deliberately NO xla_force_host_platform_device_count here — tests
# must see the 1 real CPU device; only repro.launch.dryrun forces 512.
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: heavyweight test, skipped by default so the tier-1 "
        "command (`python -m pytest -x -q`) finishes in minutes; run with "
        "--runslow")
    config.addinivalue_line(
        "markers", "smoke: fast end-to-end smoke over an architecture/path")


def pytest_addoption(parser):
    parser.addoption("--runslow", action="store_true", default=False,
                     help="also run tests marked slow")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="slow: needs --runslow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)
