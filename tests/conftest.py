# NOTE: deliberately NO xla_force_host_platform_device_count here — tests
# must see the 1 real CPU device; only repro.launch.dryrun forces 512.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
