"""Paged KV cache: pool primitives, PagePool policy, paged engine parity.

Acceptance bars pinned here:
  * paged decode is token-for-token identical to the contiguous path
    (greedy), including adapter stacks and int8 side-delta tables;
  * COW prefix sharing: shared prompt pages diverge on first write with no
    cross-request contamination, and registered prefixes survive sharers;
  * chunked prefill never stalls live decode lanes for more than one step;
  * admission is gated on free pages, not free lanes;
  * ``update_quant_cache`` writes the caller-specified sequence axis
    (the serving caches carry scan-stack dims in front of batch).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.configs import AdapterConfig, get_smoke_config
from repro.hub import PagedServingEngine, ServingEngine
from repro.models import layers, lm
from repro.serving import MultiTenantEngine
from repro.serving.kvcache import (PagePool, QuantKV, copy_page,
                                   dequantize_kv, paged_gather, paged_write,
                                   pages_for, pool_zeros, quantize_kv,
                                   update_quant_cache)

TARGETS = ("wq", "wk", "wv", "wo", "w_up", "w_gate", "w_down")


def make_packs(cfg, params, n, seed=7, scale=0.05):
    acfg = AdapterConfig(kind="shira", mask="rand", sparsity=0.98,
                         target_modules=TARGETS)
    packs = []
    for i in range(n):
        sub = jax.random.fold_in(jax.random.PRNGKey(seed), i)
        values, aux = core.init_adapter(sub, params, acfg)
        values = jax.tree.map(
            lambda v: None if v is None
            else scale * jax.random.normal(sub, v.shape), values,
            is_leaf=lambda x: x is None)
        packs.append(core.pack_from_shira(f"a{i}", values, aux))
    return packs


@pytest.fixture(scope="module")
def paged_setup():
    with layers.compute_precision(jnp.float32):
        cfg = get_smoke_config("starcoder2-7b")
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        packs = make_packs(cfg, params, 2)
        mt = MultiTenantEngine(cfg, params)
        for p in packs:
            mt.register(p)
        yield cfg, params, packs, mt


def reference(mt, cfg, prompt, name, tokens):
    out, _ = mt.generate({"tokens": jnp.asarray(np.asarray(prompt)[None])},
                         [name], tokens)
    return np.asarray(out)[0]


# ---------------------------------------------------------------------------
# PagePool policy (pure host)
# ---------------------------------------------------------------------------

def test_pool_alloc_release_refcounts():
    pool = PagePool(6, 4)
    assert pool.free_pages() == 5          # page 0 is pinned scratch
    a = pool.alloc(3)
    assert 0 not in a and len(set(a)) == 3
    assert pool.used_pages() == 3
    pool.share(a[0])
    assert pool.is_shared(a[0]) and not pool.is_shared(a[1])
    pool.release(a)                        # table refs drop
    assert pool.free_pages() == 4          # a[0] kept alive by the share
    pool.release([a[0]])
    assert pool.free_pages() == 5
    with pytest.raises(MemoryError):
        pool.alloc(6)


def test_pool_prefix_match_and_cap():
    p = 4
    pool = PagePool(10, p)
    toks = np.arange(10, dtype=np.int32)
    pages = pool.alloc(pages_for(len(toks), p))        # 3 pages
    pool.register_prefix(toks, pages)
    assert pool.registered_prefixes() == 3             # 2 full + 1 partial

    # identical prompt: capped at L-1, tail page stays shared for COW
    n, shared = pool.match_prefix(toks)
    assert n == 9 and shared == pages
    assert all(pool.refs[pg] >= 3 for pg in shared)    # owner+registry+match
    pool.release(shared)

    # page-aligned different tail: only the full-page chain matches
    other = np.concatenate([toks[:8], [99, 98]]).astype(np.int32)
    n, shared = pool.match_prefix(other)
    assert n == 8 and shared == pages[:2]
    pool.release(shared)

    # cap inside the first page: single-page prompt shares up to L-1
    n, shared = pool.match_prefix(toks[:4])
    assert n == 3 and shared == pages[:1]
    pool.release(shared)

    # one-token prompt can never share (its logits must be recomputed)
    n, shared = pool.match_prefix(toks[:1])
    assert n == 0 and shared == []


def test_pool_lru_eviction_frees_cold_prefixes():
    p = 2
    pool = PagePool(6, p)
    t1, t2 = np.asarray([1, 2], np.int32), np.asarray([3, 4], np.int32)
    pg1, pg2 = pool.alloc(1), pool.alloc(1)
    pool.register_prefix(t1, pg1)
    pool.register_prefix(t2, pg2)
    pool.release(pg1)
    pool.release(pg2)                      # only registry refs remain
    assert pool.free_pages() == 3 and pool.can_alloc(5)
    _, sh = pool.match_prefix(np.asarray([3, 4, 5], np.int32))  # touch t2
    pool.release(sh)                       # keep both evictable: LRU decides
    got = pool.alloc(4)                    # forces one eviction: t1 first
    assert pool.evictions == 1 and len(got) == 4
    assert pool.registered_prefixes() == 1
    n, shared = pool.match_prefix(np.asarray([1, 2, 9], np.int32))
    assert n == 0 and shared == []         # t1 is gone; t2 survives


# ---------------------------------------------------------------------------
# Device primitives
# ---------------------------------------------------------------------------

def test_paged_write_gather_roundtrip_and_scratch():
    P, page, tail = 5, 4, (2, 3)
    pool = pool_zeros(P, page, tail, jnp.float32)
    bt = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
    new = jax.random.normal(jax.random.PRNGKey(0), (2, 3) + tail)
    positions = jnp.asarray([[0, 1, 5], [2, 3, 9]])
    valid = jnp.asarray([[True, True, True], [True, True, False]])
    pool = paged_write(pool, new, bt, positions, valid)
    out = paged_gather(pool, bt)           # (2, 8, 2, 3)
    np.testing.assert_allclose(out[0, 0], new[0, 0], rtol=1e-6)
    np.testing.assert_allclose(out[0, 1], new[0, 1], rtol=1e-6)
    np.testing.assert_allclose(out[0, 5], new[0, 2], rtol=1e-6)
    np.testing.assert_allclose(out[1, 2], new[1, 0], rtol=1e-6)
    np.testing.assert_allclose(out[1, 3], new[1, 1], rtol=1e-6)
    # the invalid row landed in scratch page 0, not in request 1's pages
    scratch = paged_gather(pool, jnp.zeros((1, 1), jnp.int32))
    np.testing.assert_allclose(scratch[0, 0], new[1, 2], rtol=1e-6)
    assert float(jnp.abs(out[1, 4:]).max()) == 0.0


def test_paged_quant_pool_matches_quantize_roundtrip():
    P, page, tail = 4, 2, (3, 8)
    pool = pool_zeros(P, page, tail, jnp.float32, quant=True)
    assert isinstance(pool, QuantKV)
    new = jax.random.normal(jax.random.PRNGKey(1), (1, 2) + tail)
    bt = jnp.asarray([[2]], jnp.int32)
    positions = jnp.asarray([[0, 1]])
    pool = paged_write(pool, new, bt, positions, jnp.ones((1, 2), bool))
    out = paged_gather(pool, bt)
    want = dequantize_kv(quantize_kv(new))
    np.testing.assert_array_equal(np.asarray(out[0], np.float32),
                                  np.asarray(want[0], np.float32))


def test_copy_page_layer_stacked_axis():
    x = jax.random.normal(jax.random.PRNGKey(2), (3, 5, 2, 4))  # (L, P, ...)
    y = copy_page(x, 4, 1, page_axis=1)
    np.testing.assert_array_equal(np.asarray(y[:, 1]), np.asarray(x[:, 4]))
    np.testing.assert_array_equal(np.asarray(y[:, 2:]), np.asarray(x[:, 2:]))
    np.testing.assert_array_equal(np.asarray(y[:, 0]), np.asarray(x[:, 0]))


def test_flash_decode_paged_matches_reference():
    from repro.kernels.flash_decode import flash_decode_paged
    B, KV, G, D, page, nblk = 2, 2, 2, 8, 4, 3
    P = 1 + B * nblk
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (B, KV, G, D), jnp.float32)
    kc = jax.random.normal(jax.random.fold_in(key, 1),
                           (B, nblk * page, KV, D), jnp.float32)
    vc = jax.random.normal(jax.random.fold_in(key, 2),
                           (B, nblk * page, KV, D), jnp.float32)
    kv_len = jnp.asarray([7, 11], jnp.int32)
    # scatter the contiguous rows into per-request pages
    bt = jnp.arange(1, P, dtype=jnp.int32).reshape(B, nblk)
    kp = pool_zeros(P, page, (KV, D), jnp.float32)
    vp = pool_zeros(P, page, (KV, D), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(nblk * page)[None], (B, nblk * page))
    ones = jnp.ones((B, nblk * page), bool)
    kp = paged_write(kp, kc, bt, pos, ones)
    vp = paged_write(vp, vc, bt, pos, ones)
    got = flash_decode_paged(q, kp, vp, bt, kv_len, interpret=True)
    # reference: per-request masked softmax attention
    for b in range(B):
        L = int(kv_len[b])
        for h in range(KV):
            s = (np.asarray(q[b, h]) @ np.asarray(kc[b, :L, h]).T
                 ) / np.sqrt(D)
            pr = np.exp(s - s.max(-1, keepdims=True))
            pr /= pr.sum(-1, keepdims=True)
            want = pr @ np.asarray(vc[b, :L, h])
            np.testing.assert_allclose(np.asarray(got[b, h]), want,
                                       rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# update_quant_cache sequence axis (bugfix regression)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,seq_axis", [
    ((2, 6, 3, 4), 1),       # plain (B, S, KV, D) — historical default
    ((5, 2, 6, 3, 4), 2),    # scan-stacked serving layout (L, B, S, KV, D)
    ((5, 2, 6, 3, 4), -3),   # same axis, negative form
])
def test_update_quant_cache_seq_axis(shape, seq_axis):
    from repro.serving.kvcache import quant_cache_zeros
    cache = quant_cache_zeros(shape)
    ax = seq_axis % len(shape)
    new_shape = tuple(1 if i == ax else d for i, d in enumerate(shape))
    new = jax.random.normal(jax.random.PRNGKey(4), new_shape)
    pos = 3
    out = update_quant_cache(cache, new, pos, seq_axis=seq_axis)
    got = dequantize_kv(out)
    want = dequantize_kv(quantize_kv(new))
    np.testing.assert_array_equal(
        np.asarray(jnp.take(got, pos, axis=ax), np.float32),
        np.asarray(jnp.squeeze(want, ax), np.float32))
    # every other sequence index is untouched
    other = jnp.delete(out.codes, pos, axis=ax)
    assert int(jnp.abs(other).max()) == 0


def test_update_quant_cache_rejects_bad_axis():
    from repro.serving.kvcache import quant_cache_zeros
    cache = quant_cache_zeros((2, 6, 4))
    with pytest.raises(ValueError, match="seq_axis"):
        update_quant_cache(cache, jnp.zeros((2, 1, 4)), 0, seq_axis=5)


# ---------------------------------------------------------------------------
# Paged engine: parity, COW, chunked admission
# ---------------------------------------------------------------------------

def test_paged_engine_matches_fixed_batch(paged_setup):
    """Greedy paged decode == fixed-batch contiguous decode token-for-token,
    with mixed lengths, an adapter stack, and chunked prefill in play."""
    with layers.compute_precision(jnp.float32):
        cfg, params, packs, mt = paged_setup
        B, S = 4, 9
        lens = [4, 2, 5, 3]
        names = ["a0", None, ("a0", "a1"), "a1"]
        toks = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (B, S),
                                             0, cfg.vocab_size))
        want, _ = mt.generate({"tokens": jnp.asarray(toks)}, names, max(lens))
        want = np.asarray(want)
        pe = PagedServingEngine(cfg, params, slots=2, num_pages=24,
                                page_size=4, max_len=20, chunk_size=4)
        for p in packs:
            pe.register(p)
        futs = [pe.submit(toks[i], names[i], max_tokens=lens[i])
                for i in range(B)]
        pe.run()
        for i, f in enumerate(futs):
            assert f.done()
            np.testing.assert_array_equal(f.result(), want[i][:lens[i]],
                                          err_msg=f"request {i}")
        assert pe.tokens_out == sum(lens)
        assert pe.prefill_chunks >= B * (S // 4)   # chunked, not one-shot
        assert pe.pool.free_pages() > 0


def test_paged_engine_int8_tables_parity(paged_setup):
    """int8 side-delta tables: paged and fixed-batch engines build the same
    tables, so greedy tokens stay identical."""
    with layers.compute_precision(jnp.float32):
        cfg, params, packs, _ = paged_setup
        toks = np.asarray(jax.random.randint(jax.random.PRNGKey(6), (7,),
                                             0, cfg.vocab_size))
        mt8 = MultiTenantEngine(cfg, params, table_dtype="int8")
        for p in packs:
            mt8.register(p)
        want = reference(mt8, cfg, toks, "a0", 4)
        pe = PagedServingEngine(cfg, params, slots=2, num_pages=16,
                                page_size=4, max_len=16, chunk_size=4,
                                table_dtype="int8")
        for p in packs:
            pe.register(p)
        fut = pe.submit(toks, "a0", max_tokens=4)
        pe.run()
        np.testing.assert_array_equal(fut.result(), want[:4])


def test_paged_engine_quant_kv_pages(paged_setup):
    """int8 KV pages serve end to end; quantization error stays small
    enough that the first token (pure prompt math) agrees with f32."""
    with layers.compute_precision(jnp.float32):
        cfg, params, packs, mt = paged_setup
        toks = np.asarray(jax.random.randint(jax.random.PRNGKey(8), (6,),
                                             0, cfg.vocab_size))
        want = reference(mt, cfg, toks, None, 3)
        pe = PagedServingEngine(cfg, params, slots=1, num_pages=16,
                                page_size=4, max_len=16, chunk_size=4,
                                quant_kv=True)
        fut = pe.submit(toks, None, max_tokens=3)
        pe.run()
        got = fut.result()
        assert len(got) == 3
        assert int(got[0]) == int(want[0])


def test_paged_cow_prefix_sharing_no_contamination(paged_setup):
    """Two requests sharing a prompt prefix must (a) actually share pages,
    (b) COW on divergence, (c) produce exactly their independent outputs,
    and (d) leave the registered prefix intact for a third request."""
    with layers.compute_precision(jnp.float32):
        cfg, params, packs, mt = paged_setup
        rng = np.random.default_rng(5)
        prefix = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
        pa = np.concatenate([prefix, [7, 11]]).astype(np.int32)
        pb = np.concatenate([prefix, [13, 3]]).astype(np.int32)
        wa, wb = reference(mt, cfg, pa, None, 4), reference(mt, cfg, pb,
                                                            None, 4)
        pe = PagedServingEngine(cfg, params, slots=2, num_pages=32,
                                page_size=4, max_len=16, chunk_size=4)
        fa = pe.submit(pa, None, max_tokens=4)
        pe.run()                               # registers pa's prefix pages
        assert pe.pool.registered_prefixes() >= 3
        fb = pe.submit(pb, None, max_tokens=4)
        pe.run()
        assert pe.pool.prefix_hits == 1
        assert pe.pool.prefix_shared_tokens >= len(prefix)
        assert pe.pool.cow_copies >= 1         # divergent tail writes copied
        np.testing.assert_array_equal(fa.result(), wa[:4])
        np.testing.assert_array_equal(fb.result(), wb[:4])
        # shared pages were never mutated: pa replays identically via reuse
        fa2 = pe.submit(pa, None, max_tokens=4)
        pe.run()
        assert pe.pool.prefix_hits == 2
        np.testing.assert_array_equal(fa2.result(), wa[:4])


def test_paged_prefix_not_shared_across_adapters(paged_setup):
    """Prefix pages hold adapter-dependent KV: the same prompt under a
    different adapter stack must NOT hit the registry (the registry is
    salted by tenant), and its output must match its own reference."""
    with layers.compute_precision(jnp.float32):
        cfg, params, packs, mt = paged_setup
        toks = np.asarray(jax.random.randint(jax.random.PRNGKey(14), (9,),
                                             0, cfg.vocab_size))
        pe = PagedServingEngine(cfg, params, slots=2, num_pages=32,
                                page_size=4, max_len=16, chunk_size=4)
        pe.register(packs[0])
        f0 = pe.submit(toks, None, max_tokens=4)
        pe.run()                           # registers under the None salt
        f1 = pe.submit(toks, "a0", max_tokens=4)
        pe.run()
        assert pe.pool.prefix_hits == 0    # different tenant: no sharing
        np.testing.assert_array_equal(f0.result(),
                                      reference(mt, cfg, toks, None, 4))
        np.testing.assert_array_equal(f1.result(),
                                      reference(mt, cfg, toks, "a0", 4))
        # same tenant does share
        f2 = pe.submit(toks, "a0", max_tokens=4)
        pe.run()
        assert pe.pool.prefix_hits == 1
        np.testing.assert_array_equal(f2.result(), f1.result())


def test_paged_chunked_prefill_no_decode_stall(paged_setup):
    """While a long prompt trickles in chunk by chunk, a live lane must
    emit one token per engine step — no stall > one step."""
    with layers.compute_precision(jnp.float32):
        cfg, params, packs, mt = paged_setup
        short = np.asarray(jax.random.randint(jax.random.PRNGKey(9), (4,),
                                              0, cfg.vocab_size))
        long = np.asarray(jax.random.randint(jax.random.PRNGKey(10), (20,),
                                             0, cfg.vocab_size))
        pe = PagedServingEngine(cfg, params, slots=2, num_pages=32,
                                page_size=4, max_len=32, chunk_size=4)
        fs = pe.submit(short, None, max_tokens=24)
        while not fs.tokens:                   # drive until it is decoding
            pe.step()
        fl = pe.submit(long, None, max_tokens=2)
        stall = 0
        while fl.first_token_step is None:
            before = len(fs.tokens)
            assert pe.step()
            stall = max(stall, len(fs.tokens) - before < 1)
            assert not fs.done(), "short request drained before prefill end"
        assert stall == 0, "live decode lane stalled during chunked prefill"
        # the long prompt took several steps (chunks), not one big prefill
        assert fl.first_token_step - fl.submitted_step >= len(long) // 4 - 1
        pe.run()
        np.testing.assert_array_equal(fl.result(),
                                      reference(mt, cfg, long, None, 2))


def test_paged_admission_gated_on_pages_not_lanes(paged_setup):
    """With lanes to spare but a small pool, admission waits for pages; the
    queued request completes once earlier requests release theirs."""
    with layers.compute_precision(jnp.float32):
        cfg, params, packs, mt = paged_setup
        pool_pages = 9                         # 8 usable
        pe = PagedServingEngine(cfg, params, slots=4, num_pages=pool_pages,
                                page_size=4, max_len=16, chunk_size=4)
        prompts = [np.asarray(jax.random.randint(
            jax.random.fold_in(jax.random.PRNGKey(11), i), (12,), 0,
            cfg.vocab_size)) for i in range(3)]
        futs = [pe.submit(p, None, max_tokens=5) for p in prompts]
        pe.step()                              # each request needs 4 pages
        admitted = sum(a is not None for a in pe._active)
        assert admitted == 2 and len(pe._queue) == 1
        assert pe.pool.free_pages() == 0
        pe.run()
        for f, p in zip(futs, prompts):
            np.testing.assert_array_equal(f.result(),
                                          reference(mt, cfg, p, None, 5))
        assert pe.peak_used_pages <= pool_pages - 1
        # a request that can never fit is rejected up front
        with pytest.raises(ValueError, match="KV rows"):
            pe.submit(np.zeros(30, np.int32), None, max_tokens=8)


def test_paged_engine_rejects_unpaged_families():
    with layers.compute_precision(jnp.float32):
        cfg = get_smoke_config("mamba2-780m")
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        with pytest.raises(NotImplementedError, match="paged"):
            PagedServingEngine(cfg, params, num_pages=8, page_size=4)


# ---------------------------------------------------------------------------
# Randomized page/prompt/chunk boundary sweep
# ---------------------------------------------------------------------------

def _boundary_case(paged_setup, page_size, plen, chunk, max_tokens):
    with layers.compute_precision(jnp.float32):
        cfg, params, packs, mt = paged_setup
        toks = np.asarray(jax.random.randint(jax.random.PRNGKey(plen),
                                             (plen,), 0, cfg.vocab_size))
        want = reference(mt, cfg, toks, "a0", max_tokens)
        pe = PagedServingEngine(cfg, params, slots=1, num_pages=24,
                                page_size=page_size, max_len=16,
                                chunk_size=chunk)
        pe.register(packs[0])
        fut = pe.submit(toks, "a0", max_tokens=max_tokens)
        pe.run()
        np.testing.assert_array_equal(
            fut.result(), want[:max_tokens],
            err_msg=f"page={page_size} plen={plen} chunk={chunk} "
                    f"T={max_tokens}")


@pytest.mark.parametrize("page_size,plen,chunk,max_tokens", [
    (4, 8, 4, 3),     # everything page/chunk aligned
    (4, 7, 3, 2),     # partial tail page, chunk != page
    (3, 10, 5, 1),    # chunk > page, max_tokens == 1 (no decode step)
    (2, 2, 4, 4),     # prompt smaller than one chunk
])
def test_paged_engine_boundary_sweep(paged_setup, page_size, plen, chunk,
                                     max_tokens):
    """Token parity must hold across page-size / prompt-length /
    chunk-boundary alignments (partial tail pages, chunk != page, prompts
    smaller than one chunk, max_tokens == 1). Deterministic slice of the
    randomized sweep below, so the invariant is pinned even where
    ``hypothesis`` is not installed."""
    _boundary_case(paged_setup, page_size, plen, chunk, max_tokens)


try:                       # optional dep, same convention as test_property
    from hypothesis import given, settings, strategies as st
except ImportError:
    st = None

if st is not None:
    @settings(max_examples=8, deadline=None)
    @given(page_size=st.sampled_from([2, 3, 4]),
           plen=st.integers(min_value=2, max_value=11),
           chunk=st.sampled_from([2, 3, 5]),
           max_tokens=st.integers(min_value=1, max_value=4))
    def test_paged_engine_random_boundaries(paged_setup, page_size, plen,
                                            chunk, max_tokens):
        """Randomized version of the boundary sweep."""
        _boundary_case(paged_setup, page_size, plen, chunk, max_tokens)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_paged_engine_random_boundaries():
        pass


# ---------------------------------------------------------------------------
# Lane-engine splice + admission bugfix regressions
# ---------------------------------------------------------------------------

def test_lane_engine_slots_equal_cache_size_and_heads(paged_setup):
    """slots == cache_size used to trigger a silent cache_size+1 bump (the
    shape-difference splice was ambiguous); slots == num_heads used to risk
    splicing the wrong axis. Explicit batch-axis metadata handles both."""
    with layers.compute_precision(jnp.float32):
        cfg, params, packs, mt = paged_setup
        toks = np.asarray(jax.random.randint(jax.random.PRNGKey(12), (5,),
                                             0, cfg.vocab_size))
        want = reference(mt, cfg, toks, None, 4)
        assert cfg.num_heads == 4
        for slots, cache_size in ((8, 8), (cfg.num_heads, 12)):
            se = ServingEngine(cfg, params, slots=slots,
                               cache_size=cache_size)
            assert se.cache_size == cache_size     # no silent +1
            fut = se.submit(toks, None, max_tokens=4)
            se.run()
            np.testing.assert_array_equal(fut.result(), want[:4])


def test_lane_engine_exact_fit_boundary(paged_setup):
    """need = prompt + max_tokens - 1: the final generated token is never
    written back, so an exactly-sized cache must be accepted (and one less
    rejected)."""
    with layers.compute_precision(jnp.float32):
        cfg, params, packs, mt = paged_setup
        toks = np.asarray(jax.random.randint(jax.random.PRNGKey(13), (6,),
                                             0, cfg.vocab_size))
        want = reference(mt, cfg, toks, None, 5)
        se = ServingEngine(cfg, params, slots=1, cache_size=10)
        fut = se.submit(toks, None, max_tokens=5)   # needs exactly 10 rows
        se.run()
        np.testing.assert_array_equal(fut.result(), want[:5])
        se2 = ServingEngine(cfg, params, slots=1, cache_size=9)
        with pytest.raises(ValueError, match="cache slots"):
            se2.submit(toks, None, max_tokens=5)
        # the paged engine applies the same bound in pages
        pe = PagedServingEngine(cfg, params, slots=1, num_pages=8,
                                page_size=5, max_len=10, chunk_size=5)
        pf = pe.submit(toks, None, max_tokens=5)    # 10 rows = max_len
        pe.run()
        np.testing.assert_array_equal(pf.result(), want[:5])
        with pytest.raises(ValueError, match="KV rows"):
            pe.submit(toks, None, max_tokens=6)
