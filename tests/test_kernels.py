"""Pallas kernels vs pure-jnp oracles, swept over shapes/dtypes
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("shape,bn,bm", [
    ((256, 256), 128, 128),
    ((512, 768), 256, 256),
    ((384, 512), 128, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("alpha", [1.0, -1.0, 0.5])
def test_scatter_apply(shape, bn, bm, dtype, alpha):
    rng = np.random.RandomState(hash((shape, str(dtype))) % 2**31)
    n, m = shape
    k = max(int(0.02 * n * m), 4)
    w = jnp.asarray(rng.randn(n, m), dtype)
    idx = np.unique(rng.randint(0, n * m, 2 * k))[:k]
    vals = rng.randn(len(idx)).astype(np.float32)
    counts, rows, cols, vbuf = ops.bucket_updates(idx, vals, n, m, bn=bn, bm=bm)
    out = ops.scatter_apply(w, jnp.asarray(counts), jnp.asarray(rows),
                            jnp.asarray(cols), jnp.asarray(vbuf), alpha,
                            bn=bn, bm=bm, interpret=True)
    want = ref.scatter_apply_ref(w, jnp.asarray(idx), jnp.asarray(vals), alpha)
    tol = 1e-6 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_scatter_apply_empty_tiles_untouched():
    """Struct-style masks: tiles without updates must be bit-identical."""
    rng = np.random.RandomState(0)
    n, m, bn, bm = 512, 512, 256, 256
    w = jnp.asarray(rng.randn(n, m), jnp.float32)
    # updates only in the top-left tile
    idx = (rng.randint(0, bn, 50) * m + rng.randint(0, bm, 50)).astype(np.int64)
    idx = np.unique(idx)
    vals = rng.randn(len(idx)).astype(np.float32)
    counts, rows, cols, vbuf = ops.bucket_updates(idx, vals, n, m, bn=bn, bm=bm)
    assert counts[0, 0] == len(idx) and counts[1, 1] == 0
    out = ops.scatter_apply(w, jnp.asarray(counts), jnp.asarray(rows),
                            jnp.asarray(cols), jnp.asarray(vbuf), 1.0,
                            bn=bn, bm=bm, interpret=True)
    np.testing.assert_array_equal(np.asarray(out[bn:, bm:]),
                                  np.asarray(w[bn:, bm:]))


def test_scatter_load_unload_roundtrip():
    rng = np.random.RandomState(1)
    n = m = 512
    w = jnp.asarray(rng.randn(n, m), jnp.float32)
    idx = np.unique(rng.randint(0, n * m, 4000))
    vals = rng.randn(len(idx)).astype(np.float32)
    args = [jnp.asarray(a) for a in ops.bucket_updates(idx, vals, n, m)]
    loaded = ops.scatter_apply(w, *args, 1.0, interpret=True)
    restored = ops.scatter_apply(loaded, *args, -1.0, interpret=True)
    np.testing.assert_allclose(np.asarray(restored), np.asarray(w), atol=1e-5)


def test_scatter_apply_vs_dense_np_reference():
    """Interpret-mode kernel vs a plain numpy dense scatter-add."""
    rng = np.random.RandomState(7)
    n, m = 512, 512
    w = rng.randn(n, m).astype(np.float32)
    idx = np.unique(rng.randint(0, n * m, 3000))
    vals = rng.randn(len(idx)).astype(np.float32)
    alpha = 0.75
    args = [jnp.asarray(a) for a in ops.bucket_updates(idx, vals, n, m)]
    out = ops.scatter_apply(jnp.asarray(w), *args, alpha, interpret=True)
    want = w.copy()
    want.reshape(-1)[idx] += alpha * vals
    np.testing.assert_allclose(np.asarray(out), want, atol=1e-6)


@pytest.mark.parametrize("B,S,n,m,A,K", [
    (4, 1, 64, 64, 3, 33),       # decode-step shape
    (3, 16, 128, 64, 4, 129),    # prefill shape, non-square
    (2, 8, 96, 160, 1, 7),       # single adapter
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sidedelta_parity(B, S, n, m, A, K, dtype):
    rng = np.random.RandomState(hash((B, S, n, m, A, K)) % 2**31)
    x = jnp.asarray(rng.randn(B, S, n), dtype)
    rows = jnp.asarray(rng.randint(0, n, (A, K)), jnp.int32)
    cols = jnp.asarray(rng.randint(0, m, (A, K)), jnp.int32)
    vals = jnp.asarray(rng.randn(A, K), jnp.float32)
    ids = jnp.asarray(rng.randint(-1, A, (B,)), jnp.int32)
    out = ops.sidedelta(x, rows, cols, vals, ids, m=m, interpret=True)
    want = ref.sidedelta_ref(x, rows, cols, vals, ids, m)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_sidedelta_base_requests_untouched():
    """ids = -1 must yield an exactly-zero delta row."""
    rng = np.random.RandomState(8)
    x = jnp.asarray(rng.randn(3, 4, 32), jnp.float32)
    rows = jnp.asarray(rng.randint(0, 32, (2, 11)), jnp.int32)
    cols = jnp.asarray(rng.randint(0, 16, (2, 11)), jnp.int32)
    vals = jnp.asarray(rng.randn(2, 11), jnp.float32)
    ids = jnp.asarray([-1, 0, -1], jnp.int32)
    out = np.asarray(ops.sidedelta(x, rows, cols, vals, ids, m=16,
                                   interpret=True))
    assert np.all(out[0] == 0) and np.all(out[2] == 0)
    assert np.any(out[1] != 0)


@pytest.mark.parametrize("B,S,n,m,A,K,bm", [
    (3, 8, 128, 512, 3, 65, 128),     # dense MLP-ish: 4 m-tiles
    (2, 4, 96, 384, 2, 33, 128),      # MoE expert-ish: odd n, 3 m-tiles
    (2, 2, 160, 576, 2, 47, 256),     # MLA-ish: non-pow2 dims, ragged tile
])
@pytest.mark.parametrize("interpret", [True, False])
def test_sidedelta_tiled_parity(B, S, n, m, A, K, bm, interpret):
    """v2 tiling: multi-m-tile grids must match the oracle in BOTH the
    Pallas interpreter and compiled mode (on CPU the latter dispatches the
    same tile plan through XLA — the interpret=False smoke that guards the
    tiling/masking bookkeeping)."""
    rng = np.random.RandomState(hash((B, S, n, m, A, K)) % 2**31)
    x = jnp.asarray(rng.randn(B, S, n), jnp.float32)
    rows = jnp.asarray(rng.randint(0, n, (A, K)), jnp.int32)
    cols = jnp.asarray(rng.randint(0, m, (A, K)), jnp.int32)
    vals = jnp.asarray(rng.randn(A, K), jnp.float32)
    ids = jnp.asarray(rng.randint(-1, A, (B,)), jnp.int32)
    out = ops.sidedelta(x, rows, cols, vals, ids, m=m, interpret=interpret,
                        bm=bm, kc=128)
    want = ref.sidedelta_ref(x, rows, cols, vals, ids, m)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want, np.float32),
                               atol=1e-5, rtol=1e-5)


def test_sidedelta_compiled_big_dff():
    """The acceptance shape: m=8192 with interpret=False on CPU. The VMEM
    plan must actually m-tile (bm < m) and the compiled result must match
    the oracle to fp32 accuracy."""
    from repro.kernels.sidedelta import (DEFAULT_VMEM_BUDGET, plan_tiles,
                                         vmem_estimate)
    B, S, n, m, A, K = 2, 8, 256, 8192, 2, 1024
    bm, kc = plan_tiles(S, n, m, K)
    assert bm < m and m % bm == 0, (bm, m)
    assert vmem_estimate(S, n, m, K, bm, kc) <= DEFAULT_VMEM_BUDGET
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(B, S, n), jnp.float32)
    rows = jnp.asarray(rng.randint(0, n, (A, K)), jnp.int32)
    cols = jnp.asarray(rng.randint(0, m, (A, K)), jnp.int32)
    vals = jnp.asarray(rng.randn(A, K), jnp.float32)
    ids = jnp.asarray([1, -1], jnp.int32)
    out = ops.sidedelta(x, rows, cols, vals, ids, m=m, interpret=False)
    want = ref.sidedelta_ref(x, rows, cols, vals, ids, m)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want, np.float32),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("interpret", [True, False])
def test_sidedelta_tile_straddle(interpret):
    """Nonzeros ON the m-tile boundaries (last col of tile j, first col of
    tile j+1) and duplicate (row, col) pairs must land exactly once each —
    the local-column one-hot masks, it must not double-count or drop."""
    n, m, bm = 32, 256, 128
    rows = jnp.asarray([[0, 1, 2, 2, 3]], jnp.int32)
    cols = jnp.asarray([[127, 128, 255, 255, 0]], jnp.int32)  # edges + dup
    vals = jnp.asarray([[1.0, 2.0, 3.0, 4.0, 5.0]], jnp.float32)
    x = jnp.asarray(np.random.RandomState(3).randn(1, 4, n), jnp.float32)
    ids = jnp.asarray([0], jnp.int32)
    out = ops.sidedelta(x, rows, cols, vals, ids, m=m, interpret=interpret,
                        bm=bm, kc=128)
    want = ref.sidedelta_ref(x, rows, cols, vals, ids, m)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)
    # the duplicate (2, 255) really accumulated 3 + 4
    np.testing.assert_allclose(np.asarray(out)[0, :, 255],
                               np.asarray(x)[0, :, 2] * 7.0, rtol=1e-5)


@pytest.mark.parametrize("interpret", [True, False])
def test_sidedelta_int8_tables(interpret):
    """int8 vals + per-adapter scale + int16 indices: exact against the
    int8 oracle (dequant is the same f32 math), and within dequant
    tolerance (<1e-2) of the unquantized f32 reference at SHiRA-realistic
    value magnitudes."""
    rng = np.random.RandomState(5)
    B, S, n, m, A, K = 3, 8, 128, 512, 3, 200
    x = jnp.asarray(rng.randn(B, S, n), jnp.float32)
    rows = jnp.asarray(rng.randint(0, n, (A, K)), jnp.int16)
    cols = jnp.asarray(rng.randint(0, m, (A, K)), jnp.int16)
    vf = (0.05 * rng.randn(A, K)).astype(np.float32)   # adapter-scale values
    qs = [ops.quantize_table(vf[a]) for a in range(A)]
    vq = jnp.asarray(np.stack([q for q, _ in qs]))
    scale = jnp.asarray(np.array([s for _, s in qs], np.float32))
    assert vq.dtype == jnp.int8
    ids = jnp.asarray([0, -1, 2], jnp.int32)
    out = ops.sidedelta(x, rows, cols, vq, ids, m=m, scale=scale,
                        interpret=interpret, bm=256, kc=128)
    want_q = ref.sidedelta_int8_ref(x, rows, cols, vq, scale, ids, m)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want_q),
                               atol=1e-5, rtol=1e-5)
    want_f = ref.sidedelta_ref(x, rows.astype(jnp.int32),
                               cols.astype(jnp.int32), jnp.asarray(vf),
                               ids, m)
    assert float(np.max(np.abs(np.asarray(out) - np.asarray(want_f)))) < 1e-2
    assert np.all(np.asarray(out)[1] == 0)             # ids = -1 stays zero


def test_sidedelta_plan_tiles_budget():
    """The VMEM helper must respect its budget knob: a tighter budget
    yields a smaller m-tile, never a plan that misses the grid."""
    from repro.kernels.sidedelta import plan_tiles, vmem_estimate
    S, n, m, K = 16, 512, 16384, 2048
    big_bm, big_kc = plan_tiles(S, n, m, K, vmem_budget=8 << 20)
    small_bm, small_kc = plan_tiles(S, n, m, K, vmem_budget=2 << 20)
    assert small_bm <= big_bm
    assert vmem_estimate(S, n, m, K, small_bm, small_kc) <= 2 << 20
    for bm in (big_bm, small_bm):
        assert bm % 128 == 0 and (-(-m // 128) * 128) % bm == 0


def test_sidedelta_table_roundtrip():
    """Host prep: packed flat indices -> padded (rows, cols, vals)."""
    flat = np.asarray([5, 17, 33], np.int64)
    vals = np.asarray([1.0, -2.0, 3.0], np.float32)
    rows, cols, v = ops.sidedelta_table(flat, vals, m=16, pad_to=5)
    np.testing.assert_array_equal(rows, [0, 1, 2, 0, 0])
    np.testing.assert_array_equal(cols, [5, 1, 1, 0, 0])
    np.testing.assert_array_equal(v, [1.0, -2.0, 3.0, 0.0, 0.0])


@pytest.mark.parametrize("shape", [(256, 256), (512, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_masked_update(shape, dtype):
    rng = np.random.RandomState(2)
    n, m = shape
    w = jnp.asarray(rng.randn(n, m), dtype)
    mask = jnp.asarray(rng.rand(n, m) < 0.02, jnp.float32)
    vals = jnp.asarray(rng.randn(n, m), jnp.float32)
    out = ops.masked_update(w, mask, vals, 1.5, interpret=True)
    want = ref.masked_update_ref(w, mask, vals, 1.5)
    tol = 1e-6 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("k", [100, 2048, 5000])
@pytest.mark.parametrize("step", [1, 7])
def test_sparse_adamw(k, step):
    rng = np.random.RandomState(3)
    v = jnp.asarray(rng.randn(k), jnp.float32)
    g = jnp.asarray(rng.randn(k), jnp.float32)
    mu = jnp.asarray(rng.rand(k), jnp.float32)
    nu = jnp.asarray(rng.rand(k), jnp.float32)
    out = ops.sparse_adamw(v, g, mu, nu, jnp.asarray(step), lr=1e-2, wd=0.01,
                           interpret=True)
    want = ref.sparse_adamw_ref(v, g, mu, nu, lr=1e-2, b1=0.9, b2=0.999,
                                eps=1e-8, wd=0.01, step=step)
    for a, b in zip(out, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("B,KV,G,D,S,sb", [
    (1, 1, 1, 64, 512, 256),
    (2, 2, 4, 64, 1024, 512),
    (2, 1, 8, 128, 768, 256),   # MQA w/ padding (768 % 256 == 0)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode(B, KV, G, D, S, sb, dtype):
    rng = np.random.RandomState(4)
    q = jnp.asarray(rng.randn(B, KV, G, D), dtype)
    k = jnp.asarray(rng.randn(B, S, KV, D), dtype)
    v = jnp.asarray(rng.randn(B, S, KV, D), dtype)
    kv_len = S - 100
    out = ops.flash_decode(q, k, v, kv_len, sb=sb, interpret=True)
    want = ref.flash_decode_ref(q, k, v, kv_len)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_flash_decode_matches_model_attention():
    """Cross-check the kernel against the model's decode attention path."""
    from repro.models.attention import _attend_block
    rng = np.random.RandomState(5)
    B, KV, G, D, S = 2, 2, 2, 64, 512
    H = KV * G
    q4 = jnp.asarray(rng.randn(B, 1, H, D), jnp.float32)
    k4 = jnp.asarray(rng.randn(B, S, KV, D), jnp.float32)
    v4 = jnp.asarray(rng.randn(B, S, KV, D), jnp.float32)
    kv_len = 300
    model_out = _attend_block(q4, k4, v4, jnp.array([kv_len - 1]),
                              jnp.arange(S), causal=True, prefix_len=0,
                              kv_len=kv_len)  # (B, 1, H, D)
    qk = q4[:, 0].reshape(B, KV, G, D)
    kern = ops.flash_decode(qk, k4, v4, kv_len, sb=256, interpret=True)
    np.testing.assert_allclose(
        np.asarray(kern.reshape(B, H, D), np.float32),
        np.asarray(model_out[:, 0], np.float32), atol=2e-2, rtol=2e-2)
