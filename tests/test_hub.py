"""repro.hub: pack format v2, AdapterStore residency, ServingEngine.

Acceptance bars pinned here:
  * f32 save/load round trip is bit-exact; corrupted files are rejected.
  * int8 packs serve within 1e-2 of their f32 source while ``nbytes()``
    drops >= 3x.
  * ServingEngine continuous batching reproduces the fixed-batch
    multi-tenant engine token-for-token on the same request trace.
  * AdapterStore eviction respects the byte budget in LRU order.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.configs import AdapterConfig, get_smoke_config
from repro.core.adapters import AdapterPack
from repro.hub import (AdapterStore, PackFormatError, ServingEngine,
                       load_pack, peek_pack, save_pack)
from repro.hub.packio import QuantPack
from repro.models import layers, lm

TARGETS = ("wq", "wk", "wv", "wo", "w_up", "w_gate", "w_down")


def synth_pack(name="t0", seed=0, k=40, scale=0.05, lead=(3,), nm=(64, 48)):
    """A hand-built pack with stacked (lead) dims, no model needed."""
    rng = np.random.default_rng(seed)
    n, m = nm
    nl = int(np.prod(lead)) if lead else 1
    idx = np.stack([rng.choice(n * m, k, replace=False)
                    for _ in range(nl)]).astype(np.int32)
    val = (scale * rng.standard_normal((nl, k))).astype(np.float32)
    entries = {
        "stages/0/attn/wq": (jnp.asarray(idx.reshape(lead + (k,))),
                             jnp.asarray(val.reshape(lead + (k,)))),
        "embed/emb": (jnp.asarray(idx[:1].reshape(k)),
                      jnp.asarray(val[:1].reshape(k))),
    }
    return AdapterPack(name=name, entries=entries, alpha=0.75)


def dense_of(pack, path, size=64 * 48):
    idx, val = pack.entries[path]
    idxf = np.asarray(idx).reshape(-1, np.asarray(idx).shape[-1])
    valf = np.asarray(val, np.float64).reshape(idxf.shape)
    out = np.zeros((idxf.shape[0], size))
    for r in range(idxf.shape[0]):
        np.add.at(out[r], idxf[r], valf[r])
    return out


# ---------------------------------------------------------------------------
# Pack format v2
# ---------------------------------------------------------------------------

def test_pack_f32_roundtrip_bit_exact(tmp_path):
    p = synth_pack()
    f = save_pack(p, str(tmp_path / "t0.shpk"), values="f32")
    p2 = load_pack(f)
    assert p2.name == p.name and p2.alpha == p.alpha
    assert sorted(p2.entries) == sorted(p.entries)
    for path in p.entries:
        np.testing.assert_array_equal(np.asarray(p.entries[path][0]),
                                      np.asarray(p2.entries[path][0]))
        np.testing.assert_array_equal(
            np.asarray(p.entries[path][1]).view(np.uint32),
            np.asarray(p2.entries[path][1]).view(np.uint32))


def test_pack_bf16_roundtrip_tolerance(tmp_path):
    p = synth_pack()
    f = save_pack(p, str(tmp_path / "t0.shpk"), values="bf16")
    p2 = load_pack(f)
    for path in p.entries:
        np.testing.assert_array_equal(np.asarray(p.entries[path][0]),
                                      np.asarray(p2.entries[path][0]))
        np.testing.assert_allclose(np.asarray(p.entries[path][1]),
                                   np.asarray(p2.entries[path][1]),
                                   rtol=1e-2, atol=1e-3)


def test_pack_int8_roundtrip_tolerance_and_compression(tmp_path):
    p = synth_pack(k=120)
    f = save_pack(p, str(tmp_path / "t0.shpk"), values="int8")
    q = load_pack(f, dequantize=False)
    assert isinstance(q, QuantPack)
    # the acceptance bar: the resident quantized form is >= 3x smaller
    assert p.nbytes() / q.nbytes() >= 3.0
    dq = q.dequantize()
    assert dq.alpha == p.alpha
    for path in p.entries:
        # (idx, val) pairs are re-sorted on save: compare as dense deltas
        want, got = dense_of(p, path), dense_of(dq, path)
        # symmetric per-path quantization: error bounded by scale/2
        bound = np.max(np.abs(np.asarray(p.entries[path][1]))) / 127.0
        assert np.max(np.abs(want - got)) <= bound
    # dequantize=True yields an AdapterPack directly
    assert isinstance(load_pack(f), AdapterPack)


def test_pack_int8_handles_duplicate_padding_indices(tmp_path):
    # fuse_packs pads rows with (idx 0, val 0) duplicates; delta coding
    # must survive gap-0 runs
    idx = jnp.asarray([[0, 0, 0, 5, 900]], jnp.int32)
    val = jnp.asarray([[0.0, 0.0, 0.1, -0.2, 0.3]], jnp.float32)
    p = AdapterPack("dup", {"embed/emb": (idx, val)})
    f = save_pack(p, str(tmp_path / "dup.shpk"), values="int8")
    dq = load_pack(f)
    want = dense_of(p, "embed/emb", 1000)
    got = dense_of(dq, "embed/emb", 1000)
    np.testing.assert_allclose(want, got, atol=0.3 / 127 + 1e-9)


def test_pack_corrupted_checksum_rejected(tmp_path):
    p = synth_pack()
    f = save_pack(p, str(tmp_path / "t0.shpk"), values="int8")
    raw = bytearray(open(f, "rb").read())
    raw[-1] ^= 0xFF
    bad = tmp_path / "bad.shpk"
    bad.write_bytes(bytes(raw))
    with pytest.raises(PackFormatError, match="checksum"):
        load_pack(str(bad))
    # truncation is caught before the checksum
    (tmp_path / "trunc.shpk").write_bytes(bytes(raw[:-10]))
    with pytest.raises(PackFormatError, match="truncated"):
        load_pack(str(tmp_path / "trunc.shpk"))
    (tmp_path / "junk.shpk").write_bytes(b"not a pack at all......")
    with pytest.raises(PackFormatError, match="magic"):
        load_pack(str(tmp_path / "junk.shpk"))
    # truncation INSIDE the header region must also raise PackFormatError,
    # not struct/json errors
    good = bytes(open(f, "rb").read())
    for cut in (10, 30):
        (tmp_path / "hdr.shpk").write_bytes(good[:cut])
        with pytest.raises(PackFormatError):
            load_pack(str(tmp_path / "hdr.shpk"))


def test_peek_reads_header_only(tmp_path):
    p = synth_pack(name="peeked")
    f = save_pack(p, str(tmp_path / "p.shpk"), values="int8")
    h = peek_pack(f)
    assert h["name"] == "peeked" and h["values"] == "int8"
    assert "entries" in h


# ---------------------------------------------------------------------------
# AdapterStore
# ---------------------------------------------------------------------------

def test_store_lazy_load_and_lru_eviction(tmp_path):
    store_root = str(tmp_path / "store")
    packs = [synth_pack(name=f"a{i}", seed=i) for i in range(4)]
    one = load_pack(save_pack(packs[0], str(tmp_path / "probe.shpk"),
                              values="int8"), dequantize=False).nbytes()
    store = AdapterStore(store_root, budget_bytes=2 * one + one // 2)
    for p in packs:
        store.add(p, values="int8")
    assert store.names() == ["a0", "a1", "a2", "a3"]
    assert store.resident_bytes() == 0          # add() does not load

    store.get("a0")
    store.get("a1")
    assert store.loads == 2
    assert store.resident_names() == ["a0", "a1"]
    # budget fits 2 residents: loading a2 evicts the LRU (a0)
    store.get("a2")
    assert store.resident_names() == ["a1", "a2"]
    assert store.evictions == 1
    assert store.resident_bytes() <= store.budget_bytes
    # touching a1 then loading a3 evicts a2, not a1
    store.get("a1")
    store.get("a3")
    assert store.resident_names() == ["a1", "a3"]
    # evicted adapters reload transparently from disk
    p0 = store.get("a0")
    assert p0.name == "a0" and store.loads == 5


def test_store_get_matches_source_pack(tmp_path):
    p = synth_pack(name="exact")
    store = AdapterStore(str(tmp_path), budget_bytes=None)
    store.add(p, values="f32")
    got = store.get("exact")
    for path in p.entries:
        np.testing.assert_array_equal(np.asarray(p.entries[path][1]),
                                      np.asarray(got.entries[path][1]))


def test_store_unknown_and_memory_only(tmp_path):
    store = AdapterStore(root=None)
    p = synth_pack(name="mem")
    store.add(p)
    assert store.get("mem") is p                # memory-only: same handle
    with pytest.raises(KeyError, match="nope"):
        store.get("nope")


def test_store_memory_only_int8_stays_quantized(tmp_path):
    """values='int8' with no root must quantize in memory, not silently
    keep the f32 object resident."""
    p = synth_pack(name="q", k=120)
    store = AdapterStore(root=None)
    store.add(p, values="int8")
    assert store.resident_bytes() <= p.nbytes() / 3
    got = store.get("q")
    bound = max(np.max(np.abs(np.asarray(v))) for _, v in p.entries.values())
    for path in p.entries:
        assert np.max(np.abs(dense_of(p, path) - dense_of(got, path))) \
            <= bound / 127.0
    with pytest.raises(ValueError, match="bf16"):
        store.add(synth_pack(name="b"), values="bf16")


def test_store_register_file(tmp_path):
    p = synth_pack(name="reg")
    f = save_pack(p, str(tmp_path / "elsewhere.shpk"), values="int8")
    store = AdapterStore(str(tmp_path / "root"))
    name = store.register_file(f)
    assert name == "reg" and store.resident_bytes() == 0
    assert store.get("reg").num_params() == p.num_params()


# ---------------------------------------------------------------------------
# ServingEngine — continuous batching
# ---------------------------------------------------------------------------

def make_model_packs(cfg, params, n, seed=7, scale=0.05):
    acfg = AdapterConfig(kind="shira", mask="rand", sparsity=0.98,
                         target_modules=TARGETS)
    packs = []
    for i in range(n):
        sub = jax.random.fold_in(jax.random.PRNGKey(seed), i)
        values, aux = core.init_adapter(sub, params, acfg)
        values = jax.tree.map(
            lambda v: None if v is None
            else scale * jax.random.normal(sub, v.shape), values,
            is_leaf=lambda x: x is None)
        packs.append(core.pack_from_shira(f"a{i}", values, aux))
    return packs


@pytest.fixture(scope="module")
def serving_setup():
    with layers.compute_precision(jnp.float32):
        cfg = get_smoke_config("starcoder2-7b")
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        packs = make_model_packs(cfg, params, 3)
        yield cfg, params, packs


def test_continuous_batching_matches_fixed_batch(serving_setup):
    """The acceptance parity bar: the ServingEngine must reproduce the
    fixed-batch multi-tenant outputs token-for-token on the same trace —
    with fewer lanes than requests, mixed request lengths, and an adapter
    stack in the mix."""
    from repro.serving import MultiTenantEngine
    with layers.compute_precision(jnp.float32):
        cfg, params, packs = serving_setup
        B, S = 5, 8
        lens = [4, 2, 4, 3, 1]
        names = ["a0", "a2", None, ("a0", "a1"), "a0"]
        toks = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (B, S),
                                             0, cfg.vocab_size))
        mt = MultiTenantEngine(cfg, params)
        for p in packs:
            mt.register(p)
        want, _ = mt.generate({"tokens": jnp.asarray(toks)}, names,
                              max(lens))
        want = np.asarray(want)

        se = ServingEngine(cfg, params, slots=2, cache_size=S + max(lens) + 8)
        for p in packs:
            se.register(p)
        futs = [se.submit(toks[i], names[i], max_tokens=lens[i])
                for i in range(B)]
        se.run()
        for i, f in enumerate(futs):
            assert f.done()
            np.testing.assert_array_equal(f.result(), want[i][:lens[i]],
                                          err_msg=f"request {i}")
        # every request decoded exactly its own length: no drain barrier
        assert se.tokens_out == sum(lens)


def test_continuous_batching_mamba_arch():
    """The admission cache-splice recovers the batch axis per leaf — ssm
    stacks put it behind the scan dims, unlike KV caches."""
    from repro.serving import MultiTenantEngine
    with layers.compute_precision(jnp.float32):
        cfg = get_smoke_config("mamba2-780m")
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        packs = make_model_packs(cfg, params, 2)
        mt = MultiTenantEngine(cfg, params)
        for p in packs:
            mt.register(p)
        B, S, T = 4, 8, 3
        toks = np.asarray(jax.random.randint(jax.random.PRNGKey(2), (B, S),
                                             0, cfg.vocab_size))
        want = np.asarray(mt.generate({"tokens": jnp.asarray(toks)},
                                      ["a0", "a1", None, "a0"], T)[0])
        se = ServingEngine(cfg, params, slots=2, cache_size=S + T + 8)
        for p in packs:
            se.register(p)
        futs = [se.submit(toks[i], n, max_tokens=T)
                for i, n in enumerate(["a0", "a1", None, "a0"])]
        se.run()
        for i, f in enumerate(futs):
            np.testing.assert_array_equal(f.result(), want[i])


def test_serving_engine_eos_recycles_slot(serving_setup):
    with layers.compute_precision(jnp.float32):
        cfg, params, packs = serving_setup
        se = ServingEngine(cfg, params, slots=1, cache_size=32)
        se.register(packs[0])
        toks = np.asarray(jax.random.randint(jax.random.PRNGKey(2), (8,),
                                             0, cfg.vocab_size))
        # discover the greedy continuation, then replay with its second
        # token as EOS: the request must stop early and free the lane
        probe = se.submit(toks, "a0", max_tokens=4)
        se.run()
        full = probe.result()
        se2 = ServingEngine(cfg, params, slots=1, cache_size=32)
        se2.register(packs[0])
        f1 = se2.submit(toks, "a0", max_tokens=4, eos_id=int(full[1]))
        f2 = se2.submit(toks, "a0", max_tokens=2)
        se2.run()
        assert len(f1.result()) == 2 and int(f1.result()[1]) == int(full[1])
        assert len(f2.result()) == 2
        np.testing.assert_array_equal(f2.result(), full[:2])


def test_serving_engine_validation(serving_setup):
    with layers.compute_precision(jnp.float32):
        cfg, params, packs = serving_setup
        se = ServingEngine(cfg, params, slots=2, cache_size=16)
        se.register(packs[0])
        with pytest.raises(KeyError, match="unregistered"):
            se.submit(np.zeros(4, np.int32), "nope", max_tokens=2)
        with pytest.raises(ValueError, match="cache slots"):
            se.submit(np.zeros(12, np.int32), "a0", max_tokens=8)
        fut = se.submit(np.zeros(4, np.int32), "a0", max_tokens=2)
        with pytest.raises(RuntimeError, match="in flight"):
            fut.result()


def test_int8_pack_serves_within_tolerance(tmp_path, serving_setup):
    """Acceptance: f32 -> int8 -> load round trip serves with max logit
    deviation < 1e-2 while the pack's resident bytes drop >= 3x."""
    from repro.serving import MultiTenantEngine
    from repro.serving.multitenant import greedy_decode
    with layers.compute_precision(jnp.float32):
        cfg, params, packs = serving_setup
        pack = packs[0]
        f = save_pack(pack, str(tmp_path / "a0.shpk"), values="int8")
        q = load_pack(f, dequantize=False)
        assert pack.nbytes() / q.nbytes() >= 3.0
        B, S, T = 2, 8, 4
        toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                                  cfg.vocab_size)
        logits = {}
        for tag, pk in (("f32", pack), ("int8", q.dequantize())):
            eng = MultiTenantEngine(cfg, params)
            eng.register(pk)
            ids = eng.ids_for(["a0", "a0"])
            p = eng.wrapped_params(ids)
            _, lg = greedy_decode(
                cfg, {"tokens": toks}, T,
                lambda b: eng._prefill(p, b, S + T + 8),
                lambda t, c, pos: eng._decode(p, t, c, pos))
            logits[tag] = np.asarray(lg, np.float32)
        dev = float(np.max(np.abs(logits["f32"] - logits["int8"])))
        assert dev < 1e-2, f"int8 serving deviated by {dev}"


def test_serving_scheduler_sees_live_lanes_only(serving_setup):
    """Idle decode lanes must not count as base traffic in the FusedLRU
    shares: 2 live 'a0' requests on a 4-slot engine are 100% a0 traffic."""
    from repro.core.switching import FusedLRU
    with layers.compute_precision(jnp.float32):
        cfg, params, packs = serving_setup
        sched = FusedLRU(promote_at=0.9, decay=0.0)
        se = ServingEngine(cfg, params, slots=4, cache_size=24,
                           scheduler=sched)
        for p in packs:
            se.register(p)
        toks = np.asarray(jax.random.randint(jax.random.PRNGKey(5), (2, 8),
                                             0, cfg.vocab_size))
        futs = [se.submit(toks[i], "a0", max_tokens=3) for i in range(2)]
        se.run()
        assert all(f.done() for f in futs)
        assert sched.share.get("a0", 0.0) == pytest.approx(1.0)
        assert se.engine.fused == "a0"


def test_stack_slots_pruned_after_ttl(serving_setup):
    """Ad-hoc adapter-stack tenants must not grow the side tables forever:
    a stack idle for > stack_ttl batches loses its slot (and duplicate
    members collapse to a single adapter)."""
    from repro.serving import MultiTenantEngine
    with layers.compute_precision(jnp.float32):
        cfg, params, packs = serving_setup
        eng = MultiTenantEngine(cfg, params)
        for p in packs:
            eng.register(p)
        eng.stack_ttl = 3
        eng.ids_for([("a0", "a1"), "a2"])
        assert ("a0", "a1") in eng._slots
        # duplicates normalize away: ("a0","a0") is the plain "a0" tenant
        ids = eng.ids_for([("a0", "a0")])
        assert int(ids[0]) == eng._slots["a0"]
        for _ in range(4):
            eng.ids_for(["a2"])
        assert ("a0", "a1") not in eng._slots
        assert ("a0", "a1") not in eng._stacks


def test_serving_engine_via_store_lazy_registration(tmp_path, serving_setup):
    """submit() resolves adapters it has never seen through the store."""
    with layers.compute_precision(jnp.float32):
        cfg, params, packs = serving_setup
        store = AdapterStore(str(tmp_path), budget_bytes=None)
        for p in packs:
            store.add(p, values="f32")
        se = ServingEngine(cfg, params, slots=2, cache_size=24, store=store)
        toks = np.asarray(jax.random.randint(jax.random.PRNGKey(4), (8,),
                                             0, cfg.vocab_size))
        fut = se.submit(toks, "a1", max_tokens=3)   # never register()ed
        se.run()
        assert len(fut.result()) == 3
        assert store.loads >= 1
