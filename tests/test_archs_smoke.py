"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, output shapes + no NaNs; decode==prefill consistency where applicable."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, applicable_shapes, get_config, get_smoke_config
from repro.models import lm


def tiny_batch(cfg, B=2, S=64, key=0):
    k = jax.random.PRNGKey(key)
    if cfg.modality == "audio":
        return {"frame_embeds": jax.random.normal(k, (B, S, cfg.d_model)) * 0.02,
                "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.modality == "vision":
        P = cfg.num_prefix_embeds
        return {"tokens": jax.random.randint(k, (B, S - P), 0, cfg.vocab_size),
                "patch_embeds": jax.random.normal(k, (B, P, cfg.d_model)) * 0.02,
                "labels": jnp.ones((B, S - P), jnp.int32)}
    return {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
            "labels": jnp.ones((B, S), jnp.int32)}


@pytest.mark.smoke
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = tiny_batch(cfg)
    loss, metrics = jax.jit(lambda p, b: lm.train_loss(p, cfg, b))(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    grads = jax.jit(jax.grad(lambda p: lm.train_loss(p, cfg, tiny_batch(cfg))[0]))(params)
    for leaf in jax.tree.leaves(grads):
        assert jnp.all(jnp.isfinite(leaf)), f"{arch}: non-finite grad"


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if not get_config(a).encoder_only])
@pytest.mark.smoke
def test_smoke_decode_matches_prefill(arch):
    cfg = get_smoke_config(arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + 1), 0,
                              cfg.vocab_size)
    extra = cfg.num_prefix_embeds if cfg.modality == "vision" else 0
    pe = (jax.random.normal(jax.random.PRNGKey(3), (B, extra, cfg.d_model))
          * 0.02 if extra else None)
    mk = lambda t: ({"tokens": t, "patch_embeds": pe} if extra
                    else {"tokens": t})
    cache_size = S + 8 + extra
    _, caches = jax.jit(lambda p, t: lm.prefill(p, cfg, mk(t), cache_size))(
        params, toks[:, :S])
    ld, _ = jax.jit(lambda p, t, c: lm.decode_step(p, cfg, t, c, S + extra))(
        params, toks[:, S:S + 1], caches)
    lr_, _ = jax.jit(lambda p, t: lm.prefill(p, cfg, mk(t), cache_size))(
        params, toks[:, :S + 1])
    rel = float(jnp.max(jnp.abs(ld - lr_))) / (float(jnp.max(jnp.abs(lr_))) + 1e-9)
    assert rel < 0.03, f"{arch}: decode/prefill mismatch rel={rel}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_well_formed(arch):
    cfg = get_config(arch)
    assert cfg.padded_vocab % 256 == 0
    assert cfg.padded_vocab >= cfg.vocab_size
    shapes = {s.name for s in applicable_shapes(arch)}
    assert "train_4k" in shapes and "prefill_32k" in shapes
    if cfg.encoder_only:
        assert "decode_32k" not in shapes
    if not cfg.subquadratic:
        assert "long_500k" not in shapes
    else:
        assert "long_500k" in shapes
    # abstract params build without allocation and match analytic count ±20%
    from repro.launch.steps import abstract_params
    from repro.analysis.roofline import count_params
    import numpy as np
    p = abstract_params(cfg)
    n = sum(int(np.prod(x.shape, dtype=np.int64)) for x in jax.tree.leaves(p))
    analytic = count_params(cfg)["total"]
    assert abs(n - analytic) / analytic < 0.2, (arch, n, analytic)
