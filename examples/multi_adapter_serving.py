"""Multi-adapter serving: the paper's deployment story end to end.

Trains two SHiRA adapters on different tasks, then serves a stream of
batched requests where each request names its adapter — the engine
rapid-switches between them (sparse scatter), and finally serves both
FUSED (naive addition, Fig. 3(b)) to handle mixed-task traffic.

  PYTHONPATH=src python examples/multi_adapter_serving.py
"""
import time

import jax
import jax.numpy as jnp

from repro import core
from repro.configs import AdapterConfig, RunConfig, TrainConfig, get_smoke_config
from repro.configs.base import ShapeSpec
from repro.data import TaskSpec, batch_iterator, make_batch
from repro.models import lm
from repro.runtime import Trainer
from repro.runtime.trainer import TrainerConfig

cfg = get_smoke_config("starcoder2-7b")
shape = ShapeSpec("serve", 64, 8, "train")
adapter = AdapterConfig(kind="shira", mask="wm", sparsity=0.95)
run = RunConfig(model=cfg, shape=shape, adapter=adapter,
                train=TrainConfig(learning_rate=2e-2, total_steps=60,
                                  warmup_steps=3))

print("== training one adapter per task ==")
packs, base = {}, None
for task in (1, 2):
    tr = Trainer(run, TrainerConfig())
    out = tr.fit(60, batches=batch_iterator(cfg, shape, seed=0,
                                            task=TaskSpec(task_id=task)),
                 log=None)
    packs[task] = tr.export_pack(out["state"], name=f"task{task}")
    base = tr.base
    print(f"  task{task}: loss {out['history'][0]['loss']:.3f} -> "
          f"{out['history'][-1]['loss']:.3f}")

engine = core.SwitchEngine(base)
loss_fn = jax.jit(lambda p, b: lm.train_loss(p, cfg, b)[0])


def handle_request(task: int) -> float:
    """Route a request: switch to its adapter if not active, then serve."""
    active = engine.active[-1].name if engine.active else None
    if active != f"task{task}":
        st = engine.switch(packs[task])
        print(f"  [switch] -> task{task} in {st.seconds*1e3:.1f}ms")
    b = {k: jnp.asarray(v) for k, v in
         make_batch(cfg, shape, seed=42, step=task,
                    task=TaskSpec(task_id=task)).items()}
    return float(loss_fn(engine.params, b))


print("\n== request stream with per-request adapters ==")
for task in (1, 1, 2, 2, 1, 2):
    l = handle_request(task)
    print(f"  request(task{task}) loss={l:.4f}")

print("\n== multi-adapter fusion (both tasks, one deployed model) ==")
while engine.active:
    engine.unload()
engine.load_fused([packs[1], packs[2]])
for task in (1, 2):
    b = {k: jnp.asarray(v) for k, v in
         make_batch(cfg, shape, seed=42, step=task,
                    task=TaskSpec(task_id=task)).items()}
    print(f"  fused model on task{task}: loss={float(loss_fn(engine.params, b)):.4f}")
ov = core.index_overlap(packs[1], packs[2])
import numpy as np
print(f"  mask index overlap (why fusion works): "
      f"{np.mean(list(ov.values())):.3%}")
