"""The adapter lifecycle end to end: train -> pack -> store -> serve.

One artifact — a SHiRA ``AdapterPack`` — flows through every stage of
``repro.hub``:

  1. pack:  synthetic "trained" adapters are packed (1-2% of the weights).
  2. store: serialized to disk in format v2 (int8: ~2 bytes/nonzero, vs 8
     for f32) and registered with an ``AdapterStore`` under a byte budget,
     so only the working set stays resident.
  3. serve: a continuous-batching ``ServingEngine`` resolves adapter ids
     through the store — requests submit individually, lanes recycle on
     completion, and an adapter *stack* request ("tenant_0"+"tenant_1")
     rides the same batch.
  4. switch: the same store feeds ``SwitchEngine`` for the paper's rapid
     single-tenant switch.

  PYTHONPATH=src python examples/adapter_hub.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.configs import AdapterConfig, get_smoke_config
from repro.hub import AdapterStore, ServingEngine, load_pack
from repro.models import layers, lm

cfg = get_smoke_config("starcoder2-7b")

with layers.compute_precision(jnp.float32):
    params = lm.init_params(cfg, jax.random.PRNGKey(0))

    print("== 1. pack: three tenants' SHiRA adapters ==")
    acfg = AdapterConfig(kind="shira", mask="rand", sparsity=0.98,
                         target_modules=("wq", "wk", "wv", "wo",
                                         "w_up", "w_gate", "w_down"))
    packs = []
    for i in range(3):
        sub = jax.random.fold_in(jax.random.PRNGKey(7), i)
        values, aux = core.init_adapter(sub, params, acfg)
        values = jax.tree.map(
            lambda v: None if v is None
            else 0.05 * jax.random.normal(sub, v.shape), values,
            is_leaf=lambda x: x is None)
        packs.append(core.pack_from_shira(f"tenant_{i}", values, aux))

    print("\n== 2. store: int8 pack files under a residency budget ==")
    root = tempfile.mkdtemp(prefix="adapter-hub-")
    store = AdapterStore(root, budget_bytes=2 * packs[0].nbytes())
    for p in packs:
        store.add(p, values="int8")
        q = load_pack(f"{root}/{p.name}.shpk", dequantize=False)
        print(f"  {p.name}: {p.nbytes()/1e3:6.1f}kB f32 -> "
              f"{q.nbytes()/1e3:6.1f}kB int8 on disk "
              f"({p.nbytes()/q.nbytes():.1f}x smaller)")

    print("\n== 3. serve: continuous batching, adapter ids + stacks ==")
    engine = ServingEngine(cfg, params, slots=3, store=store, cache_size=40)
    rng = np.random.default_rng(0)
    tenants = ["tenant_0", "tenant_1", None, "tenant_2",
               ("tenant_0", "tenant_1"), "tenant_1"]
    futs = []
    for r, who in enumerate(tenants):
        toks = jax.random.randint(jax.random.fold_in(jax.random.PRNGKey(1),
                                                     r), (16,), 0,
                                  cfg.vocab_size)
        futs.append(engine.submit(toks, who, max_tokens=int(
            rng.integers(4, 9))))
    dt = engine.run()
    for f in futs:
        who = "+".join(f.adapter) if isinstance(f.adapter, tuple) \
            else (f.adapter or "base")
        print(f"  req {f.rid} [{who:19s}] -> {len(f.result())} tokens")
    print(f"  {engine.tokens_out} tokens in {dt*1e3:.0f}ms "
          f"({engine.tokens_out/dt:.1f} tok/s); store: loads={store.loads} "
          f"evictions={store.evictions} "
          f"resident={store.resident_bytes()/1e3:.1f}kB")

    print("\n== 4. switch: the same store feeds rapid switching ==")
    sw = core.SwitchEngine(params, store=store)
    st = sw.switch("tenant_2")            # by id: store resolves the pack
    print(f"  switched to tenant_2 in {st.seconds*1e3:.1f}ms "
          f"({st.entries_written} entries, "
          f"{st.bytes_written/1e3:.0f}kB moved vs "
          f"{st.weight_bytes_total/1e6:.0f}MB of weights)")
