"""Multi-tenant serving: every request brings its own SHiRA adapter.

The sequential demo (examples/multi_adapter_serving.py) switches the ONE
deployed model between adapters — requests for different tenants can never
share a batch. This demo serves a mixed-tenant request batch in a single
forward pass: the base weights stay shared, and each request's sparse
adapter delta rides along as a batched side term computed by the Pallas
``sidedelta`` kernel (y[b] += x[b] @ dW_adapter(b)).

It then streams skewed traffic so the ``FusedLRU`` scheduler promotes the
hot adapter INTO the shared base (one sparse scatter) and serves the rest
with diff packs — and finally verifies both paths agree with sequential
switching, token for token.

  PYTHONPATH=src python examples/multi_tenant_serving.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.configs import AdapterConfig, get_smoke_config
from repro.core.switching import FusedLRU
from repro.models import layers, lm
from repro.serving import MultiTenantEngine

cfg = get_smoke_config("starcoder2-7b")

# f32 so the parity printout is exact rather than bf16-fuzzy
with layers.compute_precision(jnp.float32):
    params = lm.init_params(cfg, jax.random.PRNGKey(0))

    print("== registering 3 tenants (synthetic SHiRA packs, 2% dense) ==")
    acfg = AdapterConfig(kind="shira", mask="rand", sparsity=0.98,
                         target_modules=("wq", "wk", "wv", "wo",
                                         "w_up", "w_gate", "w_down"))
    packs = []
    for i in range(3):
        sub = jax.random.fold_in(jax.random.PRNGKey(7), i)
        values, aux = core.init_adapter(sub, params, acfg)
        values = jax.tree.map(
            lambda v: None if v is None
            else 0.05 * jax.random.normal(sub, v.shape), values,
            is_leaf=lambda x: x is None)
        packs.append(core.pack_from_shira(f"tenant_{i}", values, aux))
    engine = MultiTenantEngine(cfg, params, scheduler=FusedLRU())
    for p in packs:
        engine.register(p)
        print(f"  {p.name}: {p.num_params()} sparse entries "
              f"({p.nbytes() / 1e3:.0f}KB)")

    B, S, T = 6, 16, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    names = ["tenant_0", "tenant_2", None, "tenant_1", "tenant_0",
             "tenant_2"]

    print("\n== one batch, four tenants (incl. base), one forward pass ==")
    out_mt, dt = engine.generate({"tokens": toks}, names, T)
    print(f"  {B}x{T} tokens in {dt * 1e3:.0f}ms "
          f"({B * T / dt:.1f} tok/s), 0 weight switches")

    # sequential reference: switch -> serve, one request at a time
    from repro.serving.multitenant import switch_per_request_reference
    seq, _, dt_seq = switch_per_request_reference(cfg, params, packs, toks,
                                                  names, T)
    same = np.array_equal(np.asarray(out_mt), seq)
    print(f"  sequential switching: {dt_seq * 1e3:.0f}ms, "
          f"{len([n for n in names if n])} switches — tokens equal: {same}")

    print("\n== skewed traffic: the scheduler fuses the hot tenant ==")
    for step in range(3):
        hot = ["tenant_1"] * 4 + ["tenant_0", None]
        out, dt = engine.generate({"tokens": toks}, hot, T)
        print(f"  batch {step}: fused={engine.fused} "
              f"({engine.fuse_transitions} transitions) "
              f"{B * T / dt:.1f} tok/s")
    assert engine.fused == "tenant_1"
    out_fused, _ = engine.generate({"tokens": toks}, names, T)
    print(f"  mixed batch with tenant_1 fused — tokens still equal: "
          f"{np.array_equal(np.asarray(out_fused), np.asarray(out_mt))}")
