"""End-to-end training driver: ~100M-param dense LM, a few hundred steps.

Full-model pretraining for a while, then SHiRA adapter finetuning on a new
task — the paper's workflow at container scale. Expect ~15-40 min on CPU;
pass --quick for a 2-minute version.

  PYTHONPATH=src python examples/train_adapter.py [--quick]
"""
import argparse
import sys

from repro.configs import AdapterConfig, RunConfig, TrainConfig
from repro.configs.base import ShapeSpec
from repro.data import TaskSpec, batch_iterator
from repro.launch.train import PRESET_100M
from repro.runtime import Trainer
from repro.runtime.trainer import TrainerConfig

ap = argparse.ArgumentParser()
ap.add_argument("--quick", action="store_true")
ap.add_argument("--ckpt-dir", default="/tmp/shira_100m_ckpt")
args = ap.parse_args()

cfg = PRESET_100M if not args.quick else PRESET_100M.replace(
    num_layers=4, d_model=256, num_heads=4, num_kv_heads=4, d_ff=1024)
steps = 300 if not args.quick else 40
shape = ShapeSpec("ex", seq_len=256 if not args.quick else 64,
                  global_batch=8, kind="train")

# Phase 1: pretrain the base (full finetune mode) -----------------------------
print(f"== phase 1: pretraining {cfg.name} "
      f"({cfg.num_layers}L d={cfg.d_model}) for {steps} steps ==")
run = RunConfig(model=cfg, shape=shape, adapter=AdapterConfig(kind="none"),
                train=TrainConfig(learning_rate=3e-4, total_steps=steps,
                                  warmup_steps=max(steps // 20, 1),
                                  schedule="cosine"))
tr = Trainer(run, TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=50,
                                log_every=max(steps // 15, 1)))
out = tr.fit(steps, batches=batch_iterator(cfg, shape, seed=0,
                                           task=TaskSpec(task_id=0)))
base = out["state"]["trainable"]
print(f"pretrain loss: {out['history'][0]['loss']:.4f} -> "
      f"{out['history'][-1]['loss']:.4f}")

# Phase 2: SHiRA adapter on a NEW task ----------------------------------------
steps2 = steps // 2
print(f"\n== phase 2: SHiRA-SNIP adapter on task 7 for {steps2} steps ==")
import jax
from repro.data import make_batch
import jax.numpy as jnp
from repro.models import lm
calib = {k: jnp.asarray(v) for k, v in
         make_batch(cfg, shape, seed=1, step=0, task=TaskSpec(task_id=7)).items()}
calib_grads = jax.grad(lambda p: lm.train_loss(p, cfg, calib)[0])(base)

run2 = RunConfig(model=cfg, shape=shape,
                 adapter=AdapterConfig(kind="shira", mask="snip",
                                       sparsity=0.99),
                 train=TrainConfig(learning_rate=5e-3, total_steps=steps2,
                                   warmup_steps=max(steps2 // 20, 1)))
tr2 = Trainer(run2, TrainerConfig(log_every=max(steps2 // 10, 1)),
              calib_grads=calib_grads,
              base_params=base)  # adapt the pretrained weights
out2 = tr2.fit(steps2, batches=batch_iterator(cfg, shape, seed=0,
                                              task=TaskSpec(task_id=7)))
print(f"adapter loss: {out2['history'][0]['loss']:.4f} -> "
      f"{out2['history'][-1]['loss']:.4f}")
pack = tr2.export_pack(out2["state"], name="task7-snip")
print(f"exported pack: {pack.num_params()} params ({pack.nbytes()/1e6:.2f}MB)")
