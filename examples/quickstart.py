"""Quickstart: SHiRA in ~60 lines.

Builds a small causal LM, finetunes a SHiRA-WM adapter (1% of weights) on a
synthetic task, exports the sparse pack, and rapid-switches it on a deployed
copy of the base model.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import core
from repro.configs import AdapterConfig, RunConfig, TrainConfig, get_smoke_config
from repro.configs.base import ShapeSpec
from repro.data import TaskSpec, batch_iterator, make_batch
from repro.models import lm
from repro.runtime import Trainer
from repro.runtime.trainer import TrainerConfig

# 1. model + adapter config ---------------------------------------------------
cfg = get_smoke_config("starcoder2-7b")          # reduced config, runs on CPU
shape = ShapeSpec("demo", seq_len=64, global_batch=8, kind="train")
adapter = AdapterConfig(kind="shira", mask="wm", sparsity=0.99)  # 1% trainable
run = RunConfig(model=cfg, shape=shape, adapter=adapter,
                train=TrainConfig(learning_rate=2e-2, total_steps=60,
                                  warmup_steps=3))

# 2. finetune the adapter (packed mode: optimizer state only on the 1%) -------
trainer = Trainer(run, TrainerConfig(log_every=20))
out = trainer.fit(60, batches=batch_iterator(cfg, shape, seed=0,
                                             task=TaskSpec(task_id=1)))
pack = trainer.export_pack(out["state"], name="task1")
print(f"adapter pack: {pack.num_params()} params, {pack.nbytes()/1e3:.1f}KB "
      f"(model is {sum(x.size for x in jax.tree.leaves(trainer.base))/1e3:.0f}K params)")

# 3. rapid switching on a deployed model --------------------------------------
engine = core.SwitchEngine(trainer.base)

def task_loss(task):
    b = {k: jnp.asarray(v) for k, v in
         make_batch(cfg, shape, seed=9, step=0, task=TaskSpec(task_id=task)).items()}
    return float(lm.train_loss(engine.params, cfg, b)[0])

print(f"base model loss on task1:    {task_loss(1):.4f}")
st = engine.switch(pack)                          # sparse scatter, no fuse
print(f"switched in {st.seconds*1e3:.1f}ms ({st.entries_written} entries)")
print(f"adapted model loss on task1: {task_loss(1):.4f}")
engine.unload()                                   # base restored exactly
print(f"base restored, loss again:   {task_loss(1):.4f}")
