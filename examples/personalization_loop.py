"""Continuous personalization end to end: train -> publish -> hot-swap.

The closed loop this repo's training subsystem adds (see
src/repro/training/README.md):

  1. train:   ``MultiAdapterTrainer`` finetunes three users' SHiRA
              adapters CONCURRENTLY — one jitted step, shared base
              matmuls, per-adapter routing via the sidedelta tables —
              with int8-quantized optimizer moments.
  2. publish: ``trainer.publish`` pushes each adapter into the
              ``AdapterStore`` as a versioned id (``user@1``) and
              snapshots it into the checkpoint step dir.
  3. serve:   a live ``ServingEngine`` resolves bare names newest-wins;
              requests decode with per-request side-deltas.
  4. loop:    more training, publish again (``user@2``) — WHILE requests
              are in flight. In-flight requests finish on the version
              they arrived on, token-for-token identical; new requests
              land on the new version; the superseded version is retired
              once its last request drains.

  PYTHONPATH=src python examples/personalization_loop.py --smoke
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import AdapterConfig, RunConfig, TrainConfig, get_smoke_config
from repro.configs.base import ShapeSpec
from repro.hub import AdapterStore, ServingEngine
from repro.models import layers, lm
from repro.training import MultiAdapterTrainer

USERS = ["alice", "bob", "carol"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + few steps (CI tier-2)")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()
    steps = args.steps or (4 if args.smoke else 20)
    shape = (ShapeSpec("tiny", 8, 8, "train") if args.smoke
             else ShapeSpec("small", 32, 16, "train"))

    run = RunConfig(
        model=get_smoke_config("starcoder2-7b"), shape=shape,
        adapter=AdapterConfig(kind="shira", mask="rand", sparsity=0.95),
        train=TrainConfig(learning_rate=1e-2, total_steps=2 * steps,
                          warmup_steps=2))

    with layers.compute_precision(jnp.float32):
        print(f"== 1. train: {len(USERS)} adapters in one jitted step "
              "(int8 optimizer moments) ==")
        mt = MultiAdapterTrainer(run, USERS, moments="int8")
        out = mt.fit(steps)

        print("\n== 2. publish: versioned packs -> store + checkpoint ==")
        store = AdapterStore(tempfile.mkdtemp(prefix="personalize-store-"))
        ckpt = CheckpointManager(tempfile.mkdtemp(prefix="personalize-ck-"),
                                 keep=2)
        vids = mt.publish(store, out["state"], ckpt=ckpt)
        print(f"   published {vids}; checkpoint artifacts: "
              f"{ckpt.adapters(steps)}")
        assert vids == [f"{u}@1" for u in USERS]

        print("\n== 3. serve: bare names resolve newest-wins ==")
        eng = ServingEngine(run.model, mt.base, slots=4, cache_size=64,
                            store=store)
        rng = np.random.default_rng(0)
        prompt = rng.integers(1, run.model.vocab_size, (6,))
        f_alice = eng.submit(prompt, "alice", max_tokens=10)
        print(f"   alice's request pinned to {f_alice.adapter!r}")
        assert f_alice.adapter == "alice@1"
        for _ in range(3):
            eng.step()          # mid-stream: 3 tokens out, 7 to go

        print("\n== 4. loop: train more, publish v2 DURING serving ==")
        out2 = mt.fit(steps, state=out["state"])
        vids2 = mt.publish(store, out2["state"], ckpt=ckpt)
        f_alice2 = eng.submit(prompt, "alice", max_tokens=10)
        print(f"   published {vids2}; new request pinned to "
              f"{f_alice2.adapter!r}")
        assert f_alice2.adapter == "alice@2"
        eng.run()

        # in-flight request was NOT moved by the swap: its tokens match a
        # fresh engine that only ever saw alice@1
        ref = ServingEngine(run.model, mt.base, slots=4, cache_size=64,
                            store=store)
        r1 = ref.submit(prompt, "alice@1", max_tokens=10)
        r2 = ref.submit(prompt, "alice@2", max_tokens=10)
        ref.run()
        assert list(f_alice.tokens) == list(r1.tokens), "v1 request diverged"
        assert list(f_alice2.tokens) == list(r2.tokens), "v2 request diverged"
        assert "alice@1" not in eng.engine.packs, "superseded version kept"
        print("\n   in-flight v1 request: token-identical through the swap")
        print("   drained v1 retired from engine tables + store residency")

        losses = [h["loss"] for h in out["history"] + out2["history"]]
        print(f"\nloss {losses[0]:.4f} -> {losses[-1]:.4f} over "
              f"{len(losses)} steps, {len(USERS)} adapters, "
              f"2 published versions each")
        assert losses[-1] < losses[0], "training did not reduce loss"
        eng.shutdown(include_store=True)
        ref.shutdown()
        print("personalization loop OK")


if __name__ == "__main__":
    main()
