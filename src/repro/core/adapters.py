"""Adapter modules: SHiRA (the paper), LoRA, DoRA, and SHiRA-masked DoRA.

All adapters share one functional contract so the trainer and server are
adapter-agnostic:

  trainable, aux = init_adapter(key, base_params, acfg, calib_grads=None)
  params_eff     = materialize(base_params, trainable, aux, acfg, alpha)

``trainable`` is the pytree the optimizer sees (for SHiRA-packed: just the
(…, K) value vectors — this is exactly the paper's App. D memory win).
``aux`` holds non-trainable statics (packed indices, etc.).

Gradients flow from the loss through ``materialize`` into ``trainable`` by
ordinary autodiff: d(values) = gather(dW at indices) for SHiRA — the same
math as the paper's gradient-hook (App. C), obtained for free from the
scatter-add's transpose.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AdapterConfig
from repro.core import masks as M


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _lora_init(key, w, rank):
    *lead, n, m = w.shape
    ka, _ = jax.random.split(key)
    a = jax.random.normal(ka, tuple(lead) + (n, rank), jnp.float32) \
        * (1.0 / np.sqrt(n))
    b = jnp.zeros(tuple(lead) + (rank, m), jnp.float32)
    return {"A": a, "B": b}


def _col_norm(w):
    return jnp.sqrt(jnp.sum(jnp.square(w.astype(jnp.float32)),
                            axis=-2, keepdims=True) + 1e-12)


def init_adapter(key, params, acfg: AdapterConfig,
                 calib_grads=None) -> Tuple[Any, Any]:
    kind = acfg.kind
    if kind == "none":
        return None, None

    if kind == "shira":
        idx = M.make_packed_indices(params, acfg, key, calib_grads)
        values = jax.tree.map(
            lambda i: None if i is None else jnp.zeros(i.shape, jnp.float32),
            idx, is_leaf=lambda x: x is None)
        return values, {"indices": idx}

    if kind in ("lora", "dora", "shira-dora"):
        def per_leaf(path, w):
            sub = jax.random.fold_in(key, hash(M.path_str(path)) % (2 ** 31))
            p = _lora_init(sub, w, acfg.rank)
            if kind in ("dora", "shira-dora"):
                p["m"] = _col_norm(w)
            return p

        trainable = M.map_targets(per_leaf, params, acfg.target_modules)
        aux = None
        if kind == "shira-dora":
            aux = {"indices": M.make_packed_indices(params, acfg, key,
                                                    calib_grads)}
        return trainable, aux

    raise ValueError(f"unknown adapter kind {kind!r}")


# ---------------------------------------------------------------------------
# materialize
# ---------------------------------------------------------------------------

def _lora_delta(w, t, scale):
    return scale * jnp.einsum("...nr,...rm->...nm",
                              t["A"].astype(jnp.float32),
                              t["B"].astype(jnp.float32))


def _dora_weight(w, t, scale):
    v = w.astype(jnp.float32) + _lora_delta(w, t, scale)
    return t["m"] * v / _col_norm(v)


def materialize(params, trainable, aux, acfg: AdapterConfig,
                alpha: Optional[float] = None):
    """Return the effective parameter tree for forward passes."""
    if acfg.kind == "none" or trainable is None:
        return params
    a = acfg.alpha if alpha is None else alpha
    scale = acfg.lora_alpha / max(acfg.rank, 1)

    if acfg.kind == "shira":
        idx = aux["indices"]

        def leaf(w, i, v):
            if i is None:
                return w
            return M.scatter_packed_add(w, i, v, alpha=a).astype(w.dtype)

        return jax.tree.map(leaf, params, idx, trainable,
                            is_leaf=lambda x: x is None)

    if acfg.kind == "lora":
        def leaf(w, t):
            if t is None:
                return w
            return (w.astype(jnp.float32) + a * _lora_delta(w, t, scale)
                    ).astype(w.dtype)

        return jax.tree.map(leaf, params, trainable,
                            is_leaf=lambda x: x is None or isinstance(x, dict)
                            and "A" in x)

    if acfg.kind == "dora":
        def leaf(w, t):
            if t is None:
                return w
            wd = _dora_weight(w, t, scale)
            return (w.astype(jnp.float32) + a * (wd - w.astype(jnp.float32))
                    ).astype(w.dtype)

        return jax.tree.map(leaf, params, trainable,
                            is_leaf=lambda x: x is None or isinstance(x, dict)
                            and "A" in x)

    if acfg.kind == "shira-dora":
        idx = aux["indices"]

        def leaf(w, t, i):
            if t is None or i is None:
                return w
            delta = _dora_weight(w, t, scale) - w.astype(jnp.float32)
            dv = M.gather_packed(delta, i)          # keep only the masked 1%
            return M.scatter_packed_add(w, i, dv, alpha=a).astype(w.dtype)

        return jax.tree.map(leaf, params, trainable, idx,
                            is_leaf=lambda x: x is None or isinstance(x, dict)
                            and "A" in x)

    raise ValueError(acfg.kind)


# ---------------------------------------------------------------------------
# Shard-local materialize (multi-pod training path)
# ---------------------------------------------------------------------------

def materialize_sharded(params, values, indices, pspecs, mesh,
                        alpha: float = 1.0):
    """W_eff = W + alpha * scatter(values) with SHARD-LOCAL packed indices.

    ``indices``/``values`` leaves are (L, DPC, TPC, Ks): per (data, model)
    shard of the stacked weight, Ks flat indices into the LOCAL (n/DPC,
    m/TPC) tile. The scatter then runs inside shard_map with zero
    communication — and the value gradients are sharded exactly like the
    weights, so the only cross-replica gradient traffic left is the pod-axis
    all-reduce of the packed values (~1% of the dense sync; §Perf)."""
    import jax.numpy as jnp_

    def leaf(w, v, i, spec):
        if v is None or i is None:
            return w

        def local(wl, il, vl):
            L = wl.shape[0]
            il2 = il.reshape(L, -1)
            vl2 = vl.reshape(L, -1)
            return M.scatter_packed_add(wl, il2, vl2, alpha=alpha).astype(
                wl.dtype)

        from jax.sharding import PartitionSpec as P
        ispec = P(spec[0] if len(spec) > 0 else None,
                  spec[1] if len(spec) > 1 else None,
                  spec[2] if len(spec) > 2 else None, None)
        from repro.compat import shard_map
        return shard_map(local, mesh=mesh, in_specs=(spec, ispec, ispec),
                         out_specs=spec, check_vma=False)(w, i, v)

    return jax.tree.map(leaf, params, values, indices, pspecs,
                        is_leaf=lambda x: x is None)


# ---------------------------------------------------------------------------
# Packs — the serialized sparse adapter of Fig. 3(a)
# ---------------------------------------------------------------------------

@dataclass
class AdapterPack:
    """Sparse weights + indices, per target path. Model-size comparable to a
    LoRA but overwrites only 1-2% of entries when loaded."""

    name: str
    entries: Dict[str, Tuple[jax.Array, jax.Array]]  # path -> (idx, val)
    alpha: float = 1.0

    def num_params(self) -> int:
        return int(sum(int(np.prod(v.shape)) for _, v in self.entries.values()))

    def nbytes(self) -> int:
        return int(sum(i.size * i.dtype.itemsize + v.size * v.dtype.itemsize
                       for i, v in self.entries.values()))


def pack_from_shira(name: str, trainable, aux, alpha: float = 1.0) -> AdapterPack:
    entries = {}
    flat_idx = jax.tree_util.tree_flatten_with_path(
        aux["indices"], is_leaf=lambda x: x is None)[0]
    flat_val = jax.tree_util.tree_flatten_with_path(
        trainable, is_leaf=lambda x: x is None)[0]
    for (pi, i), (pv, v) in zip(flat_idx, flat_val):
        if i is not None:
            entries[M.path_str(pi)] = (i, v)
    return AdapterPack(name=name, entries=entries, alpha=alpha)


def pack_from_delta(name: str, base, tuned, acfg: AdapterConfig,
                    alpha: float = 1.0) -> AdapterPack:
    """S = W_new - W gathered at its own nonzeros (paper App. G). Used for
    hook-mode training where the base weights were updated in place."""
    entries = {}
    for (p, w_new), (_, w_old) in zip(
            jax.tree_util.tree_flatten_with_path(tuned)[0],
            jax.tree_util.tree_flatten_with_path(base)[0]):
        if not M.is_target(p, w_new, acfg.target_modules):
            continue
        delta = (w_new.astype(jnp.float32) - w_old.astype(jnp.float32))
        *lead, n, m = delta.shape
        k = M.budget(n, m, acfg.sparsity)
        nl = int(np.prod(lead)) if lead else 1
        df = jnp.reshape(delta, (nl, n * m))
        _, idx = jax.lax.top_k(jnp.abs(df), k)
        val = jax.vmap(lambda row, ix: row[ix])(df, idx)
        entries[M.path_str(p)] = (
            jnp.reshape(idx.astype(jnp.int32), tuple(lead) + (k,)),
            jnp.reshape(val, tuple(lead) + (k,)))
    return AdapterPack(name=name, entries=entries, alpha=alpha)


def apply_pack(params, pack: AdapterPack, alpha: Optional[float] = None,
               sign: float = 1.0):
    """W += sign * alpha * S at the pack's indices (load / unload)."""
    a = (pack.alpha if alpha is None else alpha) * sign

    def walk(tree, prefix):
        if isinstance(tree, dict):
            return {k: walk(v, prefix + (str(k),)) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            t = [walk(v, prefix + (str(i),)) for i, v in enumerate(tree)]
            return type(tree)(t) if not isinstance(tree, tuple) else tuple(t)
        key = "/".join(prefix)
        if key in pack.entries:
            idx, val = pack.entries[key]
            return M.scatter_packed_add(tree, idx, val, alpha=a).astype(
                tree.dtype)
        return tree

    return walk(params, ())
