"""SHiRA mask construction — the five strategies from §3.1 of the paper.

A mask selects the 1-2% of entries of each *target* weight matrix that are
trainable. Masks are pytrees aligned with the parameter tree: ``None`` on
non-target leaves, and on target leaves either

  * a dense 0/1 array of the weight's shape (``hook`` training mode,
    paper App. C — grads are Hadamard-masked), or
  * packed flat indices (..., K) int32 over the trailing (n, m) dims
    (``packed`` training/serving mode, paper App. D — optimizer state and
    adapter storage hold only the K nonzeros).

Leaves with more than 2 dims (scanned layer stacks (L, n, m), MoE expert
stacks (L, E, n, m)) are treated as batches of matrices: selection is done
*per matrix* with an exact per-matrix budget K, which keeps packing uniform
and — because TP shards the trailing dims evenly — keeps per-shard update
counts balanced.

Strategies (cfg.mask):
  struct : evenly-spaced rows + columns + the (high-rank) main diagonal
  rand   : uniform random K entries
  wm     : top-K |W|
  grad   : top-K |g| from a calibration gradient
  snip   : top-K |W * g|  (SNIP saliency, Lee et al. 2018)
"""
from __future__ import annotations

import re
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AdapterConfig

PathTree = Any


# ---------------------------------------------------------------------------
# Parameter-tree walking
# ---------------------------------------------------------------------------

def path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def leaf_name(path) -> str:
    return path_str(path).split("/")[-1]


def is_target(path, leaf, target_modules: Tuple[str, ...]) -> bool:
    return (hasattr(leaf, "ndim") and leaf.ndim >= 2
            and leaf_name(path) in target_modules)


def map_targets(fn: Callable, params, target_modules: Tuple[str, ...]):
    """tree_map over target leaves only; None elsewhere."""
    return jax.tree_util.tree_map_with_path(
        lambda p, x: fn(p, x) if is_target(p, x, target_modules) else None,
        params)


def target_paths(params, target_modules) -> List[str]:
    out = []
    jax.tree_util.tree_map_with_path(
        lambda p, x: out.append(path_str(p))
        if is_target(p, x, target_modules) else None, params)
    return sorted(out)


# ---------------------------------------------------------------------------
# Per-matrix index selection (all return (K,) flat indices into n*m)
# ---------------------------------------------------------------------------

def budget(n: int, m: int, sparsity: float) -> int:
    return max(1, int(round((1.0 - sparsity) * n * m)))


def _struct_indices(n: int, m: int, cfg: AdapterConfig) -> np.ndarray:
    """Evenly spaced rows + cols + main diagonal (the high-rank part)."""
    rows = np.unique(np.linspace(0, n - 1, max(cfg.struct_rows, 1)).astype(np.int64))
    cols = np.unique(np.linspace(0, m - 1, max(cfg.struct_cols, 1)).astype(np.int64))
    idx = set()
    for r in rows:
        idx.update(range(int(r) * m, int(r) * m + m))
    for c in cols:
        idx.update(int(c) + m * np.arange(n))
    d = min(n, m)
    idx.update(np.arange(d) * m + np.arange(d))
    return np.sort(np.fromiter(idx, dtype=np.int64))


def _rand_indices(key, n: int, m: int, k: int) -> jax.Array:
    return jax.random.choice(key, n * m, (k,), replace=False).astype(jnp.int32)


def _topk_indices(score_flat: jax.Array, k: int) -> jax.Array:
    _, idx = jax.lax.top_k(score_flat, k)
    return idx.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def make_packed_indices(params, cfg: AdapterConfig, key,
                        grads=None) -> PathTree:
    """Pytree of packed indices: target leaves -> int32 (..., K) flat indices
    over the trailing (n, m); None elsewhere."""

    def per_leaf(path, w, g):
        *lead, n, m = w.shape
        nl = int(np.prod(lead)) if lead else 1
        wf = jnp.reshape(w, (nl, n * m)).astype(jnp.float32)
        sub = jax.random.fold_in(key, hash(path_str(path)) % (2 ** 31))

        if cfg.mask == "struct":
            idx = jnp.asarray(_struct_indices(n, m, cfg), jnp.int32)
            idx = jnp.broadcast_to(idx[None], (nl,) + idx.shape)
        else:
            k = budget(n, m, cfg.sparsity)
            if cfg.mask == "rand":
                keys = jax.random.split(sub, nl)
                idx = jax.vmap(lambda kk: _rand_indices(kk, n, m, k))(keys)
            elif cfg.mask == "wm":
                idx = jax.vmap(lambda s: _topk_indices(s, k))(jnp.abs(wf))
            elif cfg.mask in ("grad", "snip"):
                if g is None:
                    raise ValueError(
                        f"mask={cfg.mask!r} needs calibration grads")
                gf = jnp.reshape(g, (nl, n * m)).astype(jnp.float32)
                score = jnp.abs(gf) if cfg.mask == "grad" else jnp.abs(gf * wf)
                idx = jax.vmap(lambda s: _topk_indices(s, k))(score)
            else:
                raise ValueError(f"unknown mask strategy {cfg.mask!r}")
        return jnp.reshape(idx, tuple(lead) + (idx.shape[-1],))

    if grads is None:
        return jax.tree_util.tree_map_with_path(
            lambda p, x: per_leaf(p, x, None)
            if is_target(p, x, cfg.target_modules) else None, params)
    return jax.tree_util.tree_map_with_path(
        lambda p, x, g: per_leaf(p, x, g)
        if is_target(p, x, cfg.target_modules) else None, params, grads)


def dense_mask_from_indices(w: jax.Array, idx: jax.Array) -> jax.Array:
    """(..., n, m) weight + (..., K) flat indices -> 0/1 mask of w's shape."""
    *lead, n, m = w.shape
    nl = int(np.prod(lead)) if lead else 1
    idxf = jnp.reshape(idx, (nl, idx.shape[-1]))

    def one(ix):
        z = jnp.zeros((n * m,), jnp.float32)
        return z.at[ix].set(1.0)

    return jnp.reshape(jax.vmap(one)(idxf), w.shape)


def make_dense_masks(params, cfg: AdapterConfig, key, grads=None) -> PathTree:
    idxs = make_packed_indices(params, cfg, key, grads)
    return jax.tree.map(
        lambda w, i: None if i is None else dense_mask_from_indices(w, i),
        params, idxs, is_leaf=lambda x: x is None)


def mask_grads(grads, masks, freeze_others: bool = True) -> Any:
    """Hadamard gradient masking (paper Fig. 2(b), App. C).

    ``freeze_others=True`` zeroes gradients of non-target leaves too, so only
    the masked 1-2% of the model trains — exactly the packed-mode (App. D)
    semantics, making the two implementations trajectory-identical."""
    return jax.tree.map(
        lambda g, m: (jnp.zeros_like(g) if (m is None and freeze_others)
                      else g if m is None else (g * m.astype(g.dtype))),
        grads, masks, is_leaf=lambda x: x is None)


def mask_sparsity(masks) -> Dict[str, float]:
    out = {}
    for p, m in jax.tree_util.tree_flatten_with_path(
            masks, is_leaf=lambda x: x is None)[0]:
        if m is not None:
            out[path_str(p)] = float(jnp.mean(m.astype(jnp.float32)))
    return out


# ---------------------------------------------------------------------------
# Packed gather / scatter (the numerical core of rapid switching)
# ---------------------------------------------------------------------------

def gather_packed(w: jax.Array, idx: jax.Array) -> jax.Array:
    """w (..., n, m), idx (..., K) -> values (..., K)."""
    *lead, n, m = w.shape
    nl = int(np.prod(lead)) if lead else 1
    wf = jnp.reshape(w, (nl, n * m))
    idxf = jnp.reshape(idx, (nl, -1))
    vals = jax.vmap(lambda row, ix: row[ix])(wf, idxf)
    return jnp.reshape(vals, idx.shape)


def scatter_packed_add(w: jax.Array, idx: jax.Array, val: jax.Array,
                       alpha: float = 1.0) -> jax.Array:
    """w (..., n, m) += alpha * scatter(val at idx). Pure-jnp reference path;
    the Pallas ``scatter_apply`` kernel is the TPU-optimised equivalent."""
    *lead, n, m = w.shape
    nl = int(np.prod(lead)) if lead else 1
    wf = jnp.reshape(w, (nl, n * m))
    idxf = jnp.reshape(idx, (nl, -1))
    vf = jnp.reshape(val, (nl, -1)).astype(w.dtype) * jnp.asarray(
        alpha, w.dtype)
    out = jax.vmap(lambda row, ix, v: row.at[ix].add(v))(wf, idxf, vf)
    return jnp.reshape(out, w.shape)


def scatter_packed_set(w: jax.Array, idx: jax.Array, val: jax.Array) -> jax.Array:
    *lead, n, m = w.shape
    nl = int(np.prod(lead)) if lead else 1
    wf = jnp.reshape(w, (nl, n * m))
    idxf = jnp.reshape(idx, (nl, -1))
    vf = jnp.reshape(val, (nl, -1)).astype(w.dtype)
    out = jax.vmap(lambda row, ix, v: row.at[ix].set(v))(wf, idxf, vf)
    return jnp.reshape(out, w.shape)
