# The paper's primary contribution: Sparse High Rank Adapters (SHiRA) —
# mask construction, adapter training transforms, rapid switching, and
# multi-adapter fusion — plus the LoRA/DoRA baselines it is evaluated against.
from repro.core import adapters, fusion, masks, switching  # noqa: F401
from repro.core.adapters import (AdapterPack, apply_pack,  # noqa: F401
                                 init_adapter, materialize, pack_from_delta,
                                 pack_from_shira)
from repro.core.fusion import fuse_packs, index_overlap  # noqa: F401
from repro.core.masks import (gather_packed, make_dense_masks,  # noqa: F401
                              make_packed_indices, mask_grads,
                              scatter_packed_add, scatter_packed_set)
from repro.core.switching import (FusedLRU, LoraEngine,  # noqa: F401
                                  SwitchEngine, normalize_tenant,
                                  tenant_key, tenant_members)
