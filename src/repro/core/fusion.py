"""Multi-adapter fusion diagnostics (paper §3.2, §4.2.2, §4.3.2).

Fusion itself is trivial for SHiRA — naively add the sparse deltas
(``SwitchEngine.load_fused``). This module quantifies *why* it works:
the interference between two adapters, measured as

  * index-overlap: |nz(S1) ∩ nz(S2)| / K   (exact, packed form)
  * the ||A1^T A2|| orthogonality proxy from §3.2, comparing SHiRA's sparse
    deltas against equivalent dense (fused-LoRA) deltas.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adapters import AdapterPack


def index_overlap(p1: AdapterPack, p2: AdapterPack) -> Dict[str, float]:
    """Fraction of shared nonzero coordinates per target path."""
    out = {}
    for path in p1.entries:
        if path not in p2.entries:
            continue
        i1 = np.asarray(p1.entries[path][0])
        i2 = np.asarray(p2.entries[path][0])
        i1 = i1.reshape(-1, i1.shape[-1])   # per-matrix rows
        i2 = i2.reshape(-1, i2.shape[-1])
        fr = [np.intersect1d(a, b).size / max(min(a.size, b.size), 1)
              for a, b in zip(i1, i2)]
        out[path] = float(np.mean(fr))
    return out


def gram_interference(d1: jax.Array, d2: jax.Array) -> Tuple[float, float]:
    """For deltas (n, m): returns (fraction of nonzeros in d1^T d2,
    relative Frobenius interference ||d1^T d2|| / (||d1|| ||d2||))."""
    g = jnp.einsum("nm,np->mp", d1.astype(jnp.float32), d2.astype(jnp.float32))
    nz = float(jnp.mean(jnp.abs(g) > 1e-12))
    num = float(jnp.linalg.norm(g))
    den = float(jnp.linalg.norm(d1) * jnp.linalg.norm(d2) + 1e-12)
    return nz, num / den


def pack_to_dense(pack: AdapterPack, path: str, shape) -> jax.Array:
    idx, val = pack.entries[path]
    n, m = shape[-2], shape[-1]
    lead = shape[:-2]
    nl = int(np.prod(lead)) if lead else 1
    idxf = jnp.reshape(idx, (nl, -1))
    vf = jnp.reshape(val, (nl, -1)).astype(jnp.float32)
    dense = jax.vmap(lambda ix, v: jnp.zeros((n * m,), jnp.float32).at[ix].add(v))(
        idxf, vf)
    return dense.reshape(shape)


def fuse_packs(packs: List[AdapterPack], weights=None,
               name: str = "fused") -> AdapterPack:
    """Materialise a single pack equal to sum_i w_i * alpha_i * S_i, with
    duplicate coordinates merged (so loading it == loading all of them)."""
    weights = weights or [1.0] * len(packs)
    entries = {}
    paths = []                      # union over packs, first-seen order
    for p in packs:
        paths.extend(k for k in p.entries if k not in paths)
    for path in paths:
        idx_list, val_list = [], []
        for p, w in zip(packs, weights):
            if path not in p.entries:
                continue
            i, v = p.entries[path]
            idx_list.append(np.asarray(i))
            val_list.append(np.asarray(v, np.float32) * (w * p.alpha))
        lead = idx_list[0].shape[:-1]
        nl = int(np.prod(lead)) if lead else 1
        flat_i = [i.reshape(nl, -1) for i in idx_list]
        flat_v = [v.reshape(nl, -1) for v in val_list]
        merged_i, merged_v = [], []
        for row in range(nl):
            cat_i = np.concatenate([fi[row] for fi in flat_i])
            cat_v = np.concatenate([fv[row] for fv in flat_v])
            uniq, inv = np.unique(cat_i, return_inverse=True)
            acc = np.zeros(uniq.shape, np.float32)
            np.add.at(acc, inv, cat_v)
            merged_i.append(uniq)
            merged_v.append(acc)
        k = max(len(u) for u in merged_i)
        mi = np.zeros((nl, k), np.int32)
        mv = np.zeros((nl, k), np.float32)
        for r, (u, a) in enumerate(zip(merged_i, merged_v)):
            mi[r, :len(u)] = u          # padding points at index 0 ...
            mv[r, :len(u)] = a          # ... with value 0 => harmless add
        entries[path] = (jnp.asarray(mi.reshape(lead + (k,))),
                         jnp.asarray(mv.reshape(lead + (k,))))
    return AdapterPack(name=name, entries=entries, alpha=1.0)
