"""Rapid adapter switching (paper §3.2, App. A/B) and the LoRA comparison.

``SwitchEngine`` manages a deployed base model. Loading a SHiRA pack
overwrites only the pack's 1-2% of entries (scatter-add of the delta);
unloading subtracts it back — no separate fuse/unfuse stage, no unfused
branches in the forward pass. ``LoraEngine`` reproduces the HuggingFace
load->fuse->infer->unfuse->unload pipeline the paper benchmarks against
(W + s*A@B touches and rewrites *every* entry).

Both engines account bytes moved so benchmarks/rapid_switching.py can report
the switch-cost asymmetry measured in the paper's Fig. 5 alongside wall-clock.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adapters import AdapterPack, apply_pack


@dataclass
class SwitchStats:
    name: str
    seconds: float
    entries_written: int
    bytes_written: int
    weight_bytes_total: int


def _tree_bytes(tree) -> int:
    return int(sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)))


class SwitchEngine:
    """Holds deployed params; one active adapter (or fused set) at a time."""

    def __init__(self, params):
        self.params = params
        self.active: List[AdapterPack] = []
        self.history: List[SwitchStats] = []

    def _apply(self, pack: AdapterPack, sign: float):
        self.params = apply_pack(self.params, pack, sign=sign)

    def load(self, pack: AdapterPack) -> SwitchStats:
        t0 = time.perf_counter()
        self._apply(pack, +1.0)
        jax.block_until_ready(jax.tree.leaves(self.params)[0])
        dt = time.perf_counter() - t0
        self.active.append(pack)
        st = SwitchStats(pack.name, dt, pack.num_params(), pack.nbytes(),
                         _tree_bytes(self.params))
        self.history.append(st)
        return st

    def unload(self) -> Optional[SwitchStats]:
        if not self.active:
            return None
        pack = self.active.pop()
        t0 = time.perf_counter()
        self._apply(pack, -1.0)
        jax.block_until_ready(jax.tree.leaves(self.params)[0])
        dt = time.perf_counter() - t0
        st = SwitchStats("-" + pack.name, dt, pack.num_params(),
                         pack.nbytes(), _tree_bytes(self.params))
        self.history.append(st)
        return st

    def switch(self, pack: AdapterPack) -> SwitchStats:
        """unload current -> load new; the paper's rapid-switch operation."""
        while self.active:
            self.unload()
        return self.load(pack)

    def load_fused(self, packs: List[AdapterPack],
                   weights: Optional[List[float]] = None) -> List[SwitchStats]:
        """Multi-adapter fusion by naive addition (paper Fig. 3(b))."""
        weights = weights or [1.0] * len(packs)
        out = []
        for p, w in zip(packs, weights):
            scaled = AdapterPack(p.name, p.entries, alpha=p.alpha * w)
            out.append(self.load(scaled))
        return out


class LoraEngine:
    """The fuse/unfuse pipeline the paper compares against (App. A)."""

    def __init__(self, params):
        self.params = params
        self.active = None

    def fuse(self, lora: Dict[str, dict], scale: float) -> float:
        """lora: path -> {"A","B"}; W += scale * A@B for every target."""
        t0 = time.perf_counter()

        def walk(tree, prefix):
            if isinstance(tree, dict):
                return {k: walk(v, prefix + (str(k),)) for k, v in tree.items()}
            if isinstance(tree, (list, tuple)):
                return [walk(v, prefix + (str(i),)) for i, v in enumerate(tree)]
            key = "/".join(prefix)
            if key in lora:
                t = lora[key]
                delta = scale * jnp.einsum("...nr,...rm->...nm",
                                           t["A"].astype(jnp.float32),
                                           t["B"].astype(jnp.float32))
                return (tree.astype(jnp.float32) + delta).astype(tree.dtype)
            return tree

        self.params = walk(self.params, ())
        jax.block_until_ready(jax.tree.leaves(self.params)[0])
        self.active = (lora, scale)
        return time.perf_counter() - t0

    def unfuse(self) -> float:
        if self.active is None:
            return 0.0
        lora, scale = self.active
        t = self.fuse(lora, -scale)
        self.active = None
        return t


def changed_fraction(base, switched) -> float:
    """%C from the paper's tables: fraction of weights differing from base."""
    tot, diff = 0, 0
    for a, b in zip(jax.tree.leaves(base), jax.tree.leaves(switched)):
        tot += a.size
        diff += int(jnp.sum(jnp.not_equal(a, b)))
    return diff / max(tot, 1)
