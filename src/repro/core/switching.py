"""Rapid adapter switching (paper §3.2, App. A/B) and the LoRA comparison.

``SwitchEngine`` manages a deployed base model. Loading a SHiRA pack
overwrites only the pack's 1-2% of entries (scatter-add of the delta);
unloading subtracts it back — no separate fuse/unfuse stage, no unfused
branches in the forward pass. ``LoraEngine`` reproduces the HuggingFace
load->fuse->infer->unfuse->unload pipeline the paper benchmarks against
(W + s*A@B touches and rewrites *every* entry).

Both engines account bytes moved so benchmarks/rapid_switching.py can report
the switch-cost asymmetry measured in the paper's Fig. 5 alongside wall-clock.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import trace
from repro.core.adapters import AdapterPack, apply_pack

# A tenant names either the base model (None), one adapter ("a0"), or an
# adapter *stack* (("a0", "lang_de")) — several adapters applied together,
# e.g. an agent persona on top of a domain adapter.
Tenant = Union[None, str, Tuple[str, ...]]


def normalize_tenant(name) -> Tenant:
    """Canonical tenant key: None | str | sorted tuple (len >= 2).

    Stacks are additive (scatter-adds commute), so order inside a stack is
    irrelevant — sorting makes ("b", "a") and ("a", "b") one tenant."""
    if name is None or isinstance(name, str):
        return name
    members = sorted(set(name))      # dedup: ("a", "a") must not double-load
    if not members:
        return None
    return members[0] if len(members) == 1 else tuple(members)


def tenant_members(name: Tenant) -> List[str]:
    if name is None:
        return []
    return [name] if isinstance(name, str) else list(name)


def tenant_key(name: Tenant) -> str:
    """Stable string key for sorting/labelling mixed str|tuple tenants."""
    return "" if name is None else "+".join(tenant_members(name))


def split_version(name: str) -> Tuple[str, Optional[int]]:
    """Parse a versioned adapter id: ``"persona@3" -> ("persona", 3)``.

    Unversioned ids (no ``@``, or a non-numeric suffix — ``@`` is legal in
    plain adapter names) come back as ``(name, None)``. The versioned-id
    scheme is how continuous personalization publishes retrained adapters:
    ``AdapterStore.publish`` assigns monotonically increasing versions per
    base name, lookups of the bare name resolve newest-wins, and in-flight
    serving requests stay pinned to the concrete ``name@v`` they resolved
    at submit time."""
    base, sep, v = name.rpartition("@")
    if sep and base and v.isdigit():
        return base, int(v)
    return name, None


def versioned_id(base: str, version: int) -> str:
    """The canonical id for one published version of an adapter."""
    return f"{base}@{int(version)}"


def prior_version(name: str) -> Optional[str]:
    """The previous published version of a versioned id — the fallback
    ladder's next candidate when ``name@v`` fails to load
    (``"persona@3" -> "persona@2"``). ``None`` for ``@1`` and for
    unversioned names: the ladder falls through to the base model."""
    base, v = split_version(name)
    if v is None or v <= 1:
        return None
    return versioned_id(base, v - 1)


@dataclass
class SwitchStats:
    name: str
    seconds: float
    entries_written: int
    bytes_written: int
    weight_bytes_total: int


def _tree_bytes(tree) -> int:
    return int(sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)))


class SwitchEngine:
    """Holds deployed params; one active adapter (or fused set) at a time.

    With an ``AdapterStore`` attached, packs may be referred to by name —
    ``load``/``switch``/``load_fused`` accept either an ``AdapterPack`` or a
    registered adapter id, and the store handles disk residency.

    ``blocking=False`` rides JAX async dispatch: ``load``/``unload`` return
    as soon as the sparse scatter is *dispatched*, so the device-side
    update overlaps whatever the host does next (e.g. an in-flight decode
    step driven from another params tree). The swap is still ordered
    before any later computation that reads ``self.params``; only the
    host-side sync is skipped. ``SwitchStats.seconds`` then measures
    dispatch, not completion — keep the default for switch-latency
    benchmarking."""

    def __init__(self, params, store=None, blocking: bool = True):
        self.params = params
        self.store = store
        self.blocking = blocking
        self.active: List[AdapterPack] = []
        self.history: List[SwitchStats] = []

    def _resolve(self, pack) -> AdapterPack:
        if isinstance(pack, str):
            if self.store is None:
                raise ValueError(f"adapter named by id {pack!r} but no "
                                 "AdapterStore attached")
            return self.store.get(pack)
        return pack

    def _apply(self, pack: AdapterPack, sign: float):
        self.params = apply_pack(self.params, pack, sign=sign)

    def load(self, pack) -> SwitchStats:
        pack = self._resolve(pack)
        t0 = time.perf_counter()
        with trace.span("switch.load", cat="switch", name=pack.name,
                        bytes=pack.nbytes()):
            self._apply(pack, +1.0)
            if self.blocking:
                jax.block_until_ready(jax.tree.leaves(self.params)[0])
        dt = time.perf_counter() - t0
        self.active.append(pack)
        st = SwitchStats(pack.name, dt, pack.num_params(), pack.nbytes(),
                         _tree_bytes(self.params))
        self.history.append(st)
        return st

    def unload(self) -> Optional[SwitchStats]:
        if not self.active:
            return None
        pack = self.active.pop()
        t0 = time.perf_counter()
        with trace.span("switch.unload", cat="switch", name=pack.name,
                        bytes=pack.nbytes()):
            self._apply(pack, -1.0)
            if self.blocking:
                jax.block_until_ready(jax.tree.leaves(self.params)[0])
        dt = time.perf_counter() - t0
        st = SwitchStats("-" + pack.name, dt, pack.num_params(),
                         pack.nbytes(), _tree_bytes(self.params))
        self.history.append(st)
        return st

    def switch(self, pack) -> SwitchStats:
        """unload current -> load new; the paper's rapid-switch operation."""
        while self.active:
            self.unload()
        return self.load(pack)

    def load_fused(self, packs: List,
                   weights: Optional[List[float]] = None) -> List[SwitchStats]:
        """Multi-adapter fusion by naive addition (paper Fig. 3(b))."""
        weights = weights or [1.0] * len(packs)
        out = []
        for p, w in zip(packs, weights):
            p = self._resolve(p)
            scaled = AdapterPack(p.name, p.entries, alpha=p.alpha * w)
            out.append(self.load(scaled))
        return out


class LoraEngine:
    """The fuse/unfuse pipeline the paper compares against (App. A)."""

    def __init__(self, params):
        self.params = params
        self.active = None

    def fuse(self, lora: Dict[str, dict], scale: float) -> float:
        """lora: path -> {"A","B"}; W += scale * A@B for every target."""
        t0 = time.perf_counter()

        def walk(tree, prefix):
            if isinstance(tree, dict):
                return {k: walk(v, prefix + (str(k),)) for k, v in tree.items()}
            if isinstance(tree, (list, tuple)):
                # type-preserving, like apply_pack's walk: returning a list
                # for a tuple node corrupts the pytree structure
                t = [walk(v, prefix + (str(i),)) for i, v in enumerate(tree)]
                return tuple(t) if isinstance(tree, tuple) else t
            key = "/".join(prefix)
            if key in lora:
                t = lora[key]
                delta = scale * jnp.einsum("...nr,...rm->...nm",
                                           t["A"].astype(jnp.float32),
                                           t["B"].astype(jnp.float32))
                return (tree.astype(jnp.float32) + delta).astype(tree.dtype)
            return tree

        self.params = walk(self.params, ())
        jax.block_until_ready(jax.tree.leaves(self.params)[0])
        self.active = (lora, scale)
        return time.perf_counter() - t0

    def unfuse(self) -> float:
        if self.active is None:
            return 0.0
        lora, scale = self.active
        t = self.fuse(lora, -scale)
        self.active = None
        return t


@dataclass
class FusedDecision:
    """One scheduling step: fuse ``promote`` into the shared base (after
    un-fusing ``demote``), or leave things alone (both None). Either side
    may be a single adapter name or an adapter-stack tuple."""

    promote: Optional[Tenant] = None
    demote: Optional[Tenant] = None


class FusedLRU:
    """LRU fused-state scheduler for multi-tenant serving.

    The multi-tenant engine serves every request off ONE shared copy of the
    base weights plus a per-request sparse side-delta. When traffic is
    dominated by a single adapter, it is cheaper to *fuse* that adapter into
    the shared base (one sparse scatter, paper §3.2) so its requests skip the
    side-delta entirely; the remaining tenants are then served with diff
    packs (their delta minus the fused one). This object only decides WHO is
    fused — the engine applies the scatter and rebuilds its tables.

    Policy: an exponential moving average of each tenant's share of batch
    traffic, plus a recency stamp. A tenant is promoted when its share
    crosses ``promote_at``; the fused tenant is demoted back to side-delta
    form when its share decays below ``demote_at`` or when it has been unused
    for ``max_idle`` scheduling steps (the LRU part). The fused state holds
    exactly one *tenant* at a time: fusing two distinct tenants would make
    the shared base equal to the sum of their deltas, which neither wants.
    A tenant may however be an adapter *stack* (a tuple of names served
    together, e.g. agent stacks) — ``capacity`` bounds how many adapters a
    promotable stack may contain, so ``capacity=2`` fuses a hot pair in one
    transition while singles-only traffic behaves exactly as ``capacity=1``.
    Ties in share are broken deterministically by tenant name (lexicographic
    on the "a+b" key), never by dict insertion order.
    """

    def __init__(self, promote_at: float = 0.5, demote_at: float = 0.2,
                 decay: float = 0.5, max_idle: int = 8, capacity: int = 1):
        assert 0.0 <= demote_at <= promote_at <= 1.0
        assert capacity >= 1
        self.promote_at = promote_at
        self.demote_at = demote_at
        self.decay = decay
        self.max_idle = max_idle
        self.capacity = capacity
        self.share: Dict[Tenant, float] = {}
        self.last_used: Dict[Tenant, int] = {}
        self.step = 0
        self.fused: Optional[Tenant] = None

    def observe(self, names: Sequence) -> FusedDecision:
        """Record one batch of per-request tenants (None = base model, str =
        one adapter, tuple = adapter stack) and return the promotion/demotion
        to apply before serving it."""
        self.step += 1
        n = max(len(names), 1)
        counts: Dict[Tenant, int] = {}
        for name in names:
            name = normalize_tenant(name)
            if name is not None:
                counts[name] = counts.get(name, 0) + 1
                self.last_used[name] = self.step
        seen = set(counts) | set(self.share)
        for name in seen:
            frac = counts.get(name, 0) / n
            self.share[name] = (self.decay * self.share.get(name, 0.0)
                                + (1.0 - self.decay) * frac)
        # prune decayed-out idle tenants so long-lived serving doesn't scan
        # every adapter ever seen on each batch
        for name in [n_ for n_, s in self.share.items()
                     if n_ != self.fused and s < 1e-4
                     and self.step - self.last_used.get(n_, 0) > self.max_idle]:
            del self.share[name]
            self.last_used.pop(name, None)

        decision = FusedDecision()
        if self.fused is not None:
            idle = self.step - self.last_used.get(self.fused, 0)
            if (self.share.get(self.fused, 0.0) < self.demote_at
                    or idle >= self.max_idle):
                decision.demote = self.fused
        eligible = [name for name in self.share
                    if len(tenant_members(name)) <= self.capacity]
        # min over (-share, key): highest share wins, equal shares resolve
        # to the lexicographically-first tenant (stable across runs)
        hot = min(eligible, key=lambda m: (-self.share[m], tenant_key(m)),
                  default=None)
        if (hot is not None and hot != self.fused
                and self.share[hot] >= self.promote_at):
            if self.fused is not None:
                decision.demote = self.fused
            decision.promote = hot
        if decision.promote:
            trace.instant("sched.promote", cat="switch",
                          tenant=tenant_key(decision.promote))
            self.fused = decision.promote
        elif decision.demote:
            trace.instant("sched.demote", cat="switch",
                          tenant=tenant_key(decision.demote))
            self.fused = None
        return decision


@jax.jit
def _diff_counts(xs, ys):
    return [jnp.sum(jnp.not_equal(a, b)) for a, b in zip(xs, ys)]


def changed_fraction(base, switched) -> float:
    """%C from the paper's tables: fraction of weights differing from base.

    All per-leaf comparisons run in ONE jitted computation with a single
    host sync at the end — the old per-leaf ``int(jnp.sum(...))`` did a
    blocking device round-trip per leaf, which dominated the switching
    benchmarks on deep stacks."""
    a = jax.tree.leaves(base)
    b = jax.tree.leaves(switched)
    tot = sum(x.size for x in a)
    diff = sum(int(c) for c in jax.device_get(_diff_counts(a, b)))
    return diff / max(tot, 1)
