"""Version-compat shims for jax APIs that moved between releases.

The repo targets the newest jax API surface, but the CPU CI container pins
an older jaxlib. Gate — don't vendor — the moved symbols here so call sites
stay on the modern spelling.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """jax.shard_map (new) vs jax.experimental.shard_map.shard_map (old).

    The old API spells ``check_vma`` as ``check_rep``; semantics match for
    the False we pass.
    """
    if hasattr(jax, "shard_map"):
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
