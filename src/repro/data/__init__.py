from repro.data.pipeline import (SyntheticTask, TaskSpec, batch_iterator,  # noqa: F401
                                 make_batch)
