"""Deterministic synthetic data pipeline.

No datasets ship in this container, so the pipeline generates *learnable*
synthetic tasks — the adapter experiments need finetuning to actually reduce
loss, not just run. A ``TaskSpec`` defines an affine next-token rule

    t_{i+1} = (a * t_i + b) mod V'        over a vocab slice V' <= V

with per-task (a, b, V'). Different task ids give different rules, which is
what the multi-adapter experiments (paper §4.3.2) need: independently trained
adapters whose knowledge can interfere after fusion.

Properties the substrate guarantees:
  * deterministic in (seed, task, step) — restart/elastic-rescale safe,
  * host-shardable: ``make_batch`` takes (host_index, host_count) and slices
    the global batch without materialising it,
  * modality stubs: vision (patch embeddings) and audio (frame embeddings)
    inputs are generated as deterministic pseudo-random projections.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec


@dataclass(frozen=True)
class TaskSpec:
    task_id: int = 0
    vocab_slice: int = 0        # 0 => min(4096, vocab)

    def rule(self, vocab: int):
        v = self.vocab_slice or min(4096, vocab)
        rng = np.random.RandomState(1000 + self.task_id)
        a = int(rng.randint(2, v - 1)) | 1        # odd => bijective mod 2^k-ish
        b = int(rng.randint(1, v - 1))
        return a, b, v


class SyntheticTask:
    def __init__(self, cfg: ModelConfig, shape: ShapeSpec, seed: int = 0,
                 task: TaskSpec = TaskSpec()):
        self.cfg, self.shape, self.seed, self.task = cfg, shape, seed, task

    def global_batch(self, step: int) -> Dict[str, np.ndarray]:
        return make_batch(self.cfg, self.shape, self.seed, step, self.task)

    def host_batch(self, step: int, host_index: int,
                   host_count: int) -> Dict[str, np.ndarray]:
        full = self.global_batch(step)
        bsz = self.shape.global_batch
        assert bsz % host_count == 0
        per = bsz // host_count
        sl = slice(host_index * per, (host_index + 1) * per)
        return {k: v[sl] for k, v in full.items()}


def _token_stream(cfg: ModelConfig, n: int, s: int, seed: int, step: int,
                  task: TaskSpec) -> np.ndarray:
    a, b, v = task.rule(cfg.vocab_size)
    rng = np.random.RandomState((seed * 9973 + step * 131 + task.task_id)
                                % (2 ** 31))
    t0 = rng.randint(0, v, size=(n, 1))
    toks = [t0]
    # occasional re-seeding breaks degenerate cycles, keeps the rule learnable
    for i in range(s):
        nxt = (toks[-1] * a + b) % v
        if i % 64 == 63:
            nxt = rng.randint(0, v, size=(n, 1))
        toks.append(nxt)
    return np.concatenate(toks, axis=1).astype(np.int32)  # (n, s+1)


def _stub_embeds(n: int, s: int, d: int, seed: int, step: int) -> np.ndarray:
    rng = np.random.RandomState((seed * 7919 + step * 17) % (2 ** 31))
    return (rng.randn(n, s, d) * 0.02).astype(np.float32)


def make_batch(cfg: ModelConfig, shape: ShapeSpec, seed: int, step: int,
               task: TaskSpec = TaskSpec()) -> Dict[str, np.ndarray]:
    """Global train batch for any modality (kind == 'train')."""
    n, s = shape.global_batch, shape.seq_len
    if cfg.modality == "audio":
        emb = _stub_embeds(n, s, cfg.d_model, seed, step)
        rng = np.random.RandomState((seed + step) % (2 ** 31))
        labels = rng.randint(0, cfg.vocab_size, size=(n, s)).astype(np.int32)
        return {"frame_embeds": emb, "labels": labels}
    if cfg.modality == "vision":
        p = cfg.num_prefix_embeds
        stream = _token_stream(cfg, n, s - p, seed, step, task)
        return {
            "tokens": stream[:, :-1],
            "labels": stream[:, 1:],
            "patch_embeds": _stub_embeds(n, p, cfg.d_model, seed, step),
        }
    stream = _token_stream(cfg, n, s, seed, step, task)
    return {"tokens": stream[:, :-1], "labels": stream[:, 1:]}


def batch_iterator(cfg: ModelConfig, shape: ShapeSpec, seed: int = 0,
                   task: TaskSpec = TaskSpec(),
                   start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield make_batch(cfg, shape, seed, step, task)
        step += 1


def eval_loss_possible(cfg: ModelConfig, task: TaskSpec) -> float:
    """Entropy floor of the affine rule (~0 except at re-seed positions)."""
    _, _, v = task.rule(cfg.vocab_size)
    return float(np.log(v) / 64.0)
