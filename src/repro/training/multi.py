"""MultiAdapterTrainer: A sparse adapters finetuned in ONE jitted step.

The serving side already batches per-request adapters through the
``sidedelta`` side-term (one shared base matmul + per-request sparse
corrections, routed by a per-row adapter id). This trainer reuses exactly
that machinery for the *forward* pass of training, so A adapters'
finetuning batches share every base-weight matmul:

  * the packed trainables are batched as ``(A, …, K)`` value trees;
  * the step batch is the concatenation of each adapter's batch, with an
    ``ids`` row->adapter routing vector; weight leaves become
    ``sidedelta_weight`` bundles over the *trainable* value tables, run
    through the differentiable XLA twin of the kernel
    (``sidedelta_backend("xla")``) — the one-hot gather/scatter trick of
    ``kernels/sidedelta.py``, now with autodiff giving the per-adapter
    scatter-add gradient reduction for free;
  * the loss is the SUM of per-adapter mean NLLs, so each adapter's value
    gradients are exactly what its own single-adapter run would produce
    (values only touch rows routed to them — no cross-terms);
  * gradients are clipped per adapter (``batched_global_norm``) and the
    fused ``kernels/sparse_adamw`` update runs over the batched packed
    axis (``sparse_adamw_rows``: one launch per leaf updates all A
    adapters), with optimizer moments optionally stored bf16/int8 between
    steps (``training.qstate``; dequant happens inside the kernel).

Equivalence contract (tested in tests/test_multiadapter.py): under f32
compute precision, adapter ``a`` of ``MultiAdapterTrainer(run, names,
init_key=k)`` fed task ``TaskSpec(a)`` tracks ``Trainer(run, init_key=k +
a)`` fed the same stream, step for step, within float-summation-order
tolerance. MoE archs add the load-balance aux over the *combined* batch
(a documented cross-term); the parity contract is for dense archs.

Closing the loop into serving: ``export_packs`` emits one ``AdapterPack``
per adapter; ``publish`` pushes them through ``AdapterStore.publish`` as
versioned ids (``name@v``) and optionally snapshots them via
``CheckpointManager.save_adapter``, under ``publish.swap`` trace spans.
Live engines pick up the new version for new submissions while in-flight
requests stay pinned to the old one (see hub/serving.py).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro import core
from repro.analysis import trace
from repro.configs.base import RunConfig
from repro.data import TaskSpec, make_batch
from repro.kernels import ops
from repro.models import layers, lm
from repro.models.layers import rms_norm, sidedelta_weight, unembed
from repro.optim import batched_global_norm, lr_schedule
from repro.runtime.trainer import TrainerConfig
from repro.training import qstate


def multi_batch_iterator(cfg, shape, seed: int, tasks: Sequence[TaskSpec],
                         start_step: int = 0) -> Iterator[Dict[str, Any]]:
    """Concatenation of ``len(tasks)`` per-adapter streams + row->adapter
    ids. Row block ``a`` of every batch is bit-identical to what
    ``batch_iterator(cfg, shape, seed, task=tasks[a])`` yields at the same
    step — the sequential-equivalence tests rely on this."""
    import numpy as np
    A = len(tasks)
    n = shape.global_batch
    ids = np.repeat(np.arange(A, dtype=np.int32), n)
    step = start_step
    while True:
        parts = [make_batch(cfg, shape, seed, step, t) for t in tasks]
        batch = {k: np.concatenate([p[k] for p in parts], axis=0)
                 for k in parts[0]}
        batch["ids"] = ids
        yield batch
        step += 1


def _tuple_part(flat, i):
    return [None if t is None else t[i] for t in flat]


class MultiAdapterTrainer:
    """Concurrent packed-SHiRA finetuning of ``len(names)`` adapters.

    Args:
      run: shared RunConfig (``run.adapter`` must be packed SHiRA).
      names: adapter names, one per concurrent finetune; adapter ``a``
        inits from ``PRNGKey(init_key + a)`` — the same key its
        single-adapter ``Trainer(run, init_key=init_key + a)`` twin uses.
      moments: optimizer-moment storage, ``"f32"`` (default / oracle),
        ``"bf16"``, or ``"int8"`` (see ``training.qstate``).
      fused: route the update through the batched Pallas kernel
        (``sparse_adamw_rows``); False runs the pure-jnp reference with
        identical math — the kernel's parity oracle.
      interpret: Pallas interpret mode for the update kernel
        (None = auto: interpret off-TPU).
    """

    def __init__(self, run: RunConfig, names: Sequence[str],
                 tcfg: TrainerConfig = TrainerConfig(), *,
                 init_key: int = 0, base_params=None,
                 moments: str = "f32", fused: bool = True,
                 interpret: Optional[bool] = None):
        if run.adapter.kind != "shira" or not run.adapter.packed:
            raise ValueError("MultiAdapterTrainer is packed-SHiRA only; "
                             f"got kind={run.adapter.kind!r} "
                             f"packed={run.adapter.packed}")
        if moments not in qstate.MOMENT_MODES:
            raise ValueError(f"moments={moments!r} not in "
                             f"{qstate.MOMENT_MODES}")
        self.run, self.tcfg = run, tcfg
        self.cfg, self.acfg = run.model, run.adapter
        self.names = list(names)
        self.A = len(self.names)
        self.moments = moments
        self.fused = fused
        self.interpret = (jax.default_backend() != "tpu"
                          if interpret is None else interpret)
        self.base = (base_params if base_params is not None
                     else lm.init_params(self.cfg, jax.random.PRNGKey(init_key)))
        # Per-adapter init with each twin's exact key: K per leaf depends
        # only on the weight shape, so the A index trees stack cleanly.
        inits = [core.init_adapter(jax.random.PRNGKey(init_key + a),
                                   self.base, self.acfg)
                 for a in range(self.A)]
        self.auxes = [aux for _, aux in inits]
        none_leaf = lambda x: x is None
        self.idx = jax.tree.map(
            lambda *xs: None if xs[0] is None else jnp.stack(xs),
            *[aux["indices"] for aux in self.auxes], is_leaf=none_leaf)
        # Device coordinate tables, built once: (lead…, A, K) so lax.scan
        # over stacked layer weights slices them exactly like the weights.
        def coords(w, i):
            if i is None:
                return None
            return jnp.moveaxis(i, 0, -2) % jnp.int32(w.shape[-1])
        def coords_r(w, i):
            if i is None:
                return None
            return jnp.moveaxis(i, 0, -2) // jnp.int32(w.shape[-1])
        self.rows = jax.tree.map(coords_r, self.base, self.idx,
                                 is_leaf=none_leaf)
        self.cols = jax.tree.map(coords, self.base, self.idx,
                                 is_leaf=none_leaf)
        self.values0 = jax.tree.map(
            lambda i: None if i is None else jnp.zeros(i.shape, jnp.float32),
            self.idx, is_leaf=none_leaf)
        self._ids = jnp.repeat(jnp.arange(self.A, dtype=jnp.int32),
                               run.shape.global_batch)
        self.schedule = lr_schedule(run.train)
        self._step_fn = None

    # -- state ---------------------------------------------------------------

    def init_state(self) -> Dict[str, Any]:
        none_leaf = lambda x: x is None
        # fresh zeros per moment tree: sharing one buffer between mu and nu
        # would break the jitted step's donation (same buffer donated twice)
        enc = lambda sqrt_dom: jax.tree.map(
            lambda v: None if v is None
            else qstate.encode(jnp.zeros_like(v, jnp.float32), self.moments,
                               sqrt_dom),
            self.values0, is_leaf=none_leaf)
        mu, nu = enc(False), enc(True)
        tup = lambda x: isinstance(x, tuple)
        pick = lambda t, i: jax.tree.map(lambda p: p[i], t, is_leaf=tup)
        values = jax.tree.map(          # fresh too: the step donates state
            lambda v: None if v is None else jnp.zeros_like(v),
            self.values0, is_leaf=none_leaf)
        return {"values": values,
                "mu": pick(mu, 0), "nu": pick(nu, 0),
                "mu_scale": pick(mu, 1), "nu_scale": pick(nu, 1),
                "step": jnp.zeros((), jnp.int32)}

    # -- forward -------------------------------------------------------------

    def _wrapped_params(self, values):
        """Base tree with target leaves replaced by sidedelta bundles over
        the TRAINABLE (A, …, K) value tables — gradients flow through the
        bundle's ``sd.vals`` entry via the XLA twin."""
        none_leaf = lambda x: x is None

        def leaf(w, r, c, v):
            if r is None:
                return w
            lead = w.shape[:-2]
            return sidedelta_weight(
                w, r, c, jnp.moveaxis(v, 0, -2),
                jnp.broadcast_to(self._ids, lead + self._ids.shape))

        return jax.tree.map(leaf, self.base, self.rows, self.cols, values,
                            is_leaf=none_leaf)

    def _per_adapter_loss(self, params, batch):
        """(A,) mean NLL per adapter + aux — ``lm.chunked_loss`` math with
        the scalar accumulator widened to a one-hot-routed (A,) vector, so
        every adapter's loss normalizes over ITS rows only (what its own
        single-adapter run would compute)."""
        cfg, A = self.cfg, self.A
        if cfg.modality != "text":
            raise NotImplementedError("multi-adapter training routes by "
                                      "token rows; text modality only")
        h, prefix_len = lm.embed_inputs(params, cfg, batch)
        aux = jnp.zeros((), jnp.float32)
        for sp, (kind, _) in zip(params["stages"], lm.stage_plan(cfg)):
            h, aux = lm._stage_train(sp, kind, cfg, h, aux, prefix_len,
                                     shared=params.get("shared_attn"))
        h = rms_norm(h, params["final_norm"]["scale"], cfg.norm_eps)
        B, S, d = h.shape
        T = B * S
        hf = h.reshape(T, d)
        lf = batch["labels"].reshape(T)
        af = jnp.repeat(batch["ids"].astype(jnp.int32), S)
        tie = params["embed"]["emb"] if cfg.tie_embeddings else None
        un = params.get("unembed")
        c = lm._pick_chunk(T)
        n = T // c

        def body(carry, xs):
            hc, lc, ac = xs
            from repro.launch.actctx import shard_as
            hc = shard_as(hc, "loss_act")
            logits = unembed(un, hc, tie_to=tie, softcap=cfg.logit_softcap,
                             logical_vocab=cfg.vocab_size)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, lc[:, None], axis=-1)[:, 0]
            nll = logz - gold                                   # (c,)
            onehot = (ac[:, None] == jnp.arange(A)[None, :]).astype(
                jnp.float32)                                    # (c, A)
            sums, counts = carry
            return (sums + nll @ onehot,
                    counts + jnp.sum(onehot, axis=0)), None

        body = jax.checkpoint(body)
        (sums, counts), _ = jax.lax.scan(
            body, (jnp.zeros((A,), jnp.float32), jnp.zeros((A,), jnp.float32)),
            (hf.reshape(n, c, d), lf.reshape(n, c), af.reshape(n, c)))
        return sums / jnp.maximum(counts, 1.0), aux

    # -- the pure step -------------------------------------------------------

    def _update_leaf(self, v, g, m, u, ms, us, step, lr):
        tc = self.run.train
        K = v.shape[-1]
        R = v.size // K
        shp = v.shape
        row = lambda x: None if x is None else x.reshape(R, K)
        sc = lambda x: None if x is None else x.reshape(R)
        if self.fused:
            v2, m2, u2 = ops.sparse_adamw_batched(
                row(v), row(g), row(m), row(u), step, lr=lr,
                b1=tc.beta1, b2=tc.beta2, eps=tc.eps, wd=tc.weight_decay,
                mu_scale=sc(ms), nu_scale=sc(us), interpret=self.interpret)
            v2, m2, u2 = v2.reshape(shp), m2.reshape(shp), u2.reshape(shp)
        else:   # pure-jnp reference: identical math, the kernel's oracle
            mf = qstate.decode(m, ms, self.moments)
            uf = qstate.decode(u, us, self.moments, sqrt_domain=True)
            gf = g.astype(jnp.float32)
            stepf = step.astype(jnp.float32)
            m2 = tc.beta1 * mf + (1.0 - tc.beta1) * gf
            u2 = tc.beta2 * uf + (1.0 - tc.beta2) * gf * gf
            mh = m2 / (1.0 - tc.beta1 ** stepf)
            uh = u2 / (1.0 - tc.beta2 ** stepf)
            delta = mh / (jnp.sqrt(uh) + tc.eps) + tc.weight_decay * v
            v2 = v - lr * delta
        m_st, ms2 = qstate.encode(m2, self.moments)
        u_st, us2 = qstate.encode(u2, self.moments, sqrt_domain=True)
        return v2, m_st, u_st, ms2, us2

    def build_step(self) -> Callable:
        tc = self.run.train
        none_leaf = lambda x: x is None

        def step_fn(state, batch):
            lr = self.schedule(state["step"])

            def loss_fn(values):
                # trace-time flag: the XLA twin is the differentiable path
                with layers.sidedelta_backend("xla"):
                    losses, aux = self._per_adapter_loss(
                        self._wrapped_params(values), batch)
                loss = jnp.sum(losses)
                if self.cfg.family == "moe" or (
                        self.cfg.moe and self.cfg.moe.num_experts):
                    loss = loss + 0.01 * aux
                return loss, {"losses": losses, "aux": aux}

            (_, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state["values"])
            gnorm = batched_global_norm(grads, self.A)           # (A,)
            if tc.grad_clip > 0:
                scale = jnp.minimum(1.0, tc.grad_clip / (gnorm + 1e-9))
                grads = jax.tree.map(
                    lambda g: g * scale.reshape((self.A,) + (1,) * (g.ndim - 1)),
                    grads)
            step = state["step"] + 1
            flat = lambda t: jax.tree_util.tree_flatten(t, is_leaf=none_leaf)
            fv, treedef = flat(state["values"])
            fg = flat(grads)[0]
            fm, fu = flat(state["mu"])[0], flat(state["nu"])[0]
            fms, fus = flat(state["mu_scale"])[0], flat(state["nu_scale"])[0]
            out = [(None,) * 5 if v is None
                   else self._update_leaf(v, g, m, u, ms, us, step, lr)
                   for v, g, m, u, ms, us in zip(fv, fg, fm, fu, fms, fus)]
            unf = lambda i: jax.tree_util.tree_unflatten(
                treedef, _tuple_part(out, i))
            new_state = {"values": unf(0), "mu": unf(1), "nu": unf(2),
                         "mu_scale": unf(3), "nu_scale": unf(4), "step": step}
            metrics = {**metrics, "loss": jnp.mean(metrics["losses"]),
                       "grad_norm": gnorm, "lr": lr}
            return new_state, metrics

        return jax.jit(step_fn, donate_argnums=(0,))

    # -- host loop -----------------------------------------------------------

    def fit(self, steps: int, batches: Optional[Iterator] = None,
            state: Optional[dict] = None,
            log: Optional[Callable[[str], None]] = print) -> Dict[str, Any]:
        if self._step_fn is None:
            self._step_fn = self.build_step()
        if batches is None:
            batches = multi_batch_iterator(
                self.cfg, self.run.shape, self.run.train.seed,
                [TaskSpec(a) for a in range(self.A)])
        state = state or self.init_state()
        it = iter(batches)
        history = []
        for s in range(steps):
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            t0 = time.perf_counter()
            with trace.span("train.step", cat="train", step=s,
                            adapters=self.A):
                state, metrics = self._step_fn(state, batch)
                jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            losses = [float(x) for x in metrics["losses"]]
            rec = {"loss": float(metrics["loss"]), "lr": float(metrics["lr"]),
                   "step_ms": dt * 1e3}
            rec.update({f"loss:{n}": v for n, v in zip(self.names, losses)})
            history.append(rec)
            if log and (s % self.tcfg.log_every == 0 or s == steps - 1):
                per = " ".join(f"{n}={v:.4f}"
                               for n, v in zip(self.names, losses))
                log(f"[multi] step {s:5d} {per} {dt*1e3:.0f}ms")
        return {"state": state, "history": history}

    # -- export / publish ----------------------------------------------------

    def export_packs(self, state) -> List[core.AdapterPack]:
        none_leaf = lambda x: x is None
        packs = []
        for a, name in enumerate(self.names):
            vals = jax.tree.map(lambda v: None if v is None else v[a],
                                state["values"], is_leaf=none_leaf)
            packs.append(core.pack_from_shira(name, vals, self.auxes[a]))
        return packs

    def publish(self, store, state, *, ckpt=None, step: Optional[int] = None,
                values: str = "f32") -> List[str]:
        """Push every adapter's current values into the store as a fresh
        version (``name@v``); optionally snapshot the versioned packs into
        a checkpoint step (committed by the next ``ckpt.save``). Live
        engines hot-swap on their next submit — see hub/serving.py."""
        step = int(state["step"]) if step is None else step
        vids = []
        for pack in self.export_packs(state):
            with trace.span("publish.swap", cat="train", name=pack.name):
                vid = store.publish(pack, values=values)
                if ckpt is not None:
                    ckpt.save_adapter(step, core.AdapterPack(
                        vid, pack.entries, pack.alpha), values=values)
            vids.append(vid)
        return vids
