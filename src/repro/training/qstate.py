"""Quantized optimizer-moment storage for the multi-adapter trainer.

With A adapters resident per device, optimizer memory is 2 f32 moments per
packed value — 8 bytes/value on top of the 4-byte value itself. Quantizing
the EMA moments between steps cuts that to 2 (int8 + per-row scales) or 4
(bf16) bytes/value, so moment storage stops bounding adapters-per-device.

Storage modes (``moments=``):

  * ``"f32"``  — plain f32, bit-identical to the single-adapter reference
                 path. The default, and the parity oracle for the others.
  * ``"bf16"`` — truncation cast. bf16 keeps f32's exponent range, so no
                 scales are needed; the loss is 16 mantissa bits of EMA
                 resolution.
  * ``"int8"`` — symmetric per-row quantization, one f32 scale per
                 (adapter, leaf-row). ``mu`` is signed: ``q = round(m /
                 scale)``, ``scale = amax|m| / 127``. ``nu`` is
                 non-negative with a squared dynamic range, so it is stored
                 in the *sqrt domain*: ``q = round(sqrt(nu) / scale)``,
                 ``scale = amax(sqrt(nu)) / 127`` — 8 bits then cover the
                 same relative range as 16 would linearly. All-zero rows
                 encode with scale 1.0 so they decode to exact zeros.

Encode/decode are pure jnp (usable inside jit). The fused update kernel
(``kernels/sparse_adamw.sparse_adamw_rows``) performs the *decode* inline —
dequant happens in kernel VMEM, not through an f32 round trip in HBM — and
always emits f32 moments, which ``encode`` re-compresses in the same jitted
step. The reference (non-fused) path in ``training.multi`` decodes with
``decode`` and must match the kernel bit-for-bit in f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

MOMENT_MODES = ("f32", "bf16", "int8")


def storage_dtype(mode: str):
    return {"f32": jnp.float32, "bf16": jnp.bfloat16,
            "int8": jnp.int8}[mode]


def _row_scale(amax: jax.Array) -> jax.Array:
    return jnp.where(amax > 0, amax / 127.0, 1.0)


def encode(moment: jax.Array, mode: str, sqrt_domain: bool = False):
    """f32 moment -> (stored, scale|None). ``moment``'s trailing axis is the
    packed K axis; scales are per leading row. ``sqrt_domain`` selects the
    nu encoding (compress sqrt(nu), decode squares it back)."""
    if mode == "f32":
        return moment, None
    if mode == "bf16":
        return moment.astype(jnp.bfloat16), None
    if mode != "int8":
        raise ValueError(f"unknown moment mode {mode!r}")
    x = jnp.sqrt(moment) if sqrt_domain else moment
    amax = jnp.max(jnp.abs(x), axis=-1)
    scale = _row_scale(amax)
    q = jnp.clip(jnp.rint(x / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def decode(stored: jax.Array, scale, mode: str,
           sqrt_domain: bool = False) -> jax.Array:
    """Inverse of ``encode`` — the reference-path dequant (the fused kernel
    does the same math inline in VMEM)."""
    if mode == "f32":
        return stored
    if mode == "bf16":
        return stored.astype(jnp.float32)
    x = stored.astype(jnp.float32) * scale[..., None]
    return x * x if sqrt_domain else x


def moment_bytes_per_value(mode: str, k: int) -> float:
    """Persistent bytes per packed value for BOTH moments, amortizing the
    per-row f32 scales over a K-length row (int8 only)."""
    per = {"f32": 4.0, "bf16": 2.0, "int8": 1.0}[mode]
    scales = (2 * 4.0 / max(k, 1)) if mode == "int8" else 0.0
    return 2 * per + scales
