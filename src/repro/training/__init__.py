"""Continuous personalization: concurrent multi-adapter SHiRA training
with quantized optimizer state, closed into live serving via versioned
publish + hot-swap. See training/README.md for the loop's contract."""
from repro.training.multi import (MultiAdapterTrainer,  # noqa: F401
                                  multi_batch_iterator)
from repro.training import qstate  # noqa: F401
