"""Pure-jnp oracles for every kernel — the ground truth the Pallas kernels
are validated against (tests sweep shapes/dtypes in interpret mode)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def scatter_apply_ref(w: jax.Array, flat_idx: jax.Array, vals: jax.Array,
                      alpha: float = 1.0) -> jax.Array:
    """w: (n, m); flat_idx/vals: (K,). W + alpha * scatter(vals)."""
    n, m = w.shape
    out = w.reshape(-1).astype(jnp.float32).at[flat_idx].add(
        alpha * vals.astype(jnp.float32))
    return out.reshape(n, m).astype(w.dtype)


def sidedelta_ref(x: jax.Array, rows: jax.Array, cols: jax.Array,
                  vals: jax.Array, ids: jax.Array, m: int) -> jax.Array:
    """x: (B, S, n); rows/cols/vals: (A, K); ids: (B,) with -1 = no adapter.
    Returns (B, S, m) f32: delta[b] = x[b] @ dW_{ids[b]} with dW the sparse
    matrix scattered from the packed (row, col, val) triples."""
    B, S, n = x.shape
    A, K = rows.shape

    def one_adapter(r, c, v):
        dw = jnp.zeros((n, m), jnp.float32)
        return dw.at[r, c].add(v.astype(jnp.float32))

    dense = jax.vmap(one_adapter)(rows, cols, vals)        # (A, n, m)
    slot = jnp.maximum(ids, 0)
    delta = jnp.einsum("bsn,bnm->bsm", x.astype(jnp.float32), dense[slot])
    return jnp.where((ids >= 0)[:, None, None], delta, 0.0)


def sidedelta_int8_ref(x: jax.Array, rows: jax.Array, cols: jax.Array,
                       vals_q: jax.Array, scale: jax.Array, ids: jax.Array,
                       m: int) -> jax.Array:
    """int8-table oracle: vals_q (A, K) int8 with per-adapter scale (A,)
    f32 dequantized exactly as the kernel does (q * scale in f32) before
    the dense reference contraction."""
    vals = vals_q.astype(jnp.float32) * scale[:, None].astype(jnp.float32)
    return sidedelta_ref(x, rows.astype(jnp.int32), cols.astype(jnp.int32),
                         vals, ids, m)


def masked_update_ref(w: jax.Array, mask: jax.Array, vals: jax.Array,
                      alpha: float = 1.0) -> jax.Array:
    out = w.astype(jnp.float32) + alpha * mask.astype(jnp.float32) \
        * vals.astype(jnp.float32)
    return out.astype(w.dtype)


def sparse_adamw_ref(values, grads, mu, nu, *, lr, b1, b2, eps, wd, step):
    g = grads.astype(jnp.float32)
    v = values.astype(jnp.float32)
    m = b1 * mu + (1 - b1) * g
    u = b2 * nu + (1 - b2) * g * g
    c1 = 1.0 - b1 ** step
    c2 = 1.0 - b2 ** step
    delta = (m / c1) / (jnp.sqrt(u / c2) + eps) + wd * v
    return (v - lr * delta).astype(values.dtype), m, u


def flash_prefill_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                      causal: bool = True) -> jax.Array:
    """q: (B, Sq, H, D); k/v: (B, Skv, KV, D) -> (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, D)
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Skv)[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def flash_decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_len: int) -> jax.Array:
    """q: (B, KV, G, D); k/v: (B, S, KV, D). Masked softmax attention."""
    B, KV, G, D = q.shape
    S = k.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    s = jnp.einsum("bhgd,bshd->bhgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = jnp.arange(S)[None, None, None, :] < kv_len
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
