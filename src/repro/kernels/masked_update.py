"""masked_update — dense-mask tile update (the vectorised SHiRA apply).

W_out = W + alpha * (M ⊙ V), computed tile-by-tile in VMEM. This is the
bandwidth-optimal path when the adapter ships as a dense (mask, delta) pair
(e.g. straight out of hook-mode training) and the in-training fused apply
for masked finetuning: fully vectorised on the VPU, one pass over W.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _masked_update_kernel(alpha_ref, w_ref, m_ref, v_ref, out_ref):
    alpha = alpha_ref[0]
    w = w_ref[...].astype(jnp.float32)
    out = w + alpha * m_ref[...].astype(jnp.float32) \
        * v_ref[...].astype(jnp.float32)
    out_ref[...] = out.astype(out_ref.dtype)


def masked_update_tiles(w: jax.Array, mask: jax.Array, vals: jax.Array,
                        alpha: jax.Array, *, bn: int = 256, bm: int = 256,
                        interpret: bool = False) -> jax.Array:
    """w/mask/vals: (n, m); alpha: (1,) f32."""
    n, m = w.shape
    assert n % bn == 0 and m % bm == 0, (n, m, bn, bm)
    grid = (n // bn, m // bm)
    tile = pl.BlockSpec((bn, bm), lambda i, j: (i, j))
    return pl.pallas_call(
        _masked_update_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1,), lambda i, j: (0,)), tile, tile, tile],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((n, m), w.dtype),
        input_output_aliases={1: 0},
        interpret=interpret,
    )(alpha, w, mask, vals)
