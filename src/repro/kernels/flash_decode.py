"""flash_decode — blocked online-softmax decode attention.

Single-query attention against a long KV cache is the serving hot loop the
SHiRA adapters plug into (decode_32k / long_500k shapes). The kernel walks
the KV sequence in (Sb)-sized blocks, maintaining the online-softmax
running max / normaliser / accumulator in VMEM scratch, and emits the
normalised output on the last block. GQA group heads share their KV head's
pass (q laid out as (B, KV, G, D)).

Grid: (B, KV, S // Sb) — the sequence axis iterates innermost so scratch
accumulation across blocks is sequential per (batch, kv-head).

``flash_decode_paged`` is the block-table variant: K/V live in a global
page pool (P, page, KV, D) and each request's logical block ``i`` resolves
to physical page ``block_tables[b, i]``. The tables ride in as scalar
prefetch (``pltpu.PrefetchScalarGridSpec``) so the K/V index maps can
dereference them when scheduling block DMAs — the kernel body is the same
online-softmax loop, walking pages instead of contiguous blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_decode_kernel(kvlen_ref, q_ref, k_ref, v_ref, out_ref,
                         acc_ref, m_ref, l_ref, *, sb: int):
    blk = pl.program_id(2)
    nblk = pl.num_programs(2)

    @pl.when(blk == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                # (G, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)          # (Sb, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)          # (Sb, D)
    kv_len = kvlen_ref[0]

    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = blk * sb + jax.lax.broadcasted_iota(jnp.int32, (1, sb), 1)
    s = jnp.where(pos < kv_len, s, NEG_INF)            # (G, Sb)

    m_prev = m_ref[...]                                # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                             # (G, Sb)
    corr = jnp.exp(m_prev - m_new)                     # (G, 1)
    l_new = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(blk == nblk - 1)
    def _():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        out_ref[0, 0] = out.astype(out_ref.dtype)


def flash_decode_blocks(q: jax.Array, k: jax.Array, v: jax.Array,
                        kv_len: jax.Array, *, sb: int = 512,
                        interpret: bool = False) -> jax.Array:
    """q: (B, KV, G, D); k/v: (B, S, KV, D); kv_len: (1,) int32.
    Returns (B, KV, G, D). S must be a multiple of sb."""
    B, KV, G, D = q.shape
    S = k.shape[1]
    assert S % sb == 0, (S, sb)
    kernel = functools.partial(_flash_decode_kernel, sb=sb)
    return pl.pallas_call(
        kernel,
        grid=(B, KV, S // sb),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, i: (0,)),
            pl.BlockSpec((1, 1, G, D), lambda b, h, i: (b, h, 0, 0)),
            pl.BlockSpec((1, sb, 1, D), lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((1, sb, 1, D), lambda b, h, i: (b, i, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, i: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len, q, k, v)


def _flash_decode_paged_kernel(kvlen_ref, bt_ref, q_ref, k_ref, v_ref,
                               out_ref, acc_ref, m_ref, l_ref, *, page: int):
    del bt_ref  # consumed by the index maps (scalar prefetch)
    b = pl.program_id(0)
    blk = pl.program_id(2)
    nblk = pl.num_programs(2)

    @pl.when(blk == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                # (G, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)          # (page, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)          # (page, D)
    kv_len = kvlen_ref[b]

    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = blk * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    s = jnp.where(pos < kv_len, s, NEG_INF)            # (G, page)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(blk == nblk - 1)
    def _():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        out_ref[0, 0] = out.astype(out_ref.dtype)


def flash_decode_paged(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                       block_tables: jax.Array, kv_len: jax.Array, *,
                       interpret: bool = False) -> jax.Array:
    """q: (B, KV, G, D); k_pool/v_pool: (P, page, KV, D) physical pages;
    block_tables: (B, nblk) int32 (entry 0 = scratch page); kv_len: (B,)
    int32 per-request valid lengths. Returns (B, KV, G, D).

    Positions >= kv_len[b] are masked, so null table entries (scratch) and
    unwritten page tails contribute nothing.
    """
    B, KV, G, D = q.shape
    page = k_pool.shape[1]
    nblk = block_tables.shape[1]
    kernel = functools.partial(_flash_decode_paged_kernel, page=page)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, nblk),
        in_specs=[
            pl.BlockSpec((1, 1, G, D),
                         lambda b, h, i, kvlen, bt: (b, h, 0, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, i, kvlen, bt: (bt[b, i], 0, h, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, i, kvlen, bt: (bt[b, i], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, i, kvlen, bt: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        interpret=interpret,
    )(jnp.asarray(kv_len, jnp.int32), jnp.asarray(block_tables, jnp.int32),
      q, k_pool, v_pool)
