"""flash_prefill — tiled causal attention forward (online softmax).

The §Perf analysis showed the pure-JAX chunked attention still streams
(q_chunk, S)-sized score tensors through HBM several times per chunk (the
dominant memory term on every big dense train/prefill cell). This kernel
keeps score tiles in VMEM: grid walks (batch, head, q-block, kv-block) with
the kv axis innermost, carrying the online-softmax running max / sum /
accumulator in VMEM scratch — HBM traffic collapses to q, k, v, o.

Causality is exploited at tile granularity: kv-blocks strictly above the
diagonal are skipped via ``pl.when`` (no DMA cost for masked-out tiles on
TPU since the loads are conditional).

GQA: q heads of one kv group are processed together, q laid out as
(B, KV, G, Sq, D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_prefill_kernel(q_ref, k_ref, v_ref, out_ref,
                          acc_ref, m_ref, l_ref, *,
                          bq: int, bkv: int, causal: bool):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # tile is fully masked iff its lowest q position < its first kv position
    run = (not causal) or (qi * bq + bq - 1 >= ki * bkv)

    @pl.when(run)
    def _():
        qf = q_ref[0, 0].astype(jnp.float32)                # (G, bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)           # (bkv, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)           # (bkv, D)
        scale = 1.0 / jnp.sqrt(jnp.asarray(qf.shape[-1], jnp.float32))
        s = jax.lax.dot_general(qf, k, (((2,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        # s: (G, bq, bkv)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (1, bq, 1), 1)
            kpos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bkv), 2)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]                                 # (G, bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, -1, keepdims=True)
        pv = jax.lax.dot_general(p, v, (((2,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        out_ref[0, 0] = out.astype(out_ref.dtype)


def flash_prefill_blocks(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         bq: int = 512, bkv: int = 512, causal: bool = True,
                         interpret: bool = False) -> jax.Array:
    """q: (B, KV, G, Sq, D); k/v: (B, Skv, KV, D) -> (B, KV, G, Sq, D).

    Sq % bq == 0 and Skv % bkv == 0 (ops.py pads).
    """
    B, KV, G, Sq, D = q.shape
    Skv = k.shape[1]
    assert Sq % bq == 0 and Skv % bkv == 0, (Sq, bq, Skv, bkv)
    kernel = functools.partial(_flash_prefill_kernel, bq=bq, bkv=bkv,
                               causal=causal)
    return pl.pallas_call(
        kernel,
        grid=(B, KV, Sq // bq, Skv // bkv),
        in_specs=[
            pl.BlockSpec((1, 1, G, bq, D), lambda b, h, i, j: (b, h, 0, i, 0)),
            pl.BlockSpec((1, bkv, 1, D), lambda b, h, i, j: (b, j, h, 0)),
            pl.BlockSpec((1, bkv, 1, D), lambda b, h, i, j: (b, j, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, bq, D),
                               lambda b, h, i, j: (b, h, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, bq, D), jnp.float32),
            pltpu.VMEM((G, bq, 1), jnp.float32),
            pltpu.VMEM((G, bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
