"""sparse_adamw — fused packed-AdamW update (paper App. D, kernelised).

Packed SHiRA training keeps optimizer state only for the K nonzero values
per matrix. This kernel fuses the whole moment + parameter update over the
packed (…, K) vectors in one pass: 4 reads + 3 writes per element and zero
intermediate HBM traffic (vs ~7 separate elementwise HLO ops).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _adamw_kernel(scal_ref, v_ref, g_ref, m_ref, u_ref,
                  v_out, m_out, u_out):
    lr = scal_ref[0]
    b1 = scal_ref[1]
    b2 = scal_ref[2]
    eps = scal_ref[3]
    wd = scal_ref[4]
    c1 = scal_ref[5]   # 1 - b1**t
    c2 = scal_ref[6]   # 1 - b2**t
    g = g_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    m = b1 * m_ref[...] + (1.0 - b1) * g
    u = b2 * u_ref[...] + (1.0 - b2) * g * g
    mh = m / c1
    uh = u / c2
    delta = mh / (jnp.sqrt(uh) + eps) + wd * v
    v_out[...] = (v - lr * delta).astype(v_out.dtype)
    m_out[...] = m
    u_out[...] = u


def sparse_adamw_blocks(values: jax.Array, grads: jax.Array, mu: jax.Array,
                        nu: jax.Array, scalars: jax.Array, *,
                        block: int = 2048,
                        interpret: bool = False):
    """values/grads/mu/nu: (K,) — pre-padded to a multiple of ``block``.
    scalars: (8,) f32 = [lr, b1, b2, eps, wd, c1, c2, pad]."""
    k = values.shape[0]
    assert k % block == 0, (k, block)
    grid = (k // block,)
    vec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _adamw_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((8,), lambda i: (0,)), vec, vec, vec, vec],
        out_specs=(vec, vec, vec),
        out_shape=(jax.ShapeDtypeStruct((k,), values.dtype),
                   jax.ShapeDtypeStruct((k,), jnp.float32),
                   jax.ShapeDtypeStruct((k,), jnp.float32)),
        interpret=interpret,
    )(scalars, values, grads, mu, nu)
