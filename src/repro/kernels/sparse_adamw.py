"""sparse_adamw — fused packed-AdamW update (paper App. D, kernelised).

Packed SHiRA training keeps optimizer state only for the K nonzero values
per matrix. This kernel fuses the whole moment + parameter update over the
packed (…, K) vectors in one pass: 4 reads + 3 writes per element and zero
intermediate HBM traffic (vs ~7 separate elementwise HLO ops).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _adamw_kernel(scal_ref, v_ref, g_ref, m_ref, u_ref,
                  v_out, m_out, u_out):
    lr = scal_ref[0]
    b1 = scal_ref[1]
    b2 = scal_ref[2]
    eps = scal_ref[3]
    wd = scal_ref[4]
    c1 = scal_ref[5]   # 1 - b1**t
    c2 = scal_ref[6]   # 1 - b2**t
    g = g_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    m = b1 * m_ref[...] + (1.0 - b1) * g
    u = b2 * u_ref[...] + (1.0 - b2) * g * g
    mh = m / c1
    uh = u / c2
    delta = mh / (jnp.sqrt(uh) + eps) + wd * v
    v_out[...] = (v - lr * delta).astype(v_out.dtype)
    m_out[...] = m
    u_out[...] = u


def sparse_adamw_blocks(values: jax.Array, grads: jax.Array, mu: jax.Array,
                        nu: jax.Array, scalars: jax.Array, *,
                        block: int = 2048,
                        interpret: bool = False):
    """values/grads/mu/nu: (K,) — pre-padded to a multiple of ``block``.
    scalars: (8,) f32 = [lr, b1, b2, eps, wd, c1, c2, pad]."""
    k = values.shape[0]
    assert k % block == 0, (k, block)
    grid = (k // block,)
    vec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _adamw_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((8,), lambda i: (0,)), vec, vec, vec, vec],
        out_specs=(vec, vec, vec),
        out_shape=(jax.ShapeDtypeStruct((k,), values.dtype),
                   jax.ShapeDtypeStruct((k,), jnp.float32),
                   jax.ShapeDtypeStruct((k,), jnp.float32)),
        interpret=interpret,
    )(scalars, values, grads, mu, nu)


def _adamw_rows_kernel(scal_ref, v_ref, g_ref, m_ref, u_ref,
                       ms_ref, us_ref, v_out, m_out, u_out, *, qmode):
    """One (row, K-block) tile of the batched update.

    ``qmode`` selects how the incoming moment refs decode:
      - "f32"/"bf16": plain cast (the per-row scale refs are ignored —
        bf16's exponent range covers AdamW moments directly).
      - "int8": symmetric per-row dequant. ``mu`` decodes as ``q * scale``;
        ``nu`` is stored in the *sqrt domain* (``q = sqrt(nu) / scale``) so
        8 bits cover nu's squared dynamic range — decode squares it back.
    Updated moments always leave in f32; re-encoding happens outside the
    kernel so one kernel serves every storage dtype.
    """
    lr = scal_ref[0]
    b1 = scal_ref[1]
    b2 = scal_ref[2]
    eps = scal_ref[3]
    wd = scal_ref[4]
    c1 = scal_ref[5]   # 1 - b1**t
    c2 = scal_ref[6]   # 1 - b2**t
    g = g_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    if qmode == "int8":
        m_prev = m_ref[...].astype(jnp.float32) * ms_ref[0]
        ru = u_ref[...].astype(jnp.float32) * us_ref[0]
        u_prev = ru * ru
    else:
        m_prev = m_ref[...].astype(jnp.float32)
        u_prev = u_ref[...].astype(jnp.float32)
    m = b1 * m_prev + (1.0 - b1) * g
    u = b2 * u_prev + (1.0 - b2) * g * g
    mh = m / c1
    uh = u / c2
    delta = mh / (jnp.sqrt(uh) + eps) + wd * v
    v_out[...] = (v - lr * delta).astype(v_out.dtype)
    m_out[...] = m
    u_out[...] = u


def sparse_adamw_rows(values: jax.Array, grads: jax.Array, mu: jax.Array,
                      nu: jax.Array, mu_scale, nu_scale,
                      scalars: jax.Array, *, block: int = 2048,
                      interpret: bool = False):
    """Batched fused AdamW over row-stacked packed vectors.

    values/grads: (R, K) with K a multiple of ``block``; R is the flattened
    (adapter, leaf-lead) axis so A adapters update in one launch. mu/nu:
    (R, K) in their storage dtype (f32, bf16, or int8). mu_scale/nu_scale:
    (R,) f32 per-row dequant scales, or None when the storage dtype carries
    values directly. scalars: (8,) as in ``sparse_adamw_blocks``. Returns
    (new_values (R, K), mu (R, K) f32, nu (R, K) f32).
    """
    r, k = values.shape
    assert k % block == 0, (k, block)
    qmode = {jnp.int8: "int8"}.get(jnp.dtype(mu.dtype).type, "f32")
    if mu_scale is None:
        mu_scale = jnp.ones((r,), jnp.float32)
    if nu_scale is None:
        nu_scale = jnp.ones((r,), jnp.float32)
    grid = (r, k // block)
    vec = pl.BlockSpec((1, block), lambda i, j: (i, j))
    row = pl.BlockSpec((1,), lambda i, j: (i,))
    return pl.pallas_call(
        functools.partial(_adamw_rows_kernel, qmode=qmode),
        grid=grid,
        in_specs=[pl.BlockSpec((8,), lambda i, j: (0,)),
                  vec, vec, vec, vec, row, row],
        out_specs=(vec, vec, vec),
        out_shape=(jax.ShapeDtypeStruct((r, k), values.dtype),
                   jax.ShapeDtypeStruct((r, k), jnp.float32),
                   jax.ShapeDtypeStruct((r, k), jnp.float32)),
        interpret=interpret,
    )(scalars, values, grads, mu, nu, mu_scale, nu_scale)
