"""scatter_apply — the paper's `torch.Tensor.scatter_` re-thought for TPU.

SHiRA rapid switching overwrites 1-2% of a weight matrix in place. Scalar
scatter is hostile to the TPU memory system, so we adapt the *insight*
(move only the adapter bytes, touch the weight once) to the hierarchy:

  1. host pre-pass (ops.py): bucket the packed (flat_idx, value) updates by
     VMEM tile, producing per-tile padded (row, col, val) buffers + counts;
  2. kernel: grid = weight tiles; each program DMAs its (bn, bm) tile into
     VMEM, applies its bucket with a bounded fori_loop of dynamic stores,
     and writes the tile back. Tiles with empty buckets skip the update
     (input/output aliasing keeps them untouched) — with SHiRA-Struct masks
     whole tile rows short-circuit, so only dirty tiles cost stores.

W_out = W + alpha * scatter(vals)  (delta form: load = +alpha, unload = -alpha)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scatter_kernel(counts_ref, alpha_ref, rows_ref, cols_ref, vals_ref,
                    w_ref, out_ref, *, max_updates: int):
    cnt = counts_ref[0, 0]
    out_ref[...] = w_ref[...]

    @pl.when(cnt > 0)
    def _():
        alpha = alpha_ref[0]

        def body(u, _):
            @pl.when(u < cnt)
            def _():
                r = rows_ref[0, 0, u]
                c = cols_ref[0, 0, u]
                v = vals_ref[0, 0, u]
                cur = pl.load(out_ref, (pl.dslice(r, 1), pl.dslice(c, 1)))
                pl.store(out_ref, (pl.dslice(r, 1), pl.dslice(c, 1)),
                         cur + (alpha * v).astype(out_ref.dtype))
            return ()

        jax.lax.fori_loop(0, max_updates, body, ())


def scatter_apply_tiles(w: jax.Array, counts: jax.Array, rows: jax.Array,
                        cols: jax.Array, vals: jax.Array, alpha: jax.Array,
                        *, bn: int = 256, bm: int = 256,
                        interpret: bool = False) -> jax.Array:
    """w: (n, m); counts: (nt_i, nt_j) int32; rows/cols: (nt_i, nt_j, U)
    int32 tile-local coordinates; vals: (nt_i, nt_j, U) f32; alpha: (1,) f32.
    """
    n, m = w.shape
    assert n % bn == 0 and m % bm == 0, (n, m, bn, bm)
    nt_i, nt_j = n // bn, m // bm
    max_updates = rows.shape[-1]
    kernel = functools.partial(_scatter_kernel, max_updates=max_updates)
    return pl.pallas_call(
        kernel,
        grid=(nt_i, nt_j),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((1, 1, max_updates), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, max_updates), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, max_updates), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), w.dtype),
        input_output_aliases={5: 0},
        interpret=interpret,
    )(counts, alpha, rows, cols, vals, w)
