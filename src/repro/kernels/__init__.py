# Pallas TPU kernels for the perf-critical paths the paper optimises:
#   scatter_apply  — rapid adapter switching (paper App. B `scatter_op`)
#   sidedelta      — per-request batched sparse side-delta (multi-tenant)
#   masked_update  — dense-mask fused apply (vectorised alternative)
#   sparse_adamw   — packed optimizer update (paper App. D)
#   flash_decode   — blocked decode attention (the serving hot loop)
# Validated against ref.py oracles in interpret mode (CPU container); the
# BlockSpecs target TPU VMEM tiling.
from repro.kernels import ops, ref  # noqa: F401
from repro.kernels.ops import (bucket_updates, flash_decode,  # noqa: F401
                               masked_update, scatter_apply, sidedelta,
                               sidedelta_table, sparse_adamw)
