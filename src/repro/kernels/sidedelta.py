"""sidedelta — per-request batched sparse side-delta matmul (multi-tenant).

Multi-tenant SHiRA serving keeps ONE shared copy of the base weights and
gives every request in a batch its own adapter. Instead of patching the
weight per request (which would serialize the batch), the forward pass adds
each request's sparse delta as a side term:

  y[b] = x[b] @ W_shared  +  x[b] @ dW_{id[b]},   dW sparse with K nonzeros

The side term never materialises dW: an adapter is a packed table of
(row, col, val) triples, and the kernel computes, for request b with
adapter a = ids[b],

  delta[b, :, cols[a, k]] += x[b, :, rows[a, k]] * vals[a, k]   for all k

i.e. a gather of K input columns fused with a scatter-accumulate into K
output columns, vectorised over the request's S tokens per nonzero.

TPU mapping: grid = (B,). ``ids`` is a scalar-prefetch operand
(PrefetchScalarGridSpec), so the BlockSpec index maps can route program b
to *its adapter's* (rows, cols, vals) block — only the selected adapter's
K-entry table is DMA'd into VMEM, not the whole registry. ids[b] < 0 means
"no adapter": the index map clamps to slot 0 and the kernel body skips all
stores, leaving delta[b] = 0.

The delta accumulates in f32 regardless of the compute dtype (the caller
adds it onto the base matmul's output), so batched multi-tenant serving
matches the sequential switch-per-batch path to fp32 accuracy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _sidedelta_kernel(ids_ref, x_ref, rows_ref, cols_ref, vals_ref, out_ref,
                      *, max_nnz: int):
    b = pl.program_id(0)
    out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(ids_ref[b] >= 0)
    def _():
        def body(k, _):
            r = rows_ref[0, k]
            c = cols_ref[0, k]
            v = vals_ref[0, k]
            xc = pl.load(x_ref, (pl.dslice(0, 1), slice(None),
                                 pl.dslice(r, 1)))
            cur = pl.load(out_ref, (pl.dslice(0, 1), slice(None),
                                    pl.dslice(c, 1)))
            pl.store(out_ref, (pl.dslice(0, 1), slice(None), pl.dslice(c, 1)),
                     cur + xc.astype(jnp.float32) * v)
            return ()

        jax.lax.fori_loop(0, max_nnz, body, ())


def sidedelta_rows(x: jax.Array, rows: jax.Array, cols: jax.Array,
                   vals: jax.Array, ids: jax.Array, m: int,
                   *, interpret: bool = False) -> jax.Array:
    """x: (B, S, n); rows/cols: (A, K) int32 per-adapter coordinates into
    (n, m); vals: (A, K) f32 (zero-padded); ids: (B,) int32 adapter slot per
    request, -1 = base model. Returns delta (B, S, m) f32."""
    B, S, n = x.shape
    A, K = rows.shape
    kernel = functools.partial(_sidedelta_kernel, max_nnz=K)

    def slot(b, ids):
        return (jnp.maximum(ids[b], 0), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, S, n), lambda b, ids: (b, 0, 0)),
            pl.BlockSpec((1, K), slot),
            pl.BlockSpec((1, K), slot),
            pl.BlockSpec((1, K), slot),
        ],
        out_specs=pl.BlockSpec((1, S, m), lambda b, ids: (b, 0, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, S, m), jnp.float32),
        interpret=interpret,
    )(ids, x, rows, cols, vals)
