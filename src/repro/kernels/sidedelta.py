"""sidedelta v2 — tiled, vectorised per-request sparse side-delta matmul.

Multi-tenant SHiRA serving keeps ONE shared copy of the base weights and
gives every request in a batch its own adapter. Instead of patching the
weight per request (which would serialize the batch), the forward pass adds
each request's sparse delta as a side term:

  y[b] = x[b] @ W_shared  +  x[b] @ dW_{id[b]},   dW sparse with K nonzeros

The side term never materialises dW: an adapter is a packed table of
(row, col, val) triples, and the kernel computes, for request b with
adapter a = ids[b],

  delta[b, :, cols[a, k]] += x[b, :, rows[a, k]] * vals[a, k]   for all k

Design (v2 — compiled-mode):

  * Grid = (B, m_tiles): the output is m-tiled into (S, bm) blocks so that
    large d_ff fits VMEM — v1's single (S, m) output block made compiled
    execution infeasible for real MLP widths. ``plan_tiles`` picks (bm, kc)
    from (S, n, m, K) under a VMEM byte budget.
  * Vectorised body: no per-nonzero scalar stores. The K input columns are
    gathered as an (S, K) block with a one-hot matmul
    (x (S, n) @ onehot(rows) (n, K)), scaled by ``vals`` once, and cached
    in VMEM scratch that persists across the m-tile loop (recomputed only
    when the batch index changes, i.e. at m-tile 0). Each m-tile then
    scatter-accumulates with a second one-hot/segment-sum matmul
    ((S, K) @ onehot(cols - tile_start) (K, bm)); nonzeros whose column
    falls outside the current tile produce an all-zero one-hot row, which
    is exactly the required mask. Both matmuls run on the MXU; chunking
    over K in steps of ``kc`` bounds the one-hot VMEM footprint.
  * int8 tables in VMEM: ``vals`` may be int8 with a per-adapter f32
    ``scale`` (scalar-prefetch operand); the kernel dequantises AFTER the
    DMA, inside VMEM, so adapter HBM at serve time shrinks ~4x vs f32
    values. ``rows``/``cols`` may be int16 when both dims fit, shrinking
    the index tables 2x on top.
  * ``ids`` and ``scale`` are scalar-prefetch operands
    (PrefetchScalarGridSpec): the BlockSpec index maps route program b to
    *its adapter's* (rows, cols, vals) block — only the selected adapter's
    K-entry table is DMA'd into VMEM, not the whole registry. ids[b] < 0
    means "no adapter": the index map clamps to slot 0 and the kernel
    zeroes the output block.

Backends: ``interpret=True`` runs the Pallas interpreter (kernel-body
emulation, any backend). ``interpret=False`` compiles — through Mosaic on
TPU, and on non-TPU backends (where this jax has no compiled Pallas
lowering) through ``_sidedelta_xla``, an XLA formulation of the *same* tile
plan: identical (bm, kc) tiling, the same local-column masking, the same
int8 dequant placement. That keeps the tiling/masking/dequant logic
exercised by a genuinely compiled executable in CPU CI, guarding the shape
bookkeeping against TPU-only lowering surprises.

The delta accumulates in f32 regardless of the compute dtype (the caller
adds it onto the base matmul's output), so batched multi-tenant serving
matches the sequential switch-per-batch path to fp32 accuracy.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Default VMEM byte budget for one program's working set. TPU cores have
# ~16 MB of VMEM; half is left for double-buffered DMA and the compiler.
DEFAULT_VMEM_BUDGET = 8 * 1024 * 1024

_LANE = 128          # TPU lane width: last-dim tile granularity


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


# ---------------------------------------------------------------------------
# Autotuned plan cache. ``analysis/autotune.py`` sweeps (bm, kc) candidates
# per (S, n, m, K) shape class against measured step times and installs the
# winners here; ``plan_tiles`` consults the cache before falling back to the
# static VMEM-budget heuristic. Cached plans are validated on lookup (tile
# alignment AND the budget bound) so a stale or hand-edited cache can never
# produce an over-budget or misaligned kernel — it just misses.
# ---------------------------------------------------------------------------

PlanKey = Tuple[int, int, int, int, int, int]     # S, n, m, K, budget, isize

_PLAN_CACHE: Dict[PlanKey, Tuple[int, int]] = {}
plan_cache_stats = {"hits": 0, "misses": 0, "rejected": 0}


def plan_cache_key(S: int, n: int, m: int, K: int,
                   vmem_budget: int = DEFAULT_VMEM_BUDGET,
                   x_itemsize: int = 4) -> PlanKey:
    """One shape class = one cache entry; the budget and input itemsize are
    part of the class (they change the feasible plan set)."""
    return (int(S), int(n), int(m), int(K), int(vmem_budget), int(x_itemsize))


def plan_is_valid(S: int, n: int, m: int, K: int, bm: int, kc: int,
                  *, vmem_budget: int = DEFAULT_VMEM_BUDGET,
                  x_itemsize: int = 4) -> bool:
    """A usable (bm, kc): lane-aligned, positive, and within the budget
    (best-effort like ``plan_tiles``: the minimum plan is always valid)."""
    if bm < _LANE or kc < _LANE or bm % _LANE or kc % _LANE:
        return False
    if bm == _LANE and kc == _LANE:
        return True                  # the floor plan_tiles itself falls to
    return vmem_estimate(S, n, m, K, bm, kc,
                         x_itemsize=x_itemsize) <= vmem_budget


def install_plan_cache(plans: Dict[PlanKey, Tuple[int, int]],
                       replace: bool = False) -> int:
    """Merge autotuned plans into the cache; returns entries installed."""
    global _PLAN_CACHE
    if replace:
        _PLAN_CACHE = {}
    for key, (bm, kc) in plans.items():
        _PLAN_CACHE[tuple(int(x) for x in key)] = (int(bm), int(kc))
    return len(_PLAN_CACHE)


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()
    for k in plan_cache_stats:
        plan_cache_stats[k] = 0


def plan_cache() -> Dict[PlanKey, Tuple[int, int]]:
    return dict(_PLAN_CACHE)


def vmem_estimate(S: int, n: int, m: int, K: int, bm: int, kc: int,
                  *, x_itemsize: int = 4, idx_itemsize: int = 4,
                  val_itemsize: int = 4) -> int:
    """Bytes one grid program keeps live in VMEM under the v2 tile plan."""
    x_block = S * n * x_itemsize
    xs_scratch = S * K * 4                      # gathered+scaled f32 cache
    tables = K * (2 * idx_itemsize + val_itemsize)
    onehot_gather = n * kc * 4                  # j == 0 only
    onehot_scatter = kc * bm * 4
    out_block = S * bm * 4
    return (x_block + xs_scratch + tables + out_block
            + max(onehot_gather, onehot_scatter))


def plan_tiles(S: int, n: int, m: int, K: int,
               *, vmem_budget: int = DEFAULT_VMEM_BUDGET,
               x_itemsize: int = 4) -> Tuple[int, int]:
    """Pick (bm, kc) so one program's working set fits ``vmem_budget``.

    bm is the output m-tile (multiple of 128, <= padded m); kc the K-chunk
    both one-hot matmuls step by. Fixed costs (the x block, the (S, K)
    scratch, the tables) are paid regardless; the free variables trade the
    one-hot buffers against the budget remainder. Best-effort: if even the
    minimum (128, 128) plan exceeds the budget the minimum is returned —
    the caller wanted a kernel, not an exception.

    An autotuned plan cache (``install_plan_cache``, populated by
    ``analysis/autotune.py`` from measured step times) is consulted first;
    invalid cached plans are rejected, never trusted."""
    cached = _PLAN_CACHE.get(plan_cache_key(S, n, m, K, vmem_budget,
                                            x_itemsize))
    if cached is not None:
        bm_c, kc_c = cached
        if plan_is_valid(S, n, m, K, bm_c, kc_c, vmem_budget=vmem_budget,
                         x_itemsize=x_itemsize):
            plan_cache_stats["hits"] += 1
            return int(bm_c), int(kc_c)
        plan_cache_stats["rejected"] += 1
    plan_cache_stats["misses"] += 1
    m_pad = _round_up(max(m, 1), _LANE)
    K_pad = _round_up(max(K, 1), _LANE)
    kc = min(K_pad, 512)
    while True:
        fixed = S * n * x_itemsize + S * K_pad * 4 + K_pad * 12 + n * kc * 4
        room = max(vmem_budget - fixed, 0)
        # per-bm cost: out block (S rows) + scatter one-hot (kc rows), f32
        bm = (room // ((S + kc) * 4)) // _LANE * _LANE
        bm = max(min(bm, m_pad), _LANE)
        while bm > _LANE and m_pad % bm:
            bm -= _LANE             # keep the grid exact: bm | padded m
        if kc <= _LANE or vmem_estimate(S, n, m, K, bm, kc,
                                        x_itemsize=x_itemsize) <= vmem_budget:
            return int(bm), int(kc)
        kc -= _LANE                 # fixed costs too big: smaller K chunk


# ---------------------------------------------------------------------------
# Pallas kernel body
# ---------------------------------------------------------------------------

def _sidedelta_kernel(ids_ref, scale_ref, x_ref, rows_ref, cols_ref,
                      vals_ref, out_ref, xs_ref, *, n: int, bm: int, kc: int,
                      nchunks: int):
    b = pl.program_id(0)
    j = pl.program_id(1)
    slot = jnp.maximum(ids_ref[b], 0)
    sc = scale_ref[slot]

    @pl.when(j == 0)
    def _gather():
        # xs[:, k] = x[:, rows[k]] * vals[k] * scale — cached for every
        # m-tile of this request (the grid iterates j innermost).
        xb = x_ref[0].astype(jnp.float32)                      # (S, n)

        def chunk(i, _):
            sl = (pl.dslice(0, 1), pl.dslice(i * kc, kc))
            r = pl.load(rows_ref, sl)[0].astype(jnp.int32)     # (kc,)
            v = pl.load(vals_ref, sl)[0].astype(jnp.float32) * sc
            onehot = (jax.lax.broadcasted_iota(jnp.int32, (n, kc), 0)
                      == r[None, :]).astype(jnp.float32)
            xg = jax.lax.dot_general(
                xb, onehot, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)            # (S, kc)
            pl.store(xs_ref, (slice(None), pl.dslice(i * kc, kc)),
                     xg * v[None, :])
            return ()

        jax.lax.fori_loop(0, nchunks, chunk, ())

    def chunk(i, acc):
        sl = (pl.dslice(0, 1), pl.dslice(i * kc, kc))
        local = pl.load(cols_ref, sl)[0].astype(jnp.int32) - j * bm
        xs = pl.load(xs_ref, (slice(None), pl.dslice(i * kc, kc)))
        # nonzeros outside this m-tile get an all-zero one-hot row: the
        # segment-sum matmul masks them for free.
        onehot = (jax.lax.broadcasted_iota(jnp.int32, (kc, bm), 1)
                  == local[:, None]).astype(jnp.float32)
        return acc + jax.lax.dot_general(
            xs, onehot, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    acc = jax.lax.fori_loop(0, nchunks, chunk,
                            jnp.zeros((xs_ref.shape[0], bm), jnp.float32))
    out_ref[0] = acc * (ids_ref[b] >= 0).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Compiled non-TPU dispatch: the same tile plan through XLA
# ---------------------------------------------------------------------------

def _sidedelta_xla(x: jax.Array, rows: jax.Array, cols: jax.Array,
                   vals: jax.Array, scale: jax.Array, ids: jax.Array,
                   m: int, bm: int, kc: int) -> jax.Array:
    """XLA twin of the kernel: per-request gather once, then a sequential
    map over m-tiles, each scatter-accumulating in the same kc-sized K
    chunks with the identical local-column one-hot mask (so the chunk
    bookkeeping — K padding, chunk count — is exercised by compiled CPU
    runs too, not only in interpret mode). Peak memory stays
    O(B*S*K + B*kc*bm) — dW is never materialised."""
    B, S, n = x.shape
    K = rows.shape[-1]                                    # pre-padded to kc
    slot = jnp.maximum(ids, 0)
    r = rows[slot].astype(jnp.int32)                      # (B, K)
    c = cols[slot].astype(jnp.int32)
    v = vals[slot].astype(jnp.float32) * scale[slot][:, None]
    xs = jax.vmap(lambda xb, rb: xb.astype(jnp.float32)[:, rb])(x, r)
    xs = (xs * v[:, None, :]).reshape(B, S, K // kc, kc)
    c = c.reshape(B, K // kc, kc)
    mt = _round_up(m, bm) // bm

    def tile(j):
        def chunk(i):
            local = c[:, i] - j * bm                      # (B, kc)
            onehot = (local[..., None]
                      == jnp.arange(bm)[None, None, :]).astype(jnp.float32)
            return jnp.einsum("bsk,bkc->bsc", xs[:, :, i], onehot)
        return jnp.sum(jax.lax.map(chunk, jnp.arange(K // kc)), axis=0)

    out = jax.lax.map(tile, jnp.arange(mt))               # (mt, B, S, bm)
    out = jnp.moveaxis(out, 0, 2).reshape(B, S, mt * bm)[..., :m]
    return jnp.where((ids >= 0)[:, None, None], out, 0.0)


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------

def sidedelta_rows(x: jax.Array, rows: jax.Array, cols: jax.Array,
                   vals: jax.Array, ids: jax.Array, m: int,
                   *, scale: Optional[jax.Array] = None,
                   interpret=False,
                   bm: Optional[int] = None, kc: Optional[int] = None,
                   vmem_budget: int = DEFAULT_VMEM_BUDGET) -> jax.Array:
    """x: (B, S, n); rows/cols: (A, K) int32 (or int16) per-adapter
    coordinates into (n, m); vals: (A, K) f32 or int8 (zero-padded);
    scale: (A,) f32 per-adapter dequant scale (None = 1, i.e. f32 tables);
    ids: (B,) int32 adapter slot per request, -1 = base model.
    Returns delta (B, S, m) f32.

    ``interpret`` selects the execution mode: ``False`` compiles (Pallas on
    TPU, the XLA twin elsewhere), ``True`` runs the Pallas kernel in
    interpret mode, and ``"xla"`` forces the XLA twin on every backend —
    the twin is pure jnp and therefore differentiable w.r.t. ``vals``,
    which is what the multi-adapter trainer's forward pass relies on.

    ``bm``/``kc`` override the tile plan (defaults from ``plan_tiles``
    under ``vmem_budget``)."""
    B, S, n = x.shape
    A, K = rows.shape
    if scale is None:
        scale = jnp.ones((A,), jnp.float32)
    if K == 0:
        return jnp.zeros((B, S, m), jnp.float32)
    plan_bm, plan_kc = plan_tiles(S, n, m, K, vmem_budget=vmem_budget,
                                  x_itemsize=x.dtype.itemsize)
    bm = bm or plan_bm
    kc = kc or plan_kc
    m_pad = _round_up(m, bm)
    K_pad = _round_up(K, kc)
    if K_pad != K:
        pad = ((0, 0), (0, K_pad - K))
        rows = jnp.pad(rows, pad)       # padded entries: (0, 0) with val 0,
        cols = jnp.pad(cols, pad)       # a harmless +0 in the segment sum
        vals = jnp.pad(vals, pad)
    if interpret == "xla" or (
            not interpret and jax.default_backend() != "tpu"):
        # this jax has no compiled Pallas path off-TPU: run the same tile
        # plan through XLA so compiled-mode CI still exercises it
        return _sidedelta_xla(x, rows, cols, vals, scale, ids, m, bm, kc)
    mt = m_pad // bm
    kernel = functools.partial(_sidedelta_kernel, n=n, bm=bm, kc=kc,
                               nchunks=K_pad // kc)

    def slot_map(b, j, ids, scale):
        return (jnp.maximum(ids[b], 0), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, mt),
        in_specs=[
            pl.BlockSpec((1, S, n), lambda b, j, ids, scale: (b, 0, 0)),
            pl.BlockSpec((1, K_pad), slot_map),
            pl.BlockSpec((1, K_pad), slot_map),
            pl.BlockSpec((1, K_pad), slot_map),
        ],
        out_specs=pl.BlockSpec((1, S, bm),
                               lambda b, j, ids, scale: (b, 0, j)),
        scratch_shapes=[pltpu.VMEM((S, K_pad), jnp.float32)],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, S, m_pad), jnp.float32),
        interpret=interpret,
    )(ids, scale, x, rows, cols, vals)
    return out[..., :m]
