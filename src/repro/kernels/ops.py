"""jit'd wrappers: host-side prep + pallas_call dispatch for every kernel.

``bucket_updates`` is the host pre-pass for scatter_apply: it converts a
packed (flat_idx, values) adapter into per-VMEM-tile buckets. It runs once
per adapter at registration time (numpy), not per switch.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_decode import flash_decode_blocks
from repro.kernels.masked_update import masked_update_tiles
from repro.kernels.scatter_apply import scatter_apply_tiles
from repro.kernels.sidedelta import sidedelta_rows
from repro.kernels.sparse_adamw import sparse_adamw_blocks, sparse_adamw_rows


# ---------------------------------------------------------------------------
# scatter_apply
# ---------------------------------------------------------------------------

def bucket_updates(flat_idx: np.ndarray, vals: np.ndarray, n: int, m: int,
                   bn: int = 256, bm: int = 256
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Bucket packed updates by (bn, bm) tile.

    Returns (counts (nt_i, nt_j), rows, cols, vals) with rows/cols tile-local
    and padded to the max bucket size (zero-padded entries are masked out by
    the per-tile count in the kernel)."""
    flat_idx = np.asarray(flat_idx, np.int64)
    vals = np.asarray(vals, np.float32)
    r = flat_idx // m
    c = flat_idx % m
    ti = r // bn
    tj = c // bm
    nt_i, nt_j = n // bn, m // bm
    tile_id = ti * nt_j + tj
    order = np.argsort(tile_id, kind="stable")
    tile_id_s = tile_id[order]
    counts = np.bincount(tile_id_s, minlength=nt_i * nt_j)
    u = max(int(counts.max()), 1)
    rows = np.zeros((nt_i * nt_j, u), np.int32)
    cols = np.zeros((nt_i * nt_j, u), np.int32)
    vbuf = np.zeros((nt_i * nt_j, u), np.float32)
    starts = np.concatenate([[0], np.cumsum(counts)])
    r_s, c_s, v_s = r[order], c[order], vals[order]
    for t in range(nt_i * nt_j):
        s, e = starts[t], starts[t + 1]
        k = e - s
        if k:
            rows[t, :k] = (r_s[s:e] % bn).astype(np.int32)
            cols[t, :k] = (c_s[s:e] % bm).astype(np.int32)
            vbuf[t, :k] = v_s[s:e]
    return (counts.reshape(nt_i, nt_j).astype(np.int32),
            rows.reshape(nt_i, nt_j, u), cols.reshape(nt_i, nt_j, u),
            vbuf.reshape(nt_i, nt_j, u))


@functools.partial(jax.jit, static_argnames=("bn", "bm", "interpret"))
def scatter_apply(w, counts, rows, cols, vals, alpha, *, bn=256, bm=256,
                  interpret=False):
    alpha = jnp.asarray(alpha, jnp.float32).reshape(1)
    return scatter_apply_tiles(w, counts, rows, cols, vals, alpha,
                               bn=bn, bm=bm, interpret=interpret)


# ---------------------------------------------------------------------------
# sidedelta (multi-tenant per-request adapters)
# ---------------------------------------------------------------------------

def sidedelta_table(flat_idx: np.ndarray, vals: np.ndarray, m: int, pad_to: int
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host pre-pass: one adapter's packed (flat_idx, vals) over an (n, m)
    weight -> (rows, cols, vals) each (pad_to,), zero-padded. Padded entries
    point at (0, 0) with val 0, which the kernel applies as a harmless +0.
    Runs once per adapter at registration time, not per batch."""
    flat_idx = np.asarray(flat_idx, np.int64).reshape(-1)
    vals = np.asarray(vals, np.float32).reshape(-1)
    k = flat_idx.shape[0]
    assert k <= pad_to, (k, pad_to)
    rows = np.zeros((pad_to,), np.int32)
    cols = np.zeros((pad_to,), np.int32)
    vbuf = np.zeros((pad_to,), np.float32)
    rows[:k] = (flat_idx // m).astype(np.int32)
    cols[:k] = (flat_idx % m).astype(np.int32)
    vbuf[:k] = vals
    return rows, cols, vbuf


def quantize_table(vals: np.ndarray) -> Tuple[np.ndarray, float]:
    """Symmetric int8 quantization of one adapter's (K,) value table.
    Returns (q int8, scale) with q * scale ~= vals; scale 1.0 for an
    all-zero table so padded slots dequantize to exact zeros."""
    vals = np.asarray(vals, np.float32)
    amax = float(np.max(np.abs(vals))) if vals.size else 0.0
    scale = amax / 127.0 if amax > 0 else 1.0
    q = np.clip(np.rint(vals / scale), -127, 127).astype(np.int8)
    return q, scale


@functools.partial(jax.jit,
                   static_argnames=("m", "interpret", "bm", "kc"))
def sidedelta(x, rows, cols, vals, ids, *, m, scale=None, interpret=False,
              bm=None, kc=None):
    """Batched per-request sparse delta: (B, S, m) f32 with
    delta[b] = x[b] @ dW_{ids[b]} (ids[b] < 0 -> zeros). ``vals`` may be
    int8 with per-adapter ``scale`` (dequantised inside the kernel);
    ``bm``/``kc`` override the VMEM tile plan."""
    return sidedelta_rows(x, rows, cols, vals, ids, m, scale=scale,
                          interpret=interpret, bm=bm, kc=kc)


# ---------------------------------------------------------------------------
# masked_update
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("bn", "bm", "interpret"))
def masked_update(w, mask, vals, alpha, *, bn=256, bm=256, interpret=False):
    alpha = jnp.asarray(alpha, jnp.float32).reshape(1)
    return masked_update_tiles(w, mask, vals, alpha, bn=bn, bm=bm,
                               interpret=interpret)


# ---------------------------------------------------------------------------
# sparse_adamw
# ---------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("lr", "b1", "b2", "eps", "wd", "block",
                                    "interpret"))
def sparse_adamw(values, grads, mu, nu, step, *, lr=1e-3, b1=0.9, b2=0.999,
                 eps=1e-8, wd=0.0, block=2048, interpret=False):
    k = values.shape[0]
    pad = (-k) % block
    if pad:
        z = lambda x: jnp.pad(x, (0, pad))
        values, grads, mu, nu = z(values), z(grads), z(mu), z(nu)
    stepf = step.astype(jnp.float32)
    scalars = jnp.stack([
        jnp.asarray(lr, jnp.float32), jnp.asarray(b1, jnp.float32),
        jnp.asarray(b2, jnp.float32), jnp.asarray(eps, jnp.float32),
        jnp.asarray(wd, jnp.float32),
        1.0 - jnp.asarray(b1, jnp.float32) ** stepf,
        1.0 - jnp.asarray(b2, jnp.float32) ** stepf,
        jnp.zeros((), jnp.float32)])
    v, m, u = sparse_adamw_blocks(values, grads, mu, nu, scalars,
                                  block=block, interpret=interpret)
    if pad:
        v, m, u = v[:k], m[:k], u[:k]
    return v, m, u


def _adamw_scalars(step, lr, b1, b2, eps, wd):
    stepf = step.astype(jnp.float32)
    return jnp.stack([
        jnp.asarray(lr, jnp.float32), jnp.asarray(b1, jnp.float32),
        jnp.asarray(b2, jnp.float32), jnp.asarray(eps, jnp.float32),
        jnp.asarray(wd, jnp.float32),
        1.0 - jnp.asarray(b1, jnp.float32) ** stepf,
        1.0 - jnp.asarray(b2, jnp.float32) ** stepf,
        jnp.zeros((), jnp.float32)])


@functools.partial(jax.jit,
                   static_argnames=("b1", "b2", "eps", "wd", "block",
                                    "interpret"))
def sparse_adamw_batched(values, grads, mu, nu, step, *, lr, b1=0.9,
                         b2=0.999, eps=1e-8, wd=0.0, mu_scale=None,
                         nu_scale=None, block=2048, interpret=False):
    """Batched fused AdamW over (R, K) row-stacked packed values.

    Rows are independent (adapter, leaf) vectors, so A adapters update in
    one kernel launch. ``mu``/``nu`` may be stored f32, bf16, or int8 with
    per-row ``mu_scale``/``nu_scale`` (see ``sparse_adamw_rows`` for the
    int8 encoding); updated moments are always returned f32 — the caller
    re-encodes. ``lr`` is traced (it follows a schedule); ``step`` is the
    1-based optimizer step used for bias correction."""
    r, k = values.shape
    pad = (-k) % block
    if pad:
        z = lambda x: jnp.pad(x, ((0, 0), (0, pad)))
        values, grads, mu, nu = z(values), z(grads), z(mu), z(nu)
    scalars = _adamw_scalars(step, lr, b1, b2, eps, wd)
    v, m, u = sparse_adamw_rows(values, grads, mu, nu, mu_scale, nu_scale,
                                scalars, block=block, interpret=interpret)
    if pad:
        v, m, u = v[:, :k], m[:, :k], u[:, :k]
    return v, m, u


# ---------------------------------------------------------------------------
# flash_decode
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("sb", "interpret"))
def flash_decode(q, k, v, kv_len, *, sb=512, interpret=False):
    """q: (B, KV, G, D); k/v: (B, S, KV, D); kv_len scalar int32."""
    S = k.shape[1]
    pad = (-S) % sb
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kv_len = jnp.asarray(kv_len, jnp.int32).reshape(1)
    return flash_decode_blocks(q, k, v, kv_len, sb=sb, interpret=interpret)


# ---------------------------------------------------------------------------
# flash_prefill
# ---------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("bq", "bkv", "causal", "interpret"))
def flash_prefill(q, k, v, *, bq=512, bkv=512, causal=True, interpret=False):
    """q: (B, Sq, H, D); k/v: (B, Skv, KV, D). Returns (B, Sq, H, D).

    Handles GQA layout conversion + padding; Sq/Skv padded to the block
    sizes (padded kv masked by causality when causal; for bidirectional use
    only with already-aligned Skv)."""
    from repro.kernels.flash_prefill import flash_prefill_blocks
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    pq = (-Sq) % bq
    pk = (-k.shape[1]) % bkv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    qg = jnp.moveaxis(q.reshape(B, Sq + pq, KV, G, D), 1, 3)  # (B,KV,G,Sq,D)
    out = flash_prefill_blocks(qg, k, v, bq=bq, bkv=bkv, causal=causal,
                               interpret=interpret)
    out = jnp.moveaxis(out, 3, 1).reshape(B, Sq + pq, H, D)
    return out[:, :Sq]
