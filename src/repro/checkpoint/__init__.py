from repro.checkpoint.manager import CheckpointManager, restore_tree, save_tree  # noqa: F401
# adapter-pack (format v2) I/O lives in repro.hub.packio; CheckpointManager
# defers its imports into save_adapter/restore_adapter so importing
# repro.checkpoint stays light (no serving/model stack)
