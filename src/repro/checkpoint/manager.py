"""Checkpointing: atomic, keep-K, mesh-independent, adapter-aware.

Trees are flattened to path->ndarray and stored as ``.npz`` plus a JSON
manifest. Writes go to a temp dir then ``os.replace`` (atomic on POSIX), so
a preempted save never corrupts the latest checkpoint. Restore takes a
*template* tree (for structure and dtypes) and an optional NamedSharding
tree, so a checkpoint written on mesh A can be restored onto mesh B
(elastic re-scale) — device layout is never serialized.

Adapter-only checkpoints: packed SHiRA trainables are ~1-2% of model bytes,
so adapter snapshots are cheap enough to take every step if desired.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.core.masks import path_str


def _flatten(tree) -> Dict[str, np.ndarray]:
    out = {}
    for p, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # npz can't store ml_dtypes: view as u16
            arr = arr.view(np.uint16)
        out[path_str(p)] = arr
    return out


def save_tree(tree, directory: str, name: str = "state") -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    tmp = tempfile.mkdtemp(dir=directory)
    try:
        np.savez(os.path.join(tmp, name + ".npz"), **flat)
        manifest = {
            "keys": sorted(flat.keys()),
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "time": time.time(),
        }
        with open(os.path.join(tmp, name + ".json"), "w") as f:
            json.dump(manifest, f)
        final_npz = os.path.join(directory, name + ".npz")
        final_json = os.path.join(directory, name + ".json")
        os.replace(os.path.join(tmp, name + ".npz"), final_npz)
        os.replace(os.path.join(tmp, name + ".json"), final_json)
        return final_npz
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def restore_tree(template, directory: str, name: str = "state",
                 shardings=None):
    """Restore into the template's structure; optionally device_put with the
    given sharding tree (possibly for a different mesh than the writer's)."""
    data = np.load(os.path.join(directory, name + ".npz"))
    flat_paths = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat_paths[0]:
        key = path_str(p)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != template {leaf.shape}")
        if (getattr(leaf.dtype, "name", str(leaf.dtype)) == "bfloat16"
                and arr.dtype == np.uint16):
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        leaves.append(arr.astype(leaf.dtype))
    tree = jax.tree_util.tree_unflatten(flat_paths[1], leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else x,
            tree, shardings, is_leaf=lambda x: x is None)
    return tree


class CheckpointManager:
    """step-numbered checkpoints with atomic writes and keep-K GC."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.root, d, "COMMITTED")):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def save(self, step: int, trees: Dict[str, Any],
             meta: Optional[dict] = None) -> str:
        d = self._step_dir(step)
        os.makedirs(d, exist_ok=True)
        for name, tree in trees.items():
            save_tree(tree, d, name)
        with open(os.path.join(d, "meta.json"), "w") as f:
            json.dump({"step": step, **(meta or {})}, f)
        # commit marker makes partially-written checkpoints invisible
        with open(os.path.join(d, "COMMITTED"), "w") as f:
            f.write(str(time.time()))
        self._gc()
        return d

    def restore(self, templates: Dict[str, Any], step: Optional[int] = None,
                shardings: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints in {self.root}")
        d = self._step_dir(step)
        out = {"step": step}
        for name, tpl in templates.items():
            sh = (shardings or {}).get(name)
            out[name] = restore_tree(tpl, d, name, sh)
        return out

    # ------------------------------------------------------------------
    # Adapter packs: first-class checkpoint artifacts (format v2, repro.hub)
    # ------------------------------------------------------------------

    def save_adapter(self, step: int, pack, values: str = "f32") -> str:
        """Write an adapter pack into the step's directory. Packs are tiny
        (1-2% of model bytes, less in int8), so snapshotting one per step is
        cheap; it becomes visible with the step's COMMITTED marker (written
        by ``save``), keeping adapter and optimizer state consistent."""
        from repro.hub.packio import save_pack
        d = self._step_dir(step)
        os.makedirs(d, exist_ok=True)
        return save_pack(pack, os.path.join(d, f"adapter_{pack.name}.shpk"),
                         values=values)

    def adapters(self, step: int) -> List[str]:
        d = self._step_dir(step)
        if not os.path.isdir(d):
            return []
        return sorted(f[len("adapter_"):-len(".shpk")]
                      for f in os.listdir(d)
                      if f.startswith("adapter_") and f.endswith(".shpk"))

    def restore_adapter(self, name: str, step: Optional[int] = None,
                        dequantize: bool = True):
        from repro.hub.packio import load_pack
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints in {self.root}")
        return load_pack(
            os.path.join(self._step_dir(step), f"adapter_{name}.shpk"),
            dequantize=dequantize)

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
        # Uncommitted step dirs older than the oldest kept checkpoint are
        # orphans: a ``save_adapter`` whose committing ``save`` never ran
        # (preemption between the two). They hold per-step adapter_*.shpk
        # artifacts, so keep-K pruning must cover them or the root grows
        # by one stale pack dir per preempted save. Newer uncommitted dirs
        # stay — they may be a save in progress.
        kept = steps[-self.keep:]
        floor = kept[0] if kept else None
        for d in os.listdir(self.root):
            if not d.startswith("step_"):
                continue
            try:
                s = int(d.split("_")[1])
            except ValueError:
                continue
            committed = os.path.exists(os.path.join(self.root, d, "COMMITTED"))
            if not committed and floor is not None and s < floor:
                shutil.rmtree(os.path.join(self.root, d), ignore_errors=True)
