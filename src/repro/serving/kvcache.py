"""int8 KV-cache quantization (per-token-per-head absmax scales).

EXPERIMENTS §Dry-run flags qwen1.5-32b (MHA, 40 heads) x decode_32k as the
one honest misfit: ~5.5 TB of bf16 KV globally. Per-(token, head) absmax
int8 halves the cache (vs bf16) at <0.5% attention-output error, bringing
the padded-head variant to ~11 GB/device. The quantized cache is a drop-in
KVCache replacement for the serving path.

  qk, ks = quantize_kv(k)          # int8 codes + bf16 scales
  k ~= dequantize_kv(qk, ks)
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class QuantKV(NamedTuple):
    codes: jax.Array    # int8, same shape as the bf16 tensor
    scales: jax.Array   # bf16, shape[:-1] + (1,) — per (…, token, head)


def quantize_kv(x: jax.Array) -> QuantKV:
    """x: (..., D) -> int8 codes + per-row absmax scale."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    codes = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return QuantKV(codes, scale.astype(jnp.bfloat16))


def dequantize_kv(q: QuantKV) -> jax.Array:
    return (q.codes.astype(jnp.float32)
            * q.scales.astype(jnp.float32)).astype(jnp.bfloat16)


def quant_cache_zeros(shape: Tuple[int, ...]) -> QuantKV:
    return QuantKV(jnp.zeros(shape, jnp.int8),
                   jnp.zeros(shape[:-1] + (1,), jnp.bfloat16))


def update_quant_cache(cache: QuantKV, new: jax.Array, pos) -> QuantKV:
    """Write ``new`` (B, 1, ...) at sequence position ``pos``."""
    qn = quantize_kv(new)
    start = (0, pos) + (0,) * (cache.codes.ndim - 2)
    return QuantKV(
        jax.lax.dynamic_update_slice(cache.codes, qn.codes, start),
        jax.lax.dynamic_update_slice(cache.scales, qn.scales, start))


def cache_bytes(shape: Tuple[int, ...], quant: bool) -> int:
    import numpy as np
    n = int(np.prod(shape, dtype=np.int64))
    rows = n // shape[-1]
    return n + rows * 2 if quant else n * 2
