"""KV-cache memory: int8 quantization and the paged page-pool layout.

Two layers live here:

**Quantization** (``QuantKV``): per-(token, head) absmax int8 codes + bf16
scales. EXPERIMENTS §Dry-run flags qwen1.5-32b (MHA, 40 heads) x decode_32k
as the one honest misfit: ~5.5 TB of bf16 KV globally; int8 halves it at
<0.5% attention-output error. ``QuantKV`` is both a drop-in contiguous
cache and the element type of quantized *pages* below.

**Paged layout**: serving no longer gives every request a contiguous
``cache_size`` stripe. One global page pool per layer stack —
``(num_pages, page_size, heads, d)`` device arrays (``QuantKV`` for int8
pages) — is shared by all requests; each request owns a *block table*
mapping its logical KV blocks to physical pages:

  token position t  ->  page  block_table[t // page_size]
                        row   t %  page_size

Device-side primitives (pure jax, safe under jit/scan):

  paged_gather(pool, block_tables)       -> contiguous (B, S_max, ...) view
  paged_write(pool, new, block_tables, positions, valid)  -> scatter rows
  copy_page(pool, src, dst)              -> clone one physical page (COW)

Host-side policy (``PagePool``): page refcounts, the free list, and a
refcounted **prefix registry** for copy-on-write prefix sharing. Prompt
prefixes (hashed per page boundary, plus the final partial page, salted
by whatever shaped the forward pass — the engine salts with the adapter
stack) register their pages after prefill; a later request with the same
salt and prefix shares
those pages instead of recomputing them — system prompts dominate at
millions of users, so the shared pages are the resident majority. Shared
pages are immutable: any writer holding a page with refcount > 1 must
``copy_page`` it into a fresh page first (the engine resolves this before
every write range). Registry entries are evicted LRU when the free list
runs dry, so hot prefixes stay resident and cold ones yield their pages.

Page 0 is a pinned scratch page: padded/invalid writes land there and
null block-table entries point at it, so gathers and scatters never need
a branch.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class QuantKV(NamedTuple):
    codes: jax.Array    # int8, same shape as the bf16 tensor
    scales: jax.Array   # bf16, shape[:-1] + (1,) — per (…, token, head)


def quantize_kv(x: jax.Array) -> QuantKV:
    """x: (..., D) -> int8 codes + per-row absmax scale."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    codes = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return QuantKV(codes, scale.astype(jnp.bfloat16))


def dequantize_kv(q: QuantKV) -> jax.Array:
    return (q.codes.astype(jnp.float32)
            * q.scales.astype(jnp.float32)).astype(jnp.bfloat16)


def quant_cache_zeros(shape: Tuple[int, ...]) -> QuantKV:
    return QuantKV(jnp.zeros(shape, jnp.int8),
                   jnp.zeros(shape[:-1] + (1,), jnp.bfloat16))


def update_quant_cache(cache: QuantKV, new: jax.Array, pos,
                       seq_axis: int = 1) -> QuantKV:
    """Write ``new`` (one new token's rows) at sequence position ``pos``.

    ``seq_axis`` is the cache's sequence axis. The serving caches carry
    scan-stack dims in front of the batch axis (a dense-stage KV leaf is
    ``(L, B, S, KV, D)`` — sequence at axis 2), so the axis must come from
    the caller; the historical default of 1 matches a plain unstacked
    ``(B, S, ...)`` cache only.
    """
    qn = quantize_kv(new)
    if not -cache.codes.ndim <= seq_axis < cache.codes.ndim:
        raise ValueError(f"seq_axis {seq_axis} out of range for cache rank "
                         f"{cache.codes.ndim}")
    seq_axis %= cache.codes.ndim
    start = tuple(pos if ax == seq_axis else 0
                  for ax in range(cache.codes.ndim))
    return QuantKV(
        jax.lax.dynamic_update_slice(cache.codes, qn.codes, start),
        jax.lax.dynamic_update_slice(cache.scales, qn.scales, start))


def cache_bytes(shape: Tuple[int, ...], quant: bool) -> int:
    n = int(np.prod(shape, dtype=np.int64))
    rows = n // shape[-1]
    return n + rows * 2 if quant else n * 2


# ---------------------------------------------------------------------------
# Paged device primitives. A "pool" is either a jax.Array (P, page, *tail)
# or a QuantKV whose codes have that shape; block tables are (B, nblk) int32
# physical page ids (0 = the scratch page).
# ---------------------------------------------------------------------------

def pool_zeros(num_pages: int, page_size: int, tail: Tuple[int, ...],
               dtype, quant: bool = False):
    shape = (num_pages, page_size) + tuple(tail)
    if quant:
        return quant_cache_zeros(shape)
    return jnp.zeros(shape, dtype)


def paged_gather(pool, block_tables: jax.Array) -> jax.Array:
    """Materialise the contiguous view of each request's pages.

    pool: (P, page, *tail) [or QuantKV of that shape];
    block_tables: (B, nblk) int32. Returns (B, nblk * page, *tail) in the
    pool dtype (quantized pools dequantize to bf16).
    """
    B, nblk = block_tables.shape
    flat = block_tables.reshape(-1)
    if isinstance(pool, QuantKV):
        codes = jnp.take(pool.codes, flat, axis=0)
        scales = jnp.take(pool.scales, flat, axis=0)
        x = dequantize_kv(QuantKV(codes, scales))
    else:
        x = jnp.take(pool, flat, axis=0)
    page = x.shape[1]
    return x.reshape((B, nblk * page) + x.shape[2:])


def _write_coords(block_tables: jax.Array, positions: jax.Array,
                  valid: jax.Array, page_size: int):
    """(page_id, row) scatter coordinates; invalid rows target scratch 0."""
    B, nblk = block_tables.shape
    blk = jnp.clip(positions // page_size, 0, nblk - 1)
    pages = jnp.take_along_axis(block_tables, blk, axis=1)
    pages = jnp.where(valid, pages, 0)
    rows = jnp.where(valid, positions % page_size, 0)
    return pages, rows


def paged_write(pool, new: jax.Array, block_tables: jax.Array,
                positions: jax.Array, valid: jax.Array):
    """Scatter token rows into their pages.

    new: (B, C, *tail); positions: (B, C) absolute token indices;
    valid: (B, C) bool — False rows land in the scratch page (padding /
    idle lanes). Returns the updated pool.
    """
    B, C = positions.shape
    page_size = (pool.codes if isinstance(pool, QuantKV) else pool).shape[1]
    pages, rows = _write_coords(block_tables, positions, valid, page_size)
    pg, rw = pages.reshape(-1), rows.reshape(-1)
    if isinstance(pool, QuantKV):
        qn = quantize_kv(new)
        return QuantKV(
            pool.codes.at[pg, rw].set(
                qn.codes.reshape((B * C,) + qn.codes.shape[2:])),
            pool.scales.at[pg, rw].set(
                qn.scales.reshape((B * C,) + qn.scales.shape[2:])))
    return pool.at[pg, rw].set(
        new.astype(pool.dtype).reshape((B * C,) + new.shape[2:]))


def copy_page(pool, src, dst, page_axis: int = 0):
    """Clone physical page ``src`` into ``dst`` (the device half of COW).

    Works on a single pool or any pytree of pools. ``page_axis`` is the
    physical-page axis of every leaf (the serving caches carry a leading
    layer-stack dim, so theirs is 1).
    """
    def leaf(x):
        moved = jnp.moveaxis(x, page_axis, 0)
        row = moved[src]                     # gather: src may be traced
        return jnp.moveaxis(moved.at[dst].set(row), 0, page_axis)
    return jax.tree.map(leaf, pool)


def pages_for(tokens: int, page_size: int) -> int:
    """Pages needed to hold ``tokens`` KV rows."""
    return max(0, -(-tokens // page_size))


# ---------------------------------------------------------------------------
# Host-side page accounting: refcounts, free list, prefix registry.
# ---------------------------------------------------------------------------

def _digest(tokens: np.ndarray, salt: bytes = b"") -> bytes:
    return hashlib.sha1(salt + np.ascontiguousarray(
        np.asarray(tokens, np.int32)).tobytes()).digest()


class PagePool:
    """Refcounted physical-page allocator with a COW prefix registry.

    Pure host-side metadata — the device arrays live in the engine's cache
    pytree; this class only decides *which* physical page each logical
    block maps to. Page 0 is reserved scratch and never allocated.

    Refcount protocol: every holder of a page (a request's block table, or
    the prefix registry) owns one reference. A page with ``refs > 1`` is
    shared and therefore immutable — writers must COW it first
    (``is_shared`` + ``copy_page`` on the device pools). Pages return to
    the free list when their last reference drops.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is scratch)")
        self.num_pages = num_pages
        self.page_size = page_size
        self.refs = np.zeros(num_pages, np.int32)
        self.refs[0] = 1                       # scratch, pinned forever
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        # prefix digest -> (page_id, fill). Insertion order is the LRU.
        self._prefix: "OrderedDict[bytes, Tuple[int, int]]" = OrderedDict()
        self.prefix_hits = 0
        self.prefix_shared_tokens = 0
        self.cow_copies = 0
        self.evictions = 0

    # -- allocation ----------------------------------------------------

    def free_pages(self) -> int:
        return len(self._free)

    def used_pages(self) -> int:
        return self.num_pages - 1 - len(self._free)

    def _evictable(self) -> List[bytes]:
        return [k for k, (pg, _) in self._prefix.items()
                if self.refs[pg] == 1]

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free) + len(self._evictable())

    def alloc(self, n: int) -> List[int]:
        """Take ``n`` fresh pages (refcount 1 each), evicting cold prefix
        registry entries LRU-first if the free list runs dry."""
        while len(self._free) < n:
            for key in self._evictable():       # LRU = insertion order
                pg, _ = self._prefix.pop(key)
                self._decref(pg)
                self.evictions += 1
                break
            else:
                raise MemoryError(
                    f"page pool exhausted: want {n}, "
                    f"{len(self._free)} free, 0 evictable")
        out = [self._free.pop() for _ in range(n)]
        for pg in out:
            self.refs[pg] = 1
        return out

    def _decref(self, page: int) -> None:
        assert self.refs[page] > 0, page
        self.refs[page] -= 1
        if self.refs[page] == 0:
            self._free.append(page)

    def release(self, pages: Sequence[int]) -> None:
        """Drop one reference per page (request finished / COW replaced)."""
        for pg in pages:
            self._decref(int(pg))

    def share(self, page: int) -> int:
        self.refs[page] += 1
        return page

    def is_shared(self, page: int) -> bool:
        return bool(self.refs[page] > 1)

    # -- prefix registry ----------------------------------------------

    def match_prefix(self, tokens: np.ndarray,
                     salt: bytes = b"") -> Tuple[int, List[int]]:
        """Longest registered prefix of ``tokens``: (shared_len, pages).

        The caller receives one reference per returned page. Full pages
        chain from position 0; the final partial page matches only an
        entry covering exactly the same tokens (same digest, same fill).
        ``salt`` namespaces the lookup — prefix KV depends on everything
        that shaped the forward pass (the adapter stack above all), so
        callers must salt with it or requests would share pages computed
        under a different model. The match is capped at
        ``len(tokens) - 1`` so at least one prompt token always runs
        through the model (its logits seed decoding); when the cap lands
        inside a shared page, that page stays shared — recomputing its
        last token is the first divergent write, which COWs it.
        """
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        p, L = self.page_size, len(tokens)
        shared: List[int] = []
        matched = 0
        for i in range(L // p):
            ent = self._lookup(_digest(tokens[:(i + 1) * p], salt), p)
            if ent is None:
                break
            shared.append(ent)
            matched = (i + 1) * p
        else:
            r = L - (L // p) * p
            if r:
                ent = self._lookup(_digest(tokens, salt), r)
                if ent is not None:
                    shared.append(ent)
                    matched = L
        shared_len = min(matched, L - 1)
        while shared and (len(shared) - 1) * p >= shared_len:
            shared.pop()                         # page past the cap: useless
        shared_len = min(shared_len, len(shared) * p)
        for pg in shared:
            self.share(pg)
        if shared:
            self.prefix_hits += 1
            self.prefix_shared_tokens += shared_len
        return shared_len, shared

    def _lookup(self, key: bytes, fill: int) -> Optional[int]:
        ent = self._prefix.get(key)
        if ent is None or ent[1] != fill:
            return None
        self._prefix.move_to_end(key)            # LRU touch
        return ent[0]

    def register_prefix(self, tokens: np.ndarray, pages: Sequence[int],
                        salt: bytes = b"") -> None:
        """Register a prefilled prompt's pages for future sharing.

        ``pages[i]`` must hold tokens ``[i*p, min((i+1)*p, len))`` — i.e.
        the request's block-table prefix right after prefill, before any
        decode write (the partial tail must be pristine). ``salt`` must
        match the one future ``match_prefix`` callers will use (the
        engine salts with the adapter stack). The registry takes one
        reference per newly registered page.
        """
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        p, L = self.page_size, len(tokens)
        for i, pg in enumerate(pages):
            end = min((i + 1) * p, L)
            if end <= i * p:
                break
            key = _digest(tokens[:end], salt)
            if key in self._prefix:
                continue
            self._prefix[key] = (int(pg), end - i * p)
            self.share(int(pg))

    def registered_prefixes(self) -> int:
        return len(self._prefix)
