from repro.serving.kvcache import (QuantKV, cache_bytes, dequantize_kv,  # noqa: F401
                                   quant_cache_zeros, quantize_kv,
                                   update_quant_cache)
from repro.serving.multitenant import MultiTenantEngine  # noqa: F401
