from repro.serving.kvcache import (PagePool, QuantKV, cache_bytes,  # noqa: F401
                                   copy_page, dequantize_kv, paged_gather,
                                   paged_write, pages_for, pool_zeros,
                                   quant_cache_zeros, quantize_kv,
                                   update_quant_cache)
from repro.serving.loadgen import GenRequest, LoadGen, LoadReport, Phase  # noqa: F401
from repro.serving.multitenant import MultiTenantEngine  # noqa: F401
