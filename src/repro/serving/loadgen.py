"""Heavy-traffic load generator for the serving engines.

Real multi-adapter traffic is nothing like the benches' fixed batches:
adapter popularity is Zipf (a few hot personas, a long tail — the
SiRA-style sparse-routing regime), arrivals are Poisson at best and
bursty in practice, and overload happens. This module synthesizes such
traffic and drives a request-level engine (``ServingEngine`` /
``PagedServingEngine`` — anything with ``submit``/``step``/``pending``)
through it in wall-clock time, producing the tail-latency numbers the
SLO bench (``benchmarks/slo_load.py``) gates:

  * **Arrivals**: per-``Phase`` Poisson processes (exponential gaps at
    ``rate_rps``). ``burst > 1`` clumps arrivals — a fraction
    ``1 - 1/burst`` of gaps collapse to zero and the survivors stretch
    by ``burst``, preserving the mean rate while producing the bursty
    queue spikes that separate p99 from p50. Chain phases to model
    overload: ``[Phase(5, 2), Phase(5, 20), Phase(5, 2)]`` is a 10x
    overload spike between calm seas.
  * **Adapter popularity**: Zipf(``zipf_s``) over the adapter list, so
    one adapter dominates and the tail is cold — exactly the traffic a
    ``FusedLRU`` promotes for and an ``AdapterStore`` LRU thrashes on.
  * **Prompts**: random tokens, optionally opening with a shared system
    prefix (exercises COW prefix sharing in the paged engine).

``run()`` is the driver: requests are submitted when their arrival time
comes due and the engine is stepped continuously in between, so queue
wait is real and TTFT/latency are measured submit-to-token wall clock.
When the engine is fully idle and the next arrival is in the future the
driver *jumps* virtual time forward instead of sleeping — CI never
burns minutes simulating quiet seconds (latencies are unaffected: an
empty engine serves an arrival identically either way).

Goodput: a request "meets SLO" when its end-to-end latency is within
``slo_ms``; goodput is tokens of SLO-met requests per second of wall
clock — under overload it diverges from raw throughput, which is the
point of measuring it.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["Phase", "GenRequest", "LoadGen", "LoadReport", "run",
           "zipf_probs"]


@dataclass(frozen=True)
class Phase:
    """One traffic regime: ``duration_s`` of arrivals at ``rate_rps``."""
    duration_s: float
    rate_rps: float
    burst: float = 1.0        # > 1: clumped arrivals, same mean rate


@dataclass
class GenRequest:
    rid: int
    t: float                  # arrival time, seconds from trace start
    adapter: Any              # tenant (name, stack tuple, or None)
    prompt: np.ndarray        # int32 token ids
    max_tokens: int
    phase: int                # index of the generating phase


def zipf_probs(n: int, s: float = 1.1) -> np.ndarray:
    """P(adapter rank i) ~ 1/(i+1)^s, normalized."""
    w = 1.0 / np.arange(1, n + 1, dtype=np.float64) ** s
    return w / w.sum()


@dataclass
class LoadGen:
    """Deterministic (seeded) trace synthesizer."""

    adapters: Sequence[Any]
    vocab: int
    seed: int = 0
    zipf_s: float = 1.1
    phases: Sequence[Phase] = (Phase(1.0, 8.0),)
    prompt_len: Tuple[int, int] = (4, 12)     # inclusive range
    max_tokens: Tuple[int, int] = (2, 8)      # inclusive range
    shared_prefix: int = 0                    # shared system-prompt tokens
    base_frac: float = 0.0                    # fraction of base-model traffic

    def schedule(self) -> List[GenRequest]:
        rng = np.random.default_rng(self.seed)
        probs = zipf_probs(len(self.adapters), self.zipf_s)
        prefix = rng.integers(0, self.vocab, self.shared_prefix,
                              dtype=np.int32)
        reqs: List[GenRequest] = []
        t = 0.0
        for pi, ph in enumerate(self.phases):
            end = t + ph.duration_s
            while True:
                if ph.burst > 1.0 and rng.random() < 1.0 - 1.0 / ph.burst:
                    gap = 0.0                      # clump into the burst
                else:
                    gap = rng.exponential(max(ph.burst, 1.0) / ph.rate_rps)
                if t + gap >= end:
                    break
                t += gap
                if self.base_frac > 0 and rng.random() < self.base_frac:
                    adapter = None
                else:
                    adapter = self.adapters[
                        rng.choice(len(self.adapters), p=probs)]
                plen = int(rng.integers(self.prompt_len[0],
                                        self.prompt_len[1] + 1))
                body = rng.integers(0, self.vocab, plen, dtype=np.int32)
                prompt = np.concatenate([prefix, body]) if self.shared_prefix \
                    else body
                reqs.append(GenRequest(
                    rid=len(reqs), t=t, adapter=adapter, prompt=prompt,
                    max_tokens=int(rng.integers(self.max_tokens[0],
                                                self.max_tokens[1] + 1)),
                    phase=pi))
            t = end
        return reqs


@dataclass
class LoadReport:
    """Raw per-request samples + aggregates; percentile math lives with
    the bench schema (``benchmarks/_emit.py::percentiles``)."""

    wall_s: float
    offered: int
    completed: int
    tokens_out: int
    steps: int
    slo_ms: Optional[float]
    latencies_ms: List[float] = field(default_factory=list)
    ttfts_ms: List[float] = field(default_factory=list)
    # TTFT split by the engine's cold stamp (``fut.cold``: the adapter was
    # neither engine-registered nor store-resident at submit) — the async
    # prefetch pipeline is judged on the cold tail specifically
    ttfts_cold_ms: List[float] = field(default_factory=list)
    ttfts_warm_ms: List[float] = field(default_factory=list)
    slo_met: int = 0
    goodput_tokens: int = 0
    per_phase_latencies_ms: Dict[int, List[float]] = field(
        default_factory=dict)
    # fault-tolerance accounting (runtime/faults.py): requests that ended
    # with a typed error never contribute latency/goodput samples —
    # ``failed`` counts all of them, ``shed`` the RequestShed subset, and
    # ``errors_by_type`` names each terminal error class. ``degraded``
    # requests completed (they count toward latency/goodput) but were
    # served below what they asked for.
    failed: int = 0
    shed: int = 0
    degraded: int = 0
    errors_by_type: Dict[str, int] = field(default_factory=dict)

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_out / max(self.wall_s, 1e-9)

    @property
    def goodput_tok_s(self) -> float:
        return self.goodput_tokens / max(self.wall_s, 1e-9)

    @property
    def slo_violation_rate(self) -> float:
        done = max(self.completed, 1)
        return (self.completed - self.slo_met) / done

    @property
    def shed_rate(self) -> float:
        return self.shed / max(self.offered, 1)

    @property
    def degraded_rate(self) -> float:
        return self.degraded / max(self.offered, 1)


def run(engine, requests: Sequence[GenRequest], *,
        slo_ms: Optional[float] = None,
        deadline_s: Optional[float] = None,
        max_steps: int = 1_000_000) -> LoadReport:
    """Drive ``engine`` through the trace in wall-clock time.

    The engine contract is the request API shared by the lane and paged
    engines: ``submit(prompt, adapter, max_tokens) -> future`` (with
    ``submit_time``/``ttft``/``finish_time`` stamps), ``step()``,
    ``pending()``. ``deadline_s`` forwards a per-request queue deadline
    to engines that support shedding. Returns the filled
    ``LoadReport``."""
    reqs = sorted(requests, key=lambda r: r.t)
    futs: List[Tuple[GenRequest, Any]] = []
    kw = {} if deadline_s is None else {"deadline_s": deadline_s}
    t0 = time.perf_counter()
    i, steps = 0, 0
    while i < len(reqs) or engine.pending():
        now = time.perf_counter() - t0
        if (i < len(reqs) and not engine.pending()
                and reqs[i].t > now):
            # idle gap: jump virtual time to the next arrival
            t0 -= reqs[i].t - now
            now = reqs[i].t
        while i < len(reqs) and reqs[i].t <= now:
            r = reqs[i]
            futs.append((r, engine.submit(r.prompt, r.adapter,
                                          max_tokens=r.max_tokens, **kw)))
            i += 1
        if engine.pending():
            engine.step()
            steps += 1
            if steps >= max_steps:
                raise RuntimeError(f"load run exceeded {max_steps} steps "
                                   f"with {engine.pending()} in flight")
    wall = time.perf_counter() - t0

    rep = LoadReport(wall_s=wall, offered=len(reqs), completed=0,
                     tokens_out=0, steps=steps, slo_ms=slo_ms)
    for r, f in futs:
        if not f.done():
            continue
        err = getattr(f, "error", None)
        if err is not None or getattr(f, "cancelled", False):
            # typed terminal failure: no latency/goodput sample
            rep.failed += 1
            kind = type(err).__name__ if err is not None else "Cancelled"
            rep.errors_by_type[kind] = rep.errors_by_type.get(kind, 0) + 1
            if kind in ("RequestShed", "Cancelled"):
                rep.shed += 1
            continue
        rep.completed += 1
        rep.tokens_out += len(f.tokens)
        if getattr(f, "degraded", False):
            rep.degraded += 1
        lat_ms = (f.finish_time - f.submit_time) * 1e3 \
            if f.finish_time is not None else float("nan")
        rep.latencies_ms.append(lat_ms)
        rep.per_phase_latencies_ms.setdefault(r.phase, []).append(lat_ms)
        if f.ttft is not None:
            rep.ttfts_ms.append(f.ttft * 1e3)
            (rep.ttfts_cold_ms if getattr(f, "cold", False)
             else rep.ttfts_warm_ms).append(f.ttft * 1e3)
        if slo_ms is None or lat_ms <= slo_ms:
            rep.slo_met += 1
            rep.goodput_tokens += len(f.tokens)
    return rep
