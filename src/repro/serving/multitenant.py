"""Multi-tenant SHiRA serving: per-request adapters in ONE batch.

The sequential serving path (``launch/serve.py`` + ``SwitchEngine``) swaps
adapters *between* batches: two users wanting different adapters can never
share a decode step. Sparse adapters make the per-request fix cheap — each
request's delta is 1-2% of the weights — so this engine keeps ONE shared
copy of the base weights and applies every request's SHiRA pack as a
batched sparse side term in the forward pass:

  y[b] = x[b] @ W_shared  +  x[b] @ dW_{adapter(b)}

The side term is computed by the Pallas ``sidedelta`` kernel
(repro/kernels/sidedelta.py) from packed per-adapter (row, col, val)
tables; the weight leaves of the served parameter tree are replaced by
``layers.sidedelta_weight`` bundles, which ``layers.pdot`` understands and
which survive the LM's ``lax.scan`` over stacked layer weights (every table
carries the weight's leading layer dims).

Fused-state scheduling: with a ``core.switching.FusedLRU`` scheduler, the
engine additionally fuses the *hot* tenant into the shared base (a single
sparse scatter — the paper's rapid switch), so dominant-tenant requests skip
the side term entirely. The other tenants are then served with diff packs
(their delta minus the fused one, built by ``fusion.fuse_packs``), and base
-model requests with the negated fused pack. Demotion scatters the delta
back out and restores plain packs.

Device tables may be quantized (``table_dtype="int8"``): values stay int8
with a per-adapter scale (dequantized inside the kernel's VMEM) and the
index halves drop to int16 where the weight dims fit, so resident adapter
HBM shrinks ~4x (values) / ~2.4x (total). Packs registered from an int8
``AdapterStore`` reach the tables in their original quantization — no f32
round trip, no second rounding.

Tenants need not be single adapters: a request may name an adapter *stack*
(tuple of names) whose deltas are merged into one side pack, and a
``FusedLRU(capacity>1)`` promotes a hot stack into the base as a group —
diff packs are then group-aware (each tenant's delta minus the fused sum).
Request-level serving with continuous batching lives one layer up, in
``repro.hub.ServingEngine``, which drives this engine's prefill/decode with
per-slot adapter ids and cache positions.

Limitations: adapters on ``w_uk``/``w_uv`` (MLA absorbed-decode weights,
consumed via reshape rather than a matmul) are rejected — exclude them from
``AdapterConfig.target_modules`` when serving MLA archs multi-tenant.
"""
from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import trace
from repro.core.adapters import AdapterPack, apply_pack
from repro.core.fusion import fuse_packs
from repro.core.switching import (FusedLRU, SwitchEngine, Tenant,
                                  normalize_tenant, tenant_key,
                                  tenant_members)
from repro.kernels.ops import sidedelta_table
from repro.models import lm
from repro.models.layers import sidedelta_weight
from repro.runtime import faults

BASE = None            # the "no adapter" tenant in a names list
_BASE_SLOT = "__base__"

# MLA absorbed-decode weights are reshaped, not matmul'd — pdot never sees
# them, so a side-delta bundle there would crash (or silently diverge).
UNSUPPORTED_LEAVES = ("w_uk", "w_uv")


def _leaf_shapes(params) -> Dict[str, Tuple[int, ...]]:
    out = {}
    from repro.core import masks as M
    for p, x in jax.tree_util.tree_flatten_with_path(params)[0]:
        out[M.path_str(p)] = tuple(x.shape)
    return out


def greedy_decode(cfg, batch, tokens: int, prefill, decode):
    """The serving decode loop, shared by the engine, the sequential
    references, and the benchmark so position bookkeeping (incl. the vision
    prefix) cannot drift between them.

    prefill(batch) -> (logits, caches); decode(tok, caches, pos) ->
    (logits, caches). Returns (greedy tokens (B, tokens) int32, last-step
    logits (B, V)).
    """
    prompt_len = batch["tokens"].shape[1]
    pos0 = prompt_len + (cfg.num_prefix_embeds
                         if cfg.modality == "vision" else 0)
    logits, caches = prefill(batch)
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    outs = [nxt]
    for i in range(tokens - 1):
        logits, caches = decode(nxt, caches, pos0 + i)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        outs.append(nxt)
    jax.block_until_ready(logits)
    return jnp.concatenate(outs, axis=1), logits


def serving_cache_size(cfg, prompt_len: int, tokens: int) -> int:
    """KV-cache slots for a serve call: prompt + generated + slack, PLUS the
    vision prefix (prefix embeddings occupy cache positions too)."""
    prefix = cfg.num_prefix_embeds if cfg.modality == "vision" else 0
    return prompt_len + prefix + tokens + 8


def switch_per_request_reference(cfg, params, packs, toks, names,
                                 tokens: int):
    """Ground-truth baseline: serve each request ALONE after rapid-switching
    (SwitchEngine) to its adapter. The multi-tenant engine's batched outputs
    are validated against this in tests and examples (the benchmark uses the
    stronger switch-per-GROUP baseline instead).

    toks: (B, S) int; names: per-request adapter name or None. Returns
    (greedy tokens (B, tokens) int32, last-step logits (B, V) f32, seconds).
    """
    toks = np.asarray(toks)
    B, S = toks.shape
    cs = serving_cache_size(cfg, S, tokens)
    by_name = {p.name: p for p in packs}
    engine = SwitchEngine(params)
    prefill = jax.jit(lambda p, b: lm.prefill(p, cfg, b, cs))
    decode = jax.jit(lambda p, t, c, pos: lm.decode_step(p, cfg, t, c, pos))
    out = np.zeros((B, tokens), np.int32)
    logits_last = np.zeros((B, cfg.padded_vocab), np.float32)
    t0 = time.perf_counter()
    for b, name in enumerate(names):
        while engine.active:
            engine.unload()
        if name is not None:
            engine.load(by_name[name])
        seq, logits = greedy_decode(
            cfg, {"tokens": jnp.asarray(toks[b:b + 1])}, tokens,
            lambda bb: prefill(engine.params, bb),
            lambda t, c, pos: decode(engine.params, t, c, pos))
        out[b] = np.asarray(seq)[0]
        logits_last[b] = np.asarray(logits, np.float32)[0]
    dt = time.perf_counter() - t0
    while engine.active:
        engine.unload()
    return out, logits_last, dt


class MultiTenantEngine:
    """Serves mixed-adapter batches off one shared base parameter tree.

    A request's tenant may be ``None`` (base model), one adapter name, or an
    adapter *stack* — a tuple of names whose deltas are applied together
    (the side pack is their merged sum). With a ``FusedLRU(capacity>1)``
    scheduler a hot stack is fused into the shared base as a group, and
    every other tenant is served with a group-aware diff pack (its delta
    minus the fused sum). With an ``AdapterStore``, ``register`` also
    accepts a registered adapter id instead of a pack object."""

    def __init__(self, cfg, params, *, scheduler: Optional[FusedLRU] = None,
                 store=None, table_dtype: str = "f32",
                 interpret: Optional[bool] = None, slot_pad: int = 1):
        if table_dtype not in ("f32", "int8"):
            raise ValueError(f"table_dtype must be 'f32' or 'int8', got "
                             f"{table_dtype!r}")
        if slot_pad < 1:
            raise ValueError(f"slot_pad must be >= 1, got {slot_pad}")
        self.cfg = cfg
        self.shared = params                 # base (+ the fused packs, if any)
        self.packs: Dict[str, AdapterPack] = {}
        self.scheduler = scheduler
        self.store = store
        self.table_dtype = table_dtype       # device-table value dtype
        self.interpret = interpret           # sidedelta mode (None = auto)
        # slot-capacity bucket: the tables' adapter axis is rounded up to a
        # multiple of this, so registering an adapter within the padded
        # capacity keeps every table shape constant — no prefill/decode
        # recompile on cold admission (padded slots hold zero-valued rows
        # that contribute nothing). 1 = exact sizing (the old behavior).
        self.slot_pad = slot_pad
        self.fused: Optional[Tenant] = None
        self.fuse_transitions = 0            # promote/demote scatter count
        self._shapes = _leaf_shapes(params)
        self._tables: Dict[str, dict] = {}   # path -> rows/cols/vals[/scale]
        self._qpacks: Dict[str, Any] = {}    # name -> QuantPack (int8 tables)
        self._qtables: Dict[str, dict] = {}  # name -> decoded int8_tables()
        self._slots: Dict[Any, int] = {}     # tenant -> table slot
        self._stacks: Dict[Any, int] = {}    # multi-adapter tenant -> last use
        self._batch_no = 0                   # ids_for calls (stack recency)
        self.stack_ttl = 64                  # drop stacks idle this many calls
        self._dirty = False
        self._structural = False             # old table rows invalid too
        self._epoch = 0                      # bumps on table-invalidating change
        self._build_pool: Optional[ThreadPoolExecutor] = None
        self._build_fut = None               # (epoch, Future, decision|None)
        self._pending = None                 # deferred FusedDecision
        self.async_builds = 0                # background builds submitted
        self.async_adopted = 0               # adopted (saved a sync rebuild)
        self.async_stale = 0                 # discarded (state moved on)

        # the sidedelta mode is read at trace time (layers.sidedelta_backend)
        # — scope the traces so an engine-level override actually lands
        from repro.models import layers as L

        def _prefill(p, b, cs):
            with L.sidedelta_backend(interpret):
                return lm.prefill(p, self.cfg, b, cs)

        def _decode(p, t, c, pos):
            with L.sidedelta_backend(interpret):
                return lm.decode_step(p, self.cfg, t, c, pos)

        self._prefill = jax.jit(_prefill, static_argnums=2)
        self._decode = jax.jit(_decode)

    # ------------------------------------------------------------------
    # Registration / side-delta tables
    # ------------------------------------------------------------------

    def register(self, pack) -> None:
        from repro.hub.packio import QuantPack  # deferred: hub imports us
        qp = None
        if isinstance(pack, str):
            if self.store is None:
                raise ValueError(f"adapter named by id {pack!r} but no "
                                 "AdapterStore attached")
            if self.table_dtype == "int8" and hasattr(self.store, "get_raw"):
                # int8 tables can be built straight from the store's
                # quantized resident form — no f32 round trip, no second
                # quantization error
                pack = self.store.get_raw(pack)
            else:
                pack = self.store.get(pack)
        if isinstance(pack, QuantPack):
            qp, pack = pack, pack.dequantize()
        for path in pack.entries:
            leaf = path.rsplit("/", 1)[-1]
            if leaf in UNSUPPORTED_LEAVES:
                raise ValueError(
                    f"adapter {pack.name!r} targets {path!r}: {leaf} is "
                    "consumed outside pdot (MLA absorbed decode); exclude it "
                    "from target_modules for multi-tenant serving")
            if path not in self._shapes:
                raise KeyError(f"adapter {pack.name!r} targets unknown "
                               f"weight {path!r}")
        brand_new = pack.name not in self.packs
        if pack.name in tenant_members(self.fused):
            # un-fuse the OLD delta before replacing the pack, or the next
            # demote would subtract the new one from a base holding the old
            self._demote()
            if self.scheduler is not None and pack.name in tenant_members(
                    self.scheduler.fused):
                self.scheduler.fused = None  # keep it re-promotable
        self.packs[pack.name] = pack
        self._qpacks.pop(pack.name, None)
        self._qtables.pop(pack.name, None)
        if qp is not None:
            self._qpacks[pack.name] = qp
        self._mark_dirty(additive=brand_new)

    def unregister(self, name: str) -> bool:
        """Drop a registered adapter (hot-swap retirement: a superseded
        ``name@v`` whose in-flight requests have drained). If the adapter
        is fused — alone or inside the fused stack — it is demoted first
        so the shared base returns to clean weights. Removal dirt is
        *additive*: like stack-TTL retirement, the remaining tenants'
        table rows stay valid until the rebuild, so serving may keep
        decoding them off the old tables. Returns False if unknown."""
        if name not in self.packs:
            return False
        if name in tenant_members(self.fused):
            self._demote()
            if (self.scheduler is not None
                    and name in tenant_members(self.scheduler.fused)):
                self.scheduler.fused = None
        del self.packs[name]
        self._qpacks.pop(name, None)
        self._qtables.pop(name, None)
        for t in [t for t in self._stacks if name in tenant_members(t)]:
            del self._stacks[t]
        if self.scheduler is not None:
            for t in [t for t in self.scheduler.share
                      if name in tenant_members(t)]:
                self.scheduler.share.pop(t, None)
                self.scheduler.last_used.pop(t, None)
        self._mark_dirty(additive=True)
        return True

    def resolve(self, name):
        """Map a tenant's members through the attached store's versioned-id
        resolution (bare ``name`` -> newest ``name@v``). Identity without a
        store. Request-level engines (``repro.hub``) call this at submit so
        a request is pinned to the version that was newest when it arrived,
        even if a newer one is published mid-stream."""
        if self.store is None or not hasattr(self.store, "resolve"):
            return name
        members = tenant_members(name)
        if not members:
            return name
        resolved = tuple(self.store.resolve(m) for m in members)
        if resolved == members:
            return name
        return resolved[0] if isinstance(name, str) else resolved

    def _tenants(self) -> set:
        """Side-served tenants: every registered adapter singly, plus every
        multi-adapter stack a request has named."""
        return set(self.packs) | set(self._stacks)

    def _mark_dirty(self, additive: bool = False) -> None:
        """Tables no longer match the tenant/fused state. The epoch bump
        invalidates any background build snapshotted before this point.

        ``additive`` dirt only *adds* tenants (a new pack registered, a new
        stack named): every existing table row is still correct, so serving
        may keep using the stale tables for already-covered tenants
        (``ids_covered``) while the rebuild runs in the background.
        Structural dirt (re-register, promote/demote, anything touching the
        fused state) invalidates existing rows as well."""
        self._dirty = True
        self._epoch += 1
        if not additive:
            self._structural = True

    def _side_packs(self, packs, stacks, fused) -> Dict[Any, AdapterPack]:
        """What each tenant's side delta must be, given the fused state.
        Operates on explicit (possibly snapshotted) state so background
        builds never read dicts the serving thread is mutating."""
        fused_m = tenant_members(fused)
        out = {}
        for t in set(packs) | set(stacks):
            if t == fused:
                continue                     # fused tenant rides the base
            members = tenant_members(t)
            if not fused_m and len(members) == 1:
                out[t] = packs[members[0]]
            else:
                parts = ([packs[m] for m in members]
                         + [packs[f] for f in fused_m])
                weights = [1.0] * len(members) + [-1.0] * len(fused_m)
                out[t] = fuse_packs(
                    parts, weights=weights,
                    name=(tenant_key(t) +
                          (f"-minus-{tenant_key(fused)}" if fused_m
                           else "")))
        if fused_m:                          # base traffic must un-see it
            out[_BASE_SLOT] = fuse_packs(
                [packs[f] for f in fused_m],
                weights=[-1.0] * len(fused_m),
                name=f"-{tenant_key(fused)}")
        return out

    def _quant_direct(self, name, pk, path, packs, qpacks):
        """The store's quantized values for this side pack, when they can be
        used verbatim: a plain single-adapter tenant (no diff/merge math)
        registered from a QuantPack. Returns (idx (nl, k) int64,
        vq (nl, k) int8, scale float) or None."""
        if self.table_dtype != "int8" or not isinstance(name, str):
            return None
        if pk is not packs.get(name) or name not in qpacks:
            return None                      # diff/merged pack: f32 math
        qp = qpacks[name]
        if path not in qp.entries:
            return None
        if name not in self._qtables:    # decode the gap streams once
            self._qtables[name] = qp.int8_tables()
        idx, vq, scale = self._qtables[name][path]
        return idx, vq, scale * qp.alpha

    def _rebuild(self) -> None:
        """Synchronous (serving-thread) table rebuild — the fallback when no
        background build matches the current state."""
        with trace.span("table_rebuild", cat="tables") as sp:
            side = self._side_packs(self.packs, self._stacks, self.fused)
            slots, tables, meta = self._build_tables(side, self.packs,
                                                     self._qpacks)
            sp.set(**meta)
        self._slots, self._tables = slots, tables
        self._dirty = False
        self._structural = False

    def _build_tables(self, side, packs, qpacks):
        """Pack side deltas into device tables. Pure w.r.t. engine state
        (reads only the passed snapshots + immutable ``_shapes``), so the
        sync rebuild and the async build produce identical tables from
        identical inputs. Returns (slots, tables, meta)."""
        from repro.kernels.ops import quantize_table
        # injected device-OOM point: covers the sync rebuild AND the async
        # build worker (poll_async_build contains worker failures; the hub
        # engines back off and retry on TableBuildError from sync builds)
        faults.on_table_build()
        order = sorted(side, key=lambda t: t if isinstance(t, str)
                       else tenant_key(t))
        slots = {name: i for i, name in enumerate(order)}
        paths = sorted({p for pk in side.values() for p in pk.entries})
        tables: Dict[str, dict] = {}
        pad = self.slot_pad
        A = max(-(-max(len(side), 1) // pad) * pad, 1)
        int8 = self.table_dtype == "int8"
        for path in paths:
            shape = self._shapes[path]
            *lead, n, m = shape
            nl = int(np.prod(lead)) if lead else 1
            kmax = 1
            for pk in side.values():
                if path in pk.entries:
                    kmax = max(kmax, pk.entries[path][0].shape[-1])
            # int8 tables also shrink the index halves when the dims fit
            # int16 (the kernel widens them to int32 inside VMEM)
            idx_dt = (np.int16 if int8 and n < 2 ** 15 and m < 2 ** 15
                      else np.int32)
            rows = np.zeros((nl, A, kmax), idx_dt)
            cols = np.zeros((nl, A, kmax), idx_dt)
            vals = np.zeros((nl, A, kmax), np.int8 if int8 else np.float32)
            scale = np.ones((nl, A), np.float32)
            for name, pk in side.items():
                if path not in pk.entries:
                    continue
                s = slots[name]
                direct = self._quant_direct(name, pk, path, packs, qpacks)
                if direct is not None:       # store int8 -> table int8, 1:1
                    idxf, vq, sc = direct
                    idxf = np.asarray(idxf).reshape(nl, -1)
                    k = idxf.shape[-1]
                    rows[:, s, :k] = (idxf // m).astype(idx_dt)
                    cols[:, s, :k] = (idxf % m).astype(idx_dt)
                    vals[:, s, :k] = np.asarray(vq).reshape(nl, -1)
                    scale[:, s] = sc
                    continue
                idx, val = pk.entries[path]
                idxf = np.asarray(idx).reshape(nl, -1)
                valf = np.asarray(val, np.float32).reshape(nl, -1) * pk.alpha
                for i in range(nl):
                    r, c, v = sidedelta_table(idxf[i], valf[i], m, kmax)
                    rows[i, s], cols[i, s] = r.astype(idx_dt), c.astype(idx_dt)
                    if int8:
                        vals[i, s], scale[i, s] = quantize_table(v)
                    else:
                        vals[i, s] = v
            entry = {
                "rows": jnp.asarray(rows.reshape(tuple(lead) + (A, kmax))),
                "cols": jnp.asarray(cols.reshape(tuple(lead) + (A, kmax))),
                "vals": jnp.asarray(vals.reshape(tuple(lead) + (A, kmax))),
            }
            if int8:
                entry["scale"] = jnp.asarray(scale.reshape(tuple(lead) + (A,)))
            tables[path] = entry
        meta = {"tenants": len(side), "paths": len(tables),
                "bytes": sum(int(x.nbytes) for t in tables.values()
                             for x in t.values())}
        return slots, tables, meta

    # ------------------------------------------------------------------
    # Async table builds (overlap rebuild + H2D with in-flight decode)
    # ------------------------------------------------------------------

    def tables_ready(self) -> bool:
        """True when serving can proceed without a synchronous rebuild —
        tables are clean, or a completed background build was adopted."""
        if self._dirty:
            self.poll_async_build()
        return not self._dirty

    def kick_async_build(self) -> bool:
        """Start rebuilding the device tables on a background worker so the
        table pack + H2D upload overlap whatever the serving thread does
        next (the in-flight decode step). Snapshot semantics: the build
        captures the tenant/fused state at submit; any later
        ``_mark_dirty`` makes it stale and it is discarded at poll time.
        With a deferred fused transition pending, the build targets the
        *post-transition* state. Returns True when tables are clean or a
        matching build is in flight; False means a stale build is still
        running (back off and kick again next step)."""
        if not self._dirty and self._pending is None:
            return True
        if self._build_fut is not None:
            ep, fut, trans = self._build_fut
            if not fut.done():
                return ep == self._epoch and trans is self._pending
            self.poll_async_build()
            if not self._dirty and self._pending is None:
                return True
        epoch = self._epoch
        pending = self._pending
        packs, qpacks = dict(self.packs), dict(self._qpacks)
        stacks, fused = dict(self._stacks), self.fused
        if pending is not None:
            fused = (normalize_tenant(pending.promote)
                     if pending.promote is not None else None)
        if self._build_pool is None:
            self._build_pool = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="shira-tables")

        def job():
            with trace.span("prefetch.h2d", cat="tables") as sp:
                side = self._side_packs(packs, stacks, fused)
                slots, tables, meta = self._build_tables(side, packs, qpacks)
                # land the uploads on the worker: the serving thread must
                # never pay this build's device sync
                jax.block_until_ready([x for t in tables.values()
                                       for x in t.values()])
                sp.set(**meta)
            return slots, tables

        self._build_fut = (epoch, self._build_pool.submit(job), pending)
        self.async_builds += 1
        return True

    def poll_async_build(self) -> bool:
        """Adopt a completed background build if it still matches the
        engine state; discard it otherwise. A transition build also
        dispatches its deferred fuse/unfuse scatter at adoption. Never
        blocks. Returns True when tables are clean after the poll."""
        if self._build_fut is None:
            return not self._dirty
        ep, fut, trans = self._build_fut
        if not fut.done():
            return not self._dirty
        self._build_fut = None
        try:
            slots, tables = fut.result()
        except Exception:
            trace.instant("prefetch.h2d_failed", cat="tables")
            return not self._dirty
        if ep != self._epoch or trans is not self._pending:
            self.async_stale += 1
        elif trans is not None:
            # apply the deferred transition: the scatter is async-dispatched
            # (device-ordered before anything that reads the new shared
            # tree), the matching tables swap in the same host step
            if trans.promote is not None:
                self._promote(trans.promote)
            elif trans.demote is not None:
                self._demote()
            self._pending = None
            self._slots, self._tables = slots, tables
            self._dirty = False
            self._structural = False
            self.async_adopted += 1
        elif self._dirty:
            self._slots, self._tables = slots, tables
            self._dirty = False
            self._structural = False
            self.async_adopted += 1
        else:
            self.async_stale += 1
        return not self._dirty

    def _ensure_tables(self) -> None:
        """Make the tables serve-ready: adopt a finished background build,
        wait for a matching in-flight one (``prefetch.stall`` — the time
        async serving failed to hide), or fall back to the synchronous
        rebuild. Token output is identical on every path: same builder,
        same inputs."""
        if not self._dirty:
            return
        if not self.poll_async_build():
            if (self._build_fut is not None
                    and self._build_fut[0] == self._epoch):
                with trace.span("prefetch.stall", cat="tables"):
                    try:
                        self._build_fut[1].result()
                    except Exception:
                        pass
                self.poll_async_build()
        if self._dirty:
            self._rebuild()

    def shutdown(self) -> None:
        """Join the background build worker (tests / clean teardown)."""
        pool, self._build_pool = self._build_pool, None
        if pool is not None:
            pool.shutdown(wait=True)

    def table_nbytes(self) -> Dict[str, int]:
        """Device-side adapter-table bytes by component (what multi-tenant
        serving keeps resident in HBM). int8 tables shrink ``vals`` 4x and,
        when the dims fit int16, ``rows``/``cols`` 2x."""
        self._ensure_tables()
        out = {"rows": 0, "cols": 0, "vals": 0, "scale": 0}
        for t in self._tables.values():
            for k in out:
                if k in t:
                    out[k] += int(t[k].nbytes)
        out["total"] = sum(out.values())
        return out

    # ------------------------------------------------------------------
    # Fused-state transitions (the scheduler's promote/demote)
    # ------------------------------------------------------------------

    def _demote(self) -> None:
        if self.fused is None:
            return
        with trace.span("unfuse", cat="switch",
                        tenant=tenant_key(self.fused)):
            for m in tenant_members(self.fused):
                self.shared = apply_pack(self.shared, self.packs[m],
                                         sign=-1.0)
        self.fused = None
        self.fuse_transitions += 1
        self._mark_dirty()

    def _promote(self, tenant: Tenant) -> None:
        tenant = normalize_tenant(tenant)
        if tenant == self.fused or tenant is None:
            return
        self._demote()
        with trace.span("fuse", cat="switch", tenant=tenant_key(tenant)):
            for m in tenant_members(tenant):
                self.shared = apply_pack(self.shared, self.packs[m],
                                         sign=+1.0)
        self.fused = tenant
        self.fuse_transitions += 1
        self._mark_dirty()

    def schedule(self, names: Sequence, defer: bool = False) -> None:
        """Consult the scheduler for this batch's traffic; apply its
        promote/demote before serving.

        ``defer=True`` (the async serving engines) does not apply the
        transition inline: the decision is stashed and the tables for the
        *post-transition* state are built in the background while serving
        continues — fully correct — on the current fused state and tables.
        When that build lands (``poll_async_build``) the fuse/unfuse
        scatter is dispatched and the tables swap atomically, so a
        promotion costs the in-flight decode nothing."""
        if self.scheduler is None:
            return
        d = self.scheduler.observe([normalize_tenant(n) for n in names])
        if d.promote is None and d.demote is None:
            return
        if defer:
            # replacing an unapplied decision is safe: _promote/_demote
            # always transition from the engine's CURRENT fused state, and
            # the old pending build dies on the identity check at poll time
            self._pending = d
            return
        if d.promote is not None:
            self._promote(d.promote)
        elif d.demote is not None:
            self._demote()

    # ------------------------------------------------------------------
    # Forward passes
    # ------------------------------------------------------------------

    def ids_covered(self, names: Sequence) -> bool:
        """True when the current tables can still serve these tenants
        correctly even though a rebuild is pending: only *additive* changes
        (new tenants) happened since the last build, and every requested
        tenant already has a slot. The async serving engines use this to
        keep decoding hot tenants off stale tables while a cold adapter's
        rebuild runs in the background."""
        if not self._dirty:
            return True
        if self._structural:
            return False
        for t in (normalize_tenant(n) for n in names):
            if t is None:
                if self.fused is not None and _BASE_SLOT not in self._slots:
                    return False
            elif t != self.fused and t not in self._slots:
                return False
        return True

    def ids_for(self, names: Sequence, stale_ok: bool = False) -> jax.Array:
        norm = [normalize_tenant(n) for n in names]
        self._batch_no += 1
        for t in norm:
            for m in tenant_members(t):
                if m not in self.packs:
                    raise KeyError(f"request names unregistered adapter "
                                   f"{m!r}")
            if t is not None and not isinstance(t, str):
                if t not in self._stacks:
                    self._mark_dirty(additive=True)  # new stack: needs a slot
                self._stacks[t] = self._batch_no
        # retire stacks that left the traffic mix, or table slots (and
        # rebuild work per new ad-hoc combination) grow without bound
        for t in [t for t, used in self._stacks.items()
                  if t != self.fused
                  and self._batch_no - used > self.stack_ttl]:
            del self._stacks[t]
            # removal: remaining tenants' rows stay valid until the rebuild
            self._mark_dirty(additive=True)
        if not (stale_ok and self.ids_covered(norm)):
            self._ensure_tables()
        ids = []
        for t in norm:
            if t == self.fused or (t is BASE and self.fused is None):
                ids.append(-1)               # pure shared base
            elif t is BASE:
                ids.append(self._slots[_BASE_SLOT])
            else:
                ids.append(self._slots[t])
        return jnp.asarray(ids, jnp.int32)

    def wrapped_params(self, ids: jax.Array, stale_ok: bool = False):
        """The shared tree with side-delta bundles at every adapted weight.
        ``stale_ok`` trusts the caller's ``ids_for(..., stale_ok=True)``
        coverage check and skips the rebuild barrier."""
        if not stale_ok:
            self._ensure_tables()
        tables = self._tables

        def walk(tree, prefix):
            if isinstance(tree, dict):
                return {k: walk(v, prefix + (str(k),)) for k, v in tree.items()}
            if isinstance(tree, (list, tuple)):
                t = [walk(v, prefix + (str(i),)) for i, v in enumerate(tree)]
                return tuple(t) if isinstance(tree, tuple) else t
            key = "/".join(prefix)
            if key in tables:
                t = tables[key]
                lead = tree.shape[:-2]
                return sidedelta_weight(
                    tree, t["rows"], t["cols"], t["vals"],
                    jnp.broadcast_to(ids, lead + ids.shape),
                    scale=t.get("scale"))
            return tree

        return walk(self.shared, ())

    def prefill(self, batch, names: Sequence[Optional[str]], cache_size: int):
        p = self.wrapped_params(self.ids_for(names))
        return self._prefill(p, batch, cache_size)

    def decode_step(self, tokens, caches, pos, names: Sequence[Optional[str]]):
        p = self.wrapped_params(self.ids_for(names))
        return self._decode(p, tokens, caches, pos)

    def generate(self, batch, names: Sequence[Optional[str]], tokens: int,
                 cache_size: Optional[int] = None):
        """Greedy-decode ``tokens`` tokens for a mixed-adapter batch.

        Returns (out_tokens (B, tokens) int32, seconds)."""
        cs = cache_size or serving_cache_size(self.cfg,
                                              batch["tokens"].shape[1],
                                              tokens)
        self.schedule(names)
        ids = self.ids_for(names)
        p = self.wrapped_params(ids)
        t0 = time.perf_counter()
        out, _ = greedy_decode(
            self.cfg, batch, tokens,
            lambda b: self._prefill(p, b, cs),
            lambda t, c, pos: self._decode(p, t, c, pos))
        dt = time.perf_counter() - t0
        return out, dt
