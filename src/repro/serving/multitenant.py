"""Multi-tenant SHiRA serving: per-request adapters in ONE batch.

The sequential serving path (``launch/serve.py`` + ``SwitchEngine``) swaps
adapters *between* batches: two users wanting different adapters can never
share a decode step. Sparse adapters make the per-request fix cheap — each
request's delta is 1-2% of the weights — so this engine keeps ONE shared
copy of the base weights and applies every request's SHiRA pack as a
batched sparse side term in the forward pass:

  y[b] = x[b] @ W_shared  +  x[b] @ dW_{adapter(b)}

The side term is computed by the Pallas ``sidedelta`` kernel
(repro/kernels/sidedelta.py) from packed per-adapter (row, col, val)
tables; the weight leaves of the served parameter tree are replaced by
``layers.sidedelta_weight`` bundles, which ``layers.pdot`` understands and
which survive the LM's ``lax.scan`` over stacked layer weights (every table
carries the weight's leading layer dims).

Fused-state scheduling: with a ``core.switching.FusedLRU`` scheduler, the
engine additionally fuses the *hot* adapter into the shared base (a single
sparse scatter — the paper's rapid switch), so dominant-tenant requests skip
the side term entirely. The other tenants are then served with diff packs
(their delta minus the fused one, built by ``fusion.fuse_packs``), and base
-model requests with the negated fused pack. Demotion scatters the delta
back out and restores plain packs.

Limitations: adapters on ``w_uk``/``w_uv`` (MLA absorbed-decode weights,
consumed via reshape rather than a matmul) are rejected — exclude them from
``AdapterConfig.target_modules`` when serving MLA archs multi-tenant.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adapters import AdapterPack, apply_pack
from repro.core.fusion import fuse_packs
from repro.core.switching import FusedLRU, SwitchEngine
from repro.kernels.ops import sidedelta_table
from repro.models import lm
from repro.models.layers import sidedelta_weight

BASE = None            # the "no adapter" tenant in a names list
_BASE_SLOT = "__base__"

# MLA absorbed-decode weights are reshaped, not matmul'd — pdot never sees
# them, so a side-delta bundle there would crash (or silently diverge).
UNSUPPORTED_LEAVES = ("w_uk", "w_uv")


def _leaf_shapes(params) -> Dict[str, Tuple[int, ...]]:
    out = {}
    from repro.core import masks as M
    for p, x in jax.tree_util.tree_flatten_with_path(params)[0]:
        out[M.path_str(p)] = tuple(x.shape)
    return out


def greedy_decode(cfg, batch, tokens: int, prefill, decode):
    """The serving decode loop, shared by the engine, the sequential
    references, and the benchmark so position bookkeeping (incl. the vision
    prefix) cannot drift between them.

    prefill(batch) -> (logits, caches); decode(tok, caches, pos) ->
    (logits, caches). Returns (greedy tokens (B, tokens) int32, last-step
    logits (B, V)).
    """
    prompt_len = batch["tokens"].shape[1]
    pos0 = prompt_len + (cfg.num_prefix_embeds
                         if cfg.modality == "vision" else 0)
    logits, caches = prefill(batch)
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    outs = [nxt]
    for i in range(tokens - 1):
        logits, caches = decode(nxt, caches, pos0 + i)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        outs.append(nxt)
    jax.block_until_ready(logits)
    return jnp.concatenate(outs, axis=1), logits


def serving_cache_size(cfg, prompt_len: int, tokens: int) -> int:
    """KV-cache slots for a serve call: prompt + generated + slack, PLUS the
    vision prefix (prefix embeddings occupy cache positions too)."""
    prefix = cfg.num_prefix_embeds if cfg.modality == "vision" else 0
    return prompt_len + prefix + tokens + 8


def switch_per_request_reference(cfg, params, packs, toks, names,
                                 tokens: int):
    """Ground-truth baseline: serve each request ALONE after rapid-switching
    (SwitchEngine) to its adapter. The multi-tenant engine's batched outputs
    are validated against this in tests and examples (the benchmark uses the
    stronger switch-per-GROUP baseline instead).

    toks: (B, S) int; names: per-request adapter name or None. Returns
    (greedy tokens (B, tokens) int32, last-step logits (B, V) f32, seconds).
    """
    toks = np.asarray(toks)
    B, S = toks.shape
    cs = serving_cache_size(cfg, S, tokens)
    by_name = {p.name: p for p in packs}
    engine = SwitchEngine(params)
    prefill = jax.jit(lambda p, b: lm.prefill(p, cfg, b, cs))
    decode = jax.jit(lambda p, t, c, pos: lm.decode_step(p, cfg, t, c, pos))
    out = np.zeros((B, tokens), np.int32)
    logits_last = np.zeros((B, cfg.padded_vocab), np.float32)
    t0 = time.perf_counter()
    for b, name in enumerate(names):
        while engine.active:
            engine.unload()
        if name is not None:
            engine.load(by_name[name])
        seq, logits = greedy_decode(
            cfg, {"tokens": jnp.asarray(toks[b:b + 1])}, tokens,
            lambda bb: prefill(engine.params, bb),
            lambda t, c, pos: decode(engine.params, t, c, pos))
        out[b] = np.asarray(seq)[0]
        logits_last[b] = np.asarray(logits, np.float32)[0]
    dt = time.perf_counter() - t0
    while engine.active:
        engine.unload()
    return out, logits_last, dt


class MultiTenantEngine:
    """Serves mixed-adapter batches off one shared base parameter tree."""

    def __init__(self, cfg, params, *, scheduler: Optional[FusedLRU] = None):
        self.cfg = cfg
        self.shared = params                 # base (+ the fused pack, if any)
        self.packs: Dict[str, AdapterPack] = {}
        self.scheduler = scheduler
        self.fused: Optional[str] = None
        self.fuse_transitions = 0            # promote/demote scatter count
        self._shapes = _leaf_shapes(params)
        self._tables: Dict[str, dict] = {}   # path -> rows/cols/vals arrays
        self._slots: Dict[str, int] = {}     # tenant name -> table slot
        self._dirty = False
        self._prefill = jax.jit(
            lambda p, b, cs: lm.prefill(p, self.cfg, b, cs),
            static_argnums=2)
        self._decode = jax.jit(
            lambda p, t, c, pos: lm.decode_step(p, self.cfg, t, c, pos))

    # ------------------------------------------------------------------
    # Registration / side-delta tables
    # ------------------------------------------------------------------

    def register(self, pack: AdapterPack) -> None:
        for path in pack.entries:
            leaf = path.rsplit("/", 1)[-1]
            if leaf in UNSUPPORTED_LEAVES:
                raise ValueError(
                    f"adapter {pack.name!r} targets {path!r}: {leaf} is "
                    "consumed outside pdot (MLA absorbed decode); exclude it "
                    "from target_modules for multi-tenant serving")
            if path not in self._shapes:
                raise KeyError(f"adapter {pack.name!r} targets unknown "
                               f"weight {path!r}")
        if pack.name == self.fused:
            # un-fuse the OLD delta before replacing the pack, or the next
            # demote would subtract the new one from a base holding the old
            self._demote()
            if self.scheduler is not None and \
                    self.scheduler.fused == pack.name:
                self.scheduler.fused = None  # keep it re-promotable
        self.packs[pack.name] = pack
        self._dirty = True

    def _side_packs(self) -> Dict[str, AdapterPack]:
        """What each tenant's side delta must be, given the fused state."""
        out = {}
        for name, pack in self.packs.items():
            if name == self.fused:
                continue                     # fused tenant rides the base
            if self.fused is None:
                out[name] = pack
            else:
                out[name] = fuse_packs([pack, self.packs[self.fused]],
                                       weights=[1.0, -1.0],
                                       name=f"{name}-minus-{self.fused}")
        if self.fused is not None:           # base traffic must un-see it
            out[_BASE_SLOT] = fuse_packs([self.packs[self.fused]],
                                         weights=[-1.0],
                                         name=f"-{self.fused}")
        return out

    def _rebuild(self) -> None:
        side = self._side_packs()
        self._slots = {name: i for i, name in enumerate(sorted(side))}
        paths = sorted({p for pk in side.values() for p in pk.entries})
        tables: Dict[str, dict] = {}
        A = max(len(side), 1)
        for path in paths:
            shape = self._shapes[path]
            *lead, n, m = shape
            nl = int(np.prod(lead)) if lead else 1
            kmax = 1
            for pk in side.values():
                if path in pk.entries:
                    kmax = max(kmax, pk.entries[path][0].shape[-1])
            rows = np.zeros((nl, A, kmax), np.int32)
            cols = np.zeros((nl, A, kmax), np.int32)
            vals = np.zeros((nl, A, kmax), np.float32)
            for name, pk in side.items():
                if path not in pk.entries:
                    continue
                s = self._slots[name]
                idx, val = pk.entries[path]
                idxf = np.asarray(idx).reshape(nl, -1)
                valf = np.asarray(val, np.float32).reshape(nl, -1) * pk.alpha
                for i in range(nl):
                    r, c, v = sidedelta_table(idxf[i], valf[i], m, kmax)
                    rows[i, s], cols[i, s], vals[i, s] = r, c, v
            tables[path] = {
                "rows": jnp.asarray(rows.reshape(tuple(lead) + (A, kmax))),
                "cols": jnp.asarray(cols.reshape(tuple(lead) + (A, kmax))),
                "vals": jnp.asarray(vals.reshape(tuple(lead) + (A, kmax))),
            }
        self._tables = tables
        self._dirty = False

    # ------------------------------------------------------------------
    # Fused-state transitions (the scheduler's promote/demote)
    # ------------------------------------------------------------------

    def _demote(self) -> None:
        if self.fused is None:
            return
        self.shared = apply_pack(self.shared, self.packs[self.fused],
                                 sign=-1.0)
        self.fused = None
        self.fuse_transitions += 1
        self._dirty = True

    def _promote(self, name: str) -> None:
        if name == self.fused:
            return
        self._demote()
        self.shared = apply_pack(self.shared, self.packs[name], sign=+1.0)
        self.fused = name
        self.fuse_transitions += 1
        self._dirty = True

    def schedule(self, names: Sequence[Optional[str]]) -> None:
        """Consult the scheduler for this batch's traffic; apply its
        promote/demote before serving."""
        if self.scheduler is None:
            return
        d = self.scheduler.observe(list(names))
        if d.promote is not None:
            self._promote(d.promote)
        elif d.demote is not None:
            self._demote()

    # ------------------------------------------------------------------
    # Forward passes
    # ------------------------------------------------------------------

    def ids_for(self, names: Sequence[Optional[str]]) -> jax.Array:
        if self._dirty:
            self._rebuild()
        ids = []
        for name in names:
            if name == self.fused or (name is BASE and self.fused is None):
                ids.append(-1)               # pure shared base
            elif name is BASE:
                ids.append(self._slots[_BASE_SLOT])
            else:
                ids.append(self._slots[name])
        return jnp.asarray(ids, jnp.int32)

    def wrapped_params(self, ids: jax.Array):
        """The shared tree with side-delta bundles at every adapted weight."""
        if self._dirty:
            self._rebuild()
        tables = self._tables

        def walk(tree, prefix):
            if isinstance(tree, dict):
                return {k: walk(v, prefix + (str(k),)) for k, v in tree.items()}
            if isinstance(tree, (list, tuple)):
                t = [walk(v, prefix + (str(i),)) for i, v in enumerate(tree)]
                return tuple(t) if isinstance(tree, tuple) else t
            key = "/".join(prefix)
            if key in tables:
                t = tables[key]
                lead = tree.shape[:-2]
                return sidedelta_weight(
                    tree, t["rows"], t["cols"], t["vals"],
                    jnp.broadcast_to(ids, lead + ids.shape))
            return tree

        return walk(self.shared, ())

    def prefill(self, batch, names: Sequence[Optional[str]], cache_size: int):
        p = self.wrapped_params(self.ids_for(names))
        return self._prefill(p, batch, cache_size)

    def decode_step(self, tokens, caches, pos, names: Sequence[Optional[str]]):
        p = self.wrapped_params(self.ids_for(names))
        return self._decode(p, tokens, caches, pos)

    def generate(self, batch, names: Sequence[Optional[str]], tokens: int,
                 cache_size: Optional[int] = None):
        """Greedy-decode ``tokens`` tokens for a mixed-adapter batch.

        Returns (out_tokens (B, tokens) int32, seconds)."""
        cs = cache_size or serving_cache_size(self.cfg,
                                              batch["tokens"].shape[1],
                                              tokens)
        self.schedule(names)
        ids = self.ids_for(names)
        p = self.wrapped_params(ids)
        t0 = time.perf_counter()
        out, _ = greedy_decode(
            self.cfg, batch, tokens,
            lambda b: self._prefill(p, b, cs),
            lambda t, c, pos: self._decode(p, t, c, pos))
        dt = time.perf_counter() - t0
        return out, dt
