"""Low-overhead host-side serving tracer: spans, instants, counters.

The serving engines are instrumented with ``trace.span(...)`` /
``trace.instant(...)`` / ``trace.counter(...)`` calls at every phase the
replay cost model (``analysis/replay.py``) attributes time to: engine
steps, admission, prefill chunks, decode passes, COW page copies, fuse /
demote scatters, device-table rebuilds (H2D uploads) and adapter disk
loads. Tracing is OFF by default and the hooks are then near-free: each
call is one module-global load plus a singleton return — no allocation,
no branching in the recorded path (``tests/test_observability.py`` pins
the per-call cost at far below 1% of a decode step).

Enable tracing by installing a tracer::

    from repro.analysis import trace
    tr = trace.install()            # or trace.install(Tracer(capacity=...))
    ... serve ...
    trace.uninstall()
    tr.to_jsonl("run.trace.jsonl")          # one event per line
    tr.to_chrome("run.trace.json")          # chrome://tracing / Perfetto

Event model (serving loop on one host thread, plus prefetch workers):

  * ``span(name, cat=..., **args)`` — a context manager timing a phase.
    Recorded on exit as ``{"ph": "X", "name", "cat", "ts", "dur",
    "depth", "tid", "args"}`` with ``ts``/``dur`` in microseconds
    relative to the tracer's epoch. ``depth`` is the nesting level at
    entry (0 = top level) *within the recording thread*; the object
    returned by ``__enter__`` supports ``.set(**kw)`` to attach args
    discovered mid-span.
  * ``instant(name, **args)`` — a zero-duration marker (``"ph": "i"``).
  * ``counter(name, value)`` — a sampled gauge (``"ph": "C"``), e.g.
    page-pool pressure per step.

Threads: the serving loop stays single-threaded, but the async adapter
prefetch pipeline (``AdapterStore.prefetch`` disk loads, background
device-table builds) records spans from worker threads. Every event
carries a small ``tid``: 0 is the thread that called ``install()`` (the
serving loop), workers get 1, 2, ... in first-seen order. Span nesting
(``depth``, and the replay model's interval stacks) is tracked per
thread, so a worker's ``prefetch.disk`` span overlapping the main
thread's ``decode`` never corrupts either thread's tree — that overlap
is exactly what ``replay.verify_overlap`` measures.

The buffer is a bounded ring: when ``capacity`` events have been
recorded the oldest are dropped (``tracer.dropped`` counts them), so a
long-lived serving loop can stay instrumented without unbounded host
memory.

Fault / degradation events (``runtime/faults.py``; full failure model in
``src/repro/runtime/README.md``): the injector marks every injected
event as a ``fault.*`` instant (cat ``fault``: ``fault.disk_fail``,
``fault.corrupt``, ``fault.io_latency``, ``fault.worker_death``,
``fault.build_fail``, ``fault.poison``, ``fault.preempt``), and the
degradation ladder emits its decisions as instants too — ``store.retry``
/ ``store.quarantine`` (cat ``store``), ``shed.deadline`` /
``shed.queue_full`` / ``degrade`` / ``slot.poison`` (cat ``serving``),
``fault.build_backoff`` (cat ``tables``) — so a chaos-bench trace shows
both what was injected and how serving absorbed it.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional

__all__ = ["Tracer", "install", "uninstall", "active", "enabled",
           "span", "instant", "counter"]


class _NullSpan:
    """Singleton returned by ``span()`` when tracing is off."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **kw):
        return self


_NULL = _NullSpan()
_tracer: Optional["Tracer"] = None


class _Span:
    __slots__ = ("_tr", "name", "cat", "args", "_t0", "_depth", "_tid")

    def __init__(self, tr: "Tracer", name: str, cat: str, args: dict):
        self._tr = tr
        self.name = name
        self.cat = cat
        self.args = args

    def set(self, **kw):
        """Attach args discovered while the span is open."""
        self.args.update(kw)
        return self

    def __enter__(self):
        tr = self._tr
        self._tid = tr._tid()
        self._depth = tr._enter_depth()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        tr = self._tr
        tr._exit_depth()
        tr._push({"ph": "X", "name": self.name, "cat": self.cat,
                  "ts": (self._t0 - tr.epoch) * 1e6,
                  "dur": (t1 - self._t0) * 1e6,
                  "depth": self._depth, "tid": self._tid,
                  "args": self.args})
        return False


class Tracer:
    """Bounded in-memory event ring with JSONL / Chrome export."""

    def __init__(self, capacity: int = 1 << 16):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.epoch = time.perf_counter()
        self._buf: "deque[Dict[str, Any]]" = deque()
        self.dropped = 0
        # per-thread small tids (0 = the installing/serving thread; workers
        # get 1, 2, ... first-seen) and per-thread nesting depth — prefetch
        # workers record concurrently with the serving loop
        self._lock = threading.Lock()
        self._tids: Dict[int, int] = {threading.get_ident(): 0}
        self._depths: Dict[int, int] = {}

    # -- recording -----------------------------------------------------

    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            with self._lock:
                tid = self._tids.setdefault(ident, len(self._tids))
        return tid

    def _enter_depth(self) -> int:
        ident = threading.get_ident()
        d = self._depths.get(ident, 0)
        self._depths[ident] = d + 1
        return d

    def _exit_depth(self) -> None:
        ident = threading.get_ident()
        self._depths[ident] = max(self._depths.get(ident, 1) - 1, 0)

    def _push(self, ev: Dict[str, Any]) -> None:
        with self._lock:
            if len(self._buf) >= self.capacity:
                self._buf.popleft()
                self.dropped += 1
            self._buf.append(ev)

    def span(self, name: str, cat: str = "serving",
             args: Optional[dict] = None) -> _Span:
        return _Span(self, name, cat, dict(args or {}))

    def instant(self, name: str, cat: str = "serving",
                args: Optional[dict] = None) -> None:
        self._push({"ph": "i", "name": name, "cat": cat,
                    "ts": (time.perf_counter() - self.epoch) * 1e6,
                    "dur": 0.0,
                    "depth": self._depths.get(threading.get_ident(), 0),
                    "tid": self._tid(), "args": dict(args or {})})

    def counter(self, name: str, value: float,
                cat: str = "serving") -> None:
        self._push({"ph": "C", "name": name, "cat": cat,
                    "ts": (time.perf_counter() - self.epoch) * 1e6,
                    "dur": 0.0,
                    "depth": self._depths.get(threading.get_ident(), 0),
                    "tid": self._tid(), "args": {"value": float(value)}})

    # -- access / export ----------------------------------------------

    def __len__(self) -> int:
        return len(self._buf)

    def events(self) -> List[Dict[str, Any]]:
        """All buffered events in timestamp order."""
        return sorted(self._buf, key=lambda e: e["ts"])

    def clear(self) -> None:
        self._buf.clear()
        self.dropped = 0
        self._depths.clear()

    def to_jsonl(self, path: str) -> str:
        """One event object per line — the replay cost model's input."""
        with open(path, "w") as f:
            for ev in self.events():
                f.write(json.dumps(ev, sort_keys=True))
                f.write("\n")
        return path

    def to_chrome(self, path: str) -> str:
        """Chrome trace-event JSON (load in chrome://tracing / Perfetto)."""
        out = []
        for ev in self.events():
            ce = {"name": ev["name"], "cat": ev["cat"] or "serving",
                  "ph": ev["ph"], "ts": ev["ts"], "pid": 0,
                  "tid": ev.get("tid", 0), "args": ev["args"]}
            if ev["ph"] == "X":
                ce["dur"] = ev["dur"]
            if ev["ph"] == "i":
                ce["s"] = "t"
            out.append(ce)
        with open(path, "w") as f:
            json.dump({"traceEvents": out}, f)
        return path

    def summary(self) -> Dict[str, Any]:
        by_name: Dict[str, float] = {}
        n_spans = 0
        for ev in self._buf:
            if ev["ph"] == "X":
                n_spans += 1
                by_name[ev["name"]] = by_name.get(ev["name"], 0.0) + ev["dur"]
        return {"events": len(self._buf), "spans": n_spans,
                "dropped": self.dropped, "dur_us_by_name": by_name}


# ---------------------------------------------------------------------------
# Module-level switchboard (what the instrumentation hooks call).
# ---------------------------------------------------------------------------

def install(tracer: Optional[Tracer] = None) -> Tracer:
    """Install (and return) the active tracer. Hooks record into it until
    ``uninstall()``."""
    global _tracer
    _tracer = tracer if tracer is not None else Tracer()
    return _tracer


def uninstall() -> Optional[Tracer]:
    """Disable tracing; returns the tracer that was active (if any)."""
    global _tracer
    tr, _tracer = _tracer, None
    return tr


def active() -> Optional[Tracer]:
    return _tracer


def enabled() -> bool:
    return _tracer is not None


def span(name: str, /, cat: str = "serving", **args):
    """Time a phase. No-op (returns a shared null context) when tracing
    is off — safe to leave in hot serving loops. ``name``/``cat`` are
    positional-only so span args may themselves be called ``name``."""
    t = _tracer
    if t is None:
        return _NULL
    return t.span(name, cat, args)


def instant(name: str, /, cat: str = "serving", **args) -> None:
    t = _tracer
    if t is not None:
        t.instant(name, cat, args)


def counter(name: str, value: float, cat: str = "serving") -> None:
    t = _tracer
    if t is not None:
        t.counter(name, value, cat)
