"""Post-SPMD HLO analysis: memory, FLOPs, and collective traffic.

``cost_analysis`` gives HLO FLOPs and bytes for the *per-device* partitioned
module (verified empirically), but XLA does not count collective traffic —
so we parse the optimized HLO text. Two subtleties matter:

  * collectives inside ``while`` loops (our layer scans) appear once in the
    text but run trip-count times; XLA annotates loops with
    ``known_trip_count`` after optimization, which we use as a multiplier
    while walking the computation graph from ENTRY;
  * per-kind byte cost uses the ring model with the replica-group size S
    parsed from the instruction:  all-reduce 2·R·(S-1)/S, all-gather
    R·(S-1)/S (R = gathered result), reduce-scatter R·(S-1) (R = scattered
    result, input was R·S), all-to-all R·(S-1)/S, collective-permute R.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Any, Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
}

_COLL_OPS = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
    "all-reduce-start", "all-gather-start", "collective-permute-start",
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*"
    r"(\([^)]*\)|[\w\[\],\{\}]+)\s+"     # result type (maybe tuple)
    r"([\w\-]+)\(")                       # op name
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\{\s*$")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 2  # unknown -> conservative


def _ring_bytes(kind: str, result_bytes: int, s: int) -> float:
    frac = (s - 1) / s
    if kind == "all-reduce":
        return 2.0 * result_bytes * frac
    if kind == "all-gather":
        return result_bytes * frac
    if kind == "reduce-scatter":
        return result_bytes * (s - 1)
    if kind in ("all-to-all", "ragged-all-to-all", "collective-broadcast"):
        return result_bytes * frac
    return float(result_bytes)  # collective-permute


def _split_computations(hlo: str) -> Tuple[Dict[str, List[str]], Optional[str]]:
    comps: Dict[str, List[str]] = {}
    entry = None
    cur = None
    for line in hlo.splitlines():
        if not line.startswith(" "):
            m = _COMP_RE.match(line)
            if m and "(" in line:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
                continue
        stripped = line.strip()
        if stripped == "}":
            cur = None
            continue
        if cur is not None and stripped:
            comps[cur].append(stripped)
    return comps, entry


def _called_comps(line: str) -> List[str]:
    out = []
    for key in ("body=", "condition=", "to_apply=", "called_computations="):
        for m in re.finditer(re.escape(key) + r"\{?%?([\w\.\-]+)", line):
            out.append(m.group(1))
    return out


def _trip_count(line: str) -> Optional[int]:
    m = re.search(r"trip_count[^0-9]*(\d+)", line)
    return int(m.group(1)) if m else None


def collective_bytes(compiled_or_text) -> Dict[str, Any]:
    """Ring-model collective bytes per device for one program execution."""
    if isinstance(compiled_or_text, str):
        hlo = compiled_or_text
    else:
        try:
            hlo = compiled_or_text.as_text()
        except Exception as e:  # pragma: no cover
            return {"error": str(e)}
    comps, entry = _split_computations(hlo)

    per_kind = defaultdict(float)
    per_kind_corr = defaultdict(float)
    per_kind_count = defaultdict(int)
    unknown_loops = [0]
    pod_bytes = [0.0]

    def walk(comp: str, mult: int, depth: int):
        if comp not in comps or depth > 16:
            return
        for line in comps[comp]:
            m = _INSTR_RE.match(line)
            called = _called_comps(line)
            if m:
                result_ty, op = m.group(1), m.group(2)
                if op == "while":
                    tc = _trip_count(line)
                    if tc is None:
                        tc = 1
                        unknown_loops[0] += 1
                    for c in called:
                        walk(c, mult * tc, depth + 1)
                    continue
                if op in _COLL_OPS and not op.endswith("-done"):
                    kind = op.replace("-start", "")
                    s = _group_size(line)
                    nbytes = _ring_bytes(kind, _shape_bytes(result_ty), s)
                    # pod-axis (DCN) traffic: on the (2,16,16) mesh the only
                    # group of size 2 is the inter-pod axis — the slow link.
                    if s == 2:
                        corr0 = nbytes
                        if "f32" in result_ty and (
                                "promoted" in line or "dot_general" in line):
                            corr0 = nbytes * 0.5
                        pod_bytes[0] += corr0 * mult
                    # XLA:CPU promotes bf16 dots to f32 (no bf16 DotThunk),
                    # dragging the surrounding collectives to f32. On the TPU
                    # target these are bf16 — halve them for the corrected
                    # number (detected via the `_promoted` reduction regions
                    # and dot_general provenance in op metadata).
                    corr = nbytes
                    if "f32" in result_ty and (
                            "promoted" in line or "dot_general" in line):
                        corr = nbytes * 0.5
                    per_kind[kind] += nbytes * mult
                    per_kind_corr[kind] += corr * mult
                    per_kind_count[kind] += mult
                    continue  # don't recurse into reduction regions
            for c in called:
                walk(c, mult, depth + 1)

    if entry:
        walk(entry, 1, 0)

    total = sum(per_kind.values())
    total_corr = sum(per_kind_corr.values())
    return {
        "by_kind_bytes": {k: int(v) for k, v in per_kind.items()},
        "by_kind_bytes_tpu": {k: int(v) for k, v in per_kind_corr.items()},
        "by_kind_count": dict(per_kind_count),
        "total_bytes_raw": int(total),
        "total_bytes": int(total_corr),   # TPU-dtype-corrected
        "total_gb": total_corr / 1e9,
        "pod_axis_bytes": int(pod_bytes[0]),
        "loops_without_trip_count": unknown_loops[0],
    }


# ---------------------------------------------------------------------------
# Loop-weighted program cost (XLA's cost_analysis counts while bodies ONCE,
# verified empirically — wrong for scan-over-layers programs, so we recount).
# ---------------------------------------------------------------------------

_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],\{\}]+)\s+([\w\-]+)\(([^)]*)\)")
_PARAM_HDR_RE = re.compile(r"([\w\.\-]+):\s*([\w\[\],\{\}]+)")
_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_LHS_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")

_NO_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                 "bitcast", "while", "conditional", "after-all", "iota",
                 "partition-id", "replica-id"}


def _parse_shape_dims(ty: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(ty):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _elems(ty: str) -> int:
    n = 0
    for _, dims in _parse_shape_dims(ty):
        e = 1
        for d in dims:
            e *= d
        n += e
    return n


_BYTES_COUNTED_OPS = {"fusion", "dot", "custom-call", "convolution",
                      "reduce", "reduce-window", "sort", "gather",
                      "concatenate", "pad", "reverse", "select-and-scatter",
                      "broadcast", "transpose", "copy", "convert", "reshape",
                      "slice", "cholesky", "triangular-solve", "rng",
                      "dynamic-slice", "dynamic-update-slice", "scatter"}


def _operand_bytes(args: str, smap, index: int) -> int:
    ops = [a.strip().lstrip("%") for a in args.split(",")]
    if index < len(ops) and ops[index] in smap:
        return _shape_bytes(smap[ops[index]])
    return 0


def _op_bytes(op: str, result_ty: str, args: str, smap, line: str) -> float:
    """HBM traffic model per top-level instruction.

    In-place-update ops count only the touched region (XLA aliases the big
    operand): DUS = 2x update region; DS = 2x slice; scatter = 2x updates +
    indices. Fusions/dots/reduces count result + distinct operands once.
    Pure data-plumbing (tuple/gte/bitcast/reshape-of-alias) is free.
    """
    if op not in _BYTES_COUNTED_OPS:
        return 0.0
    if op == "dynamic-update-slice":
        return 2.0 * _operand_bytes(args, smap, 1)
    if op in ("dynamic-slice", "slice", "gather"):
        return 2.0 * _shape_bytes(result_ty)
    if op == "scatter":
        return 2.0 * _operand_bytes(args, smap, 2) \
            + _operand_bytes(args, smap, 1)
    if op in ("broadcast", "reshape"):
        return float(_shape_bytes(result_ty))
    if op in ("copy", "convert", "transpose"):
        return 2.0 * _shape_bytes(result_ty)
    b = float(_shape_bytes(result_ty))
    seen = set()
    for a in args.split(","):
        a = a.strip().lstrip("%")
        if a in smap and a not in seen:
            seen.add(a)
            b += _shape_bytes(smap[a])
    return b


def program_cost(compiled_or_text) -> Dict[str, float]:
    """Loop-weighted FLOPs / bytes estimate from the optimized HLO text.

    dots: 2 * prod(result) * prod(lhs contracting dims), exact.
    elementwise / fusions / reduces: 1 flop per output element (approx).
    bytes: see _op_bytes — result + distinct operands at fusion granularity,
    in-place ops at touched-region granularity, loop-trip-count weighted.
    """
    if isinstance(compiled_or_text, str):
        hlo = compiled_or_text
    else:
        hlo = compiled_or_text.as_text()
    comps, entry = _split_computations(hlo)

    # Pre-parse every computation: defs (name -> type), instructions.
    parsed: Dict[str, List[Tuple[str, str, str, str, str]]] = {}
    shapes: Dict[str, Dict[str, str]] = {}
    headers: Dict[str, str] = {}
    for line in hlo.splitlines():
        if not line.startswith(" "):
            m = _COMP_RE.match(line)
            if m and "(" in line:
                headers[m.group(2)] = line
    for cname, lines in comps.items():
        smap: Dict[str, str] = {}
        instrs = []
        hdr = headers.get(cname, "")
        if "(" in hdr:
            arglist = hdr[hdr.index("(") + 1:]
            for pname, pty in _PARAM_HDR_RE.findall(arglist.split("->")[0]):
                smap[pname] = pty
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            name, ty, op, args = m.groups()
            smap[name] = ty
            instrs.append((name, ty, op, args, line))
        parsed[cname] = instrs
        shapes[cname] = smap

    flops = [0.0]
    dot_flops = [0.0]
    bytes_acc = [0.0]
    unknown_loops = [0]

    def dot_cost(cname, ty, args, line) -> float:
        ops = [a.strip().lstrip("%") for a in args.split(",")]
        lhs = ops[0] if ops else ""
        lhs_ty = shapes[cname].get(lhs, "")
        lhs_dims = _parse_shape_dims(lhs_ty)
        m = _DIMS_RE.search(line)
        if not lhs_dims or not m:
            return 2.0 * _elems(ty)
        dims = lhs_dims[0][1]
        contract = 1
        for di in (int(x) for x in m.group(1).split(",") if x):
            if di < len(dims):
                contract *= dims[di]
        return 2.0 * _elems(ty) * contract

    def walk(cname: str, mult: float, count_bytes: bool, depth: int):
        if cname not in parsed or depth > 24:
            return
        for name, ty, op, args, line in parsed[cname]:
            called = _called_comps(line)
            if op == "while":
                tc = _trip_count(line)
                if tc is None:
                    tc = 1
                    unknown_loops[0] += 1
                for c in called:
                    walk(c, mult * tc, count_bytes, depth + 1)
                continue
            if op == "dot":
                f = dot_cost(cname, ty, args, line) * mult
                flops[0] += f
                dot_flops[0] += f
            elif op in ("fusion", "reduce", "reduce-window", "scatter",
                        "select-and-scatter", "sort", "map", "exp", "tanh",
                        "add", "multiply", "subtract", "divide", "convert",
                        "custom-call"):
                flops[0] += _elems(ty) * mult
            if count_bytes:
                if op == "fusion" and called:
                    # a fusion whose root is a dynamic-update-slice aliases
                    # the big operand: count only the touched region.
                    dus = None
                    for c in called:
                        for _, _, iop, iargs, _ in parsed.get(c, ()):
                            if iop == "dynamic-update-slice":
                                dus = (c, iargs)
                    if dus is not None:
                        b = 2.0 * _operand_bytes(dus[1], shapes[dus[0]], 1)
                    else:
                        b = _op_bytes(op, ty, args, shapes[cname], line)
                else:
                    b = _op_bytes(op, ty, args, shapes[cname], line)
                bytes_acc[0] += b * mult
            for c in called:
                # fusion internals: count dot flops, not bytes
                if op == "fusion":
                    walk(c, mult, False, depth + 1)
                elif op in ("call", "conditional", "async-start"):
                    walk(c, mult, count_bytes, depth + 1)

    if entry:
        walk(entry, 1.0, True, 0)
    return {"flops": flops[0], "dot_flops": dot_flops[0],
            "bytes_accessed": bytes_acc[0],
            "loops_without_trip_count": float(unknown_loops[0])}


def cost_summary(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0))}


def memory_summary(compiled) -> Dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = int(v)
    args = out.get("argument_size_in_bytes", 0)
    temp = out.get("temp_size_in_bytes", 0)
    outb = out.get("output_size_in_bytes", 0)
    alias = out.get("alias_size_in_bytes", 0)
    out["temp_mb"] = round(temp / 1e6, 1)
    out["args_mb"] = round(args / 1e6, 1)
    out["peak_device_mb"] = round((args + temp + outb - alias) / 1e6, 1)
    return out
