"""Three-term roofline from dry-run artifacts (TPU v5e-like target).

  compute   = HLO_FLOPs_per_device / peak_FLOPs
  memory    = HLO_bytes_per_device / HBM_bw
  collective= collective_bytes_per_device / link_bw

``cost_analysis`` of the partitioned module is per-device; collective bytes
parsed from the per-device HLO are per-device too. MODEL_FLOPS uses the
6·N·D (train) / 2·N·D (inference) convention with N_active for MoE.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict

from repro.configs.base import SHAPES, ModelConfig


@dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12     # bf16 FLOP/s per chip
    hbm_bw: float = 819e9          # B/s per chip
    ici_bw: float = 50e9           # B/s per link


def count_params(cfg: ModelConfig) -> Dict[str, float]:
    """Analytic parameter counts (total and active-per-token)."""
    d, L, V = cfg.d_model, cfg.num_layers, cfg.vocab_size
    hd = cfg.resolved_head_dim
    emb = V * d * (1 if cfg.tie_embeddings else 2)

    def attn_params():
        if cfg.attn_type == "mla":
            m = cfg.mla
            qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            p = d * cfg.num_heads * qk                      # wq
            p += d * (m.kv_lora_rank + m.qk_rope_head_dim)  # w_dkv
            p += m.kv_lora_rank * cfg.num_heads * (
                m.qk_nope_head_dim + m.v_head_dim)          # w_uk + w_uv
            p += cfg.num_heads * m.v_head_dim * d           # wo
            return p
        if cfg.attn_type == "none":
            return 0
        return d * hd * (cfg.num_heads * 2 + cfg.num_kv_heads * 2)

    def mlp_params(ff):
        mult = 3 if cfg.act == "silu" else 2
        return mult * d * ff

    def mamba_params():
        s = cfg.ssm
        d_in = s.expand * d
        nh = d_in // s.head_dim
        conv_dim = d_in + 2 * s.n_groups * s.d_state
        return (d * (2 * d_in + 2 * s.n_groups * s.d_state + nh)
                + conv_dim * (s.d_conv + 1) + 3 * nh + d_in + d_in * d)

    total = emb
    active = emb
    if cfg.family in ("dense", "vlm", "audio"):
        per = attn_params() + mlp_params(cfg.d_ff)
        total += L * per
        active += L * per
    elif cfg.family == "moe":
        m = cfg.moe
        fd = m.first_dense_layers
        dense_l = attn_params() + mlp_params(m.first_dense_d_ff or cfg.d_ff)
        moe_total = attn_params() + m.num_experts * mlp_params(m.d_ff) \
            + m.num_shared * mlp_params(m.d_ff) + d * m.num_experts
        moe_active = attn_params() + m.top_k * mlp_params(m.d_ff) \
            + m.num_shared * mlp_params(m.d_ff) + d * m.num_experts
        total += fd * dense_l + (L - fd) * moe_total
        active += fd * dense_l + (L - fd) * moe_active
    elif cfg.family == "ssm":
        total += L * mamba_params()
        active += L * mamba_params()
    elif cfg.family == "hybrid":
        shared = attn_params() + mlp_params(cfg.d_ff) + 2 * d * d
        total += L * mamba_params() + shared
        active += L * mamba_params() \
            + (L // max(cfg.hybrid_attn_every, 1)) * shared
    return {"total": float(total), "active": float(active)}


def model_flops(cfg: ModelConfig, shape_name: str) -> float:
    """6·N_active·D for train, 2·N_active·D for inference steps (global)."""
    shape = SHAPES[shape_name]
    n = count_params(cfg)["active"]
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def roofline_terms(record: Dict[str, Any], cfg: ModelConfig,
                   hw: HW = HW()) -> Dict[str, Any]:
    """record: one dry-run JSON entry (per-device cost numbers)."""
    chips = 1
    for s in record["mesh"]:
        chips *= s
    flops_dev = record["cost"].get("flops", 0.0)
    bytes_dev = record["cost"].get("bytes_accessed", 0.0)
    coll_dev = record["collectives"].get("total_bytes", 0)

    t_compute = flops_dev / hw.peak_flops
    t_memory = bytes_dev / hw.hbm_bw
    t_coll = coll_dev / hw.ici_bw
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(t_compute, t_memory, t_coll)

    mf_global = model_flops(cfg, record["shape"])
    mf_dev = mf_global / chips
    useful_ratio = mf_dev / flops_dev if flops_dev else 0.0
    # roofline fraction: useful model FLOPs per device / (peak * bound time)
    frac = (mf_dev / hw.peak_flops) / bound if bound > 0 else 0.0
    return {
        **terms,
        "dominant": dominant,
        "bound_s": bound,
        "model_flops_global": mf_global,
        "model_flops_per_dev": mf_dev,
        "hlo_flops_per_dev": flops_dev,
        "useful_flops_ratio": useful_ratio,
        "roofline_fraction": frac,
        "chips": chips,
    }
