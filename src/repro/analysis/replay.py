"""Replay cost model over serving traces: attribution, timelines, what-if.

Input is a trace captured by ``analysis/trace.py`` — the in-memory event
list, a ``Tracer``, or a JSONL file it exported. The engines emit one
top-level ``step`` span per scheduling step with nested ``admit`` /
``prefill_chunk`` / ``decode`` / ``cow_copy`` / ``table_rebuild`` /
``fuse`` spans, so a serving run's wall clock decomposes into a per-step
timeline this module reconstructs and explains:

  * ``attribute(events)`` — where did the wall time go? Computes each
    span's SELF time (duration minus enclosed child spans, so nothing is
    double counted), sums it by span name and category, and reports the
    fraction of the observed window covered by top-level spans. The
    serving engines' coverage is the contract: >= 90% of a traced run's
    wall time must land in spans (pinned by tests) or the trace is lying
    about where time goes.
  * ``step_timeline(events)`` — the per-step record: every ``step`` span
    with its nested phases, reproducing the engine's scheduling loop
    tick by tick (step indices come from the span args, not guesswork).
  * ``critical_path(events)`` — the top-level spans ordered by self-time
    contribution; in a single-threaded host loop the critical path IS
    the serial span sequence, so this ranks what to attack first.
  * ``what_if(events, overlap=..., under=..., scale=...)`` — replay the
    timeline under a hypothesis: spans named in ``overlap`` are assumed
    to run concurrently with (hidden under) the ``under`` phase — e.g.
    "what if H2D table uploads overlapped decode" — and ``scale``
    multiplies a phase's self time (e.g. a kernel made 2x faster).
    Returns baseline vs replayed wall and the savings.
  * ``join_costs(events, costs, hw)`` — join measured span times with
    ``analysis/hlo.py`` cost extraction (``program_cost`` /
    ``cost_summary`` dicts): each phase gets a roofline model time
    ``max(flops/peak, bytes/bw)`` and the measured/model ratio — >> 1
    means the phase is host-bound, not device-bound.
  * ``verify_overlap(events, ...)`` — close the async-prefetch loop:
    given a trace of the *async* pipeline (worker-thread
    ``prefetch.disk`` / ``prefetch.h2d`` spans recorded with
    ``tid != 0``), compare the hiding the serial what-if predicts
    (async work fully hidden under the serving thread's ``under``
    phases) against the hiding actually realized (measured temporal
    intersection of worker spans with the serving thread's ``under``
    intervals). CI gates ``realized_frac >= 0.5``.

Threads: events carry a ``tid`` (0 = the serving loop, workers 1+;
missing = 0 for pre-async traces). Self-time interval stacks are built
per tid — a worker span overlapping a serving-thread span is
concurrency, not nesting. The serial quantities (coverage, what-if
replay, critical path) are computed over the serving thread's spans
only; worker time is reported separately (``attribute()["async_by_name"]``).

All times are microseconds (the tracer's unit).
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Optional, Sequence, Union

from repro.analysis.roofline import HW

TraceLike = Union[str, Sequence[Dict[str, Any]], "object"]


def load_trace(source: TraceLike) -> List[Dict[str, Any]]:
    """Events (ts order) from a JSONL path, a Tracer, or an event list."""
    if hasattr(source, "events"):                 # a Tracer
        return list(source.events())
    if isinstance(source, str):
        events = []
        with open(source) as f:
            for line in f:
                line = line.strip()
                if line:
                    events.append(json.loads(line))
    else:
        events = list(source)
    return sorted(events, key=lambda e: e.get("ts", 0.0))


def spans(events: Iterable[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Complete spans only (ph == "X"), in ts order."""
    return sorted((e for e in events if e.get("ph") == "X"),
                  key=lambda e: (e["ts"], -e.get("dur", 0.0)))


def span_tid(e: Dict[str, Any]) -> int:
    """Recording thread of an event; 0 (the serving loop) for traces
    captured before the tracer recorded tids."""
    return int(e.get("tid", 0))


def main_spans(events: Iterable[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Serving-thread spans only (tid 0), in ts order."""
    return [s for s in spans(events) if span_tid(s) == 0]


def _self_times(sps: List[Dict[str, Any]]) -> List[float]:
    """Per-span self time: duration minus enclosed child spans.

    Within one thread spans nest strictly (a child's interval lies
    inside its parent's), so an interval stack recovers the tree without
    trusting the recorded depth. Stacks are kept per tid: a worker
    thread's prefetch span overlapping a serving-thread span is
    concurrency, not parenthood."""
    child = [0.0] * len(sps)
    stacks: Dict[int, List[int]] = {}      # tid -> open-span indices
    for i, s in enumerate(sps):
        stack = stacks.setdefault(span_tid(s), [])
        while stack and sps[stack[-1]]["ts"] + sps[stack[-1]]["dur"] \
                <= s["ts"] + 1e-9:
            stack.pop()
        if stack:
            child[stack[-1]] += s["dur"]
        stack.append(i)
    return [max(s["dur"] - c, 0.0) for s, c in zip(sps, child)]


def attribute(events: TraceLike,
              wall_us: Optional[float] = None) -> Dict[str, Any]:
    """Wall-time attribution: self time by span name/category + coverage.

    ``wall_us`` is the window to measure coverage against; when omitted
    it is the observed event window (first ts to last ts+dur). Coverage
    counts the serving thread's (tid 0) TOP-LEVEL spans only (depth 0):
    nested spans are already inside their parents' intervals, and
    worker-thread spans run concurrently with the wall clock rather
    than consuming it — their self time is reported separately in
    ``async_by_name``."""
    events = load_trace(events)
    sps = spans(events)
    if not sps:
        return {"wall_us": float(wall_us or 0.0), "covered_us": 0.0,
                "coverage": 0.0, "by_name": {}, "by_cat": {},
                "async_by_name": {}, "spans": 0}
    selfs = _self_times(sps)
    by_name: Dict[str, float] = {}
    by_cat: Dict[str, float] = {}
    async_by_name: Dict[str, float] = {}
    for s, st in zip(sps, selfs):
        if span_tid(s) == 0:
            by_name[s["name"]] = by_name.get(s["name"], 0.0) + st
            by_cat[s["cat"]] = by_cat.get(s["cat"], 0.0) + st
        else:
            async_by_name[s["name"]] = async_by_name.get(s["name"], 0.0) + st
    covered = sum(s["dur"] for s in sps
                  if s.get("depth", 0) == 0 and span_tid(s) == 0)
    if wall_us is None:
        t0 = min(e["ts"] for e in events)
        t1 = max(e["ts"] + e.get("dur", 0.0) for e in events)
        wall_us = max(t1 - t0, 1e-9)
    return {"wall_us": float(wall_us), "covered_us": float(covered),
            "coverage": float(covered / max(wall_us, 1e-9)),
            "by_name": by_name, "by_cat": by_cat,
            "async_by_name": async_by_name, "spans": len(sps)}


def step_timeline(events: TraceLike) -> List[Dict[str, Any]]:
    """Per-step reconstruction of the engine loop.

    Returns one record per ``step`` span, in step order::

        {"step": k, "ts": ..., "dur": ..., "phases": {"decode": us, ...},
         "events": [nested span/instant dicts]}

    The step index comes from the span's recorded args (the engines
    stamp ``step=self.step_count``)."""
    events = load_trace(events)
    steps = [e for e in spans(events) if e["name"] == "step"]
    out = []
    for s in steps:
        lo, hi = s["ts"], s["ts"] + s["dur"]
        # a worker-thread prefetch span may fall inside the step's window
        # temporally, but it is not part of the step's serial work
        inner = [e for e in events
                 if lo - 1e-9 <= e["ts"] and e["ts"] + e.get("dur", 0.0)
                 <= hi + 1e-9 and e is not s and e.get("ph") != "C"
                 and span_tid(e) == 0]
        phases: Dict[str, float] = {}
        for e in inner:
            if e.get("ph") == "X":
                phases[e["name"]] = phases.get(e["name"], 0.0) + e["dur"]
        out.append({"step": s["args"].get("step"), "ts": s["ts"],
                    "dur": s["dur"], "phases": phases, "events": inner})
    out.sort(key=lambda r: (r["step"] is None, r["step"], r["ts"]))
    return out


def critical_path(events: TraceLike, top: int = 10) -> List[Dict[str, Any]]:
    """Phases ranked by total self time — the serial loop's critical path."""
    att = attribute(events)
    ranked = sorted(att["by_name"].items(), key=lambda kv: -kv[1])
    total = sum(att["by_name"].values()) or 1.0
    return [{"name": n, "self_us": v, "frac": v / total}
            for n, v in ranked[:top]]


def what_if(events: TraceLike, *, overlap: Sequence[str] = (),
            under: str = "decode",
            scale: Optional[Dict[str, float]] = None,
            wall_us: Optional[float] = None) -> Dict[str, float]:
    """Replay the trace under a hypothesis.

    ``overlap`` names phases assumed to run concurrently with the
    ``under`` phase (async dispatch): their self time is hidden up to
    the ``under`` phase's own (scaled) self time — you cannot hide 40ms
    of uploads under 10ms of decode. ``scale`` multiplies named phases'
    self times (e.g. ``{"decode": 0.5}`` = a 2x faster decode step).
    Uncovered wall (host time outside any span) is carried through
    unchanged. The replay is a serial model of the serving thread, so
    only tid-0 spans participate — worker-thread prefetch spans are
    already off the critical path. Returns ``{"baseline_us",
    "replayed_us", "saved_us", "hidden_us", "speedup"}``."""
    events = load_trace(events)
    sps = main_spans(events)
    selfs = _self_times(sps)
    scale = scale or {}
    by_name: Dict[str, float] = {}
    for s, st in zip(sps, selfs):
        by_name[s["name"]] = by_name.get(s["name"], 0.0) + st
    att = attribute(events, wall_us=wall_us)
    baseline = att["wall_us"]
    uncovered = max(baseline - sum(by_name.values()), 0.0)
    scaled = {n: v * float(scale.get(n, 1.0)) for n, v in by_name.items()}
    over = sum(v for n, v in scaled.items() if n in set(overlap))
    budget = scaled.get(under, 0.0)
    hidden = min(over, budget)
    replayed = sum(scaled.values()) - hidden + uncovered
    return {"baseline_us": float(baseline), "replayed_us": float(replayed),
            "saved_us": float(baseline - replayed), "hidden_us": float(hidden),
            "speedup": float(baseline / max(replayed, 1e-9))}


def _merge_intervals(ivals: List[List[float]]) -> List[List[float]]:
    """Union of [lo, hi) intervals, sorted and non-overlapping."""
    out: List[List[float]] = []
    for lo, hi in sorted(ivals):
        if out and lo <= out[-1][1]:
            out[-1][1] = max(out[-1][1], hi)
        else:
            out.append([lo, hi])
    return out


def _intersect_us(lo: float, hi: float,
                  merged: List[List[float]]) -> float:
    """Length of [lo, hi) covered by a merged interval list."""
    got = 0.0
    for a, b in merged:
        if b <= lo:
            continue
        if a >= hi:
            break
        got += min(b, hi) - max(a, lo)
    return got


def verify_overlap(events: TraceLike, *,
                   async_names: Optional[Sequence[str]] = None,
                   under: Sequence[str] = ("decode", "prefill_chunk",
                                           "admit"),
                   baseline: Optional[TraceLike] = None,
                   serial_names: Sequence[str] = ("disk_load",
                                                  "table_rebuild"),
                   serial_under: str = "decode") -> Dict[str, Any]:
    """Did the async prefetch pipeline realize the hiding the what-if
    predicted?

    ``events`` is a trace of the *async* pipeline: adapter disk loads
    and device-table builds run on worker threads, so their spans
    (``prefetch.disk``, ``prefetch.h2d``) carry ``tid != 0``.

      * **predicted** hiding is what the serial replay model promises:
        with ``baseline`` (a pre-change synchronous trace, e.g. the
        archived ``TRACE_slo_load.sync.jsonl``), it is
        ``what_if(baseline, overlap=serial_names, under=serial_under)
        ["hidden_us"]`` — the serial ``disk_load``/``table_rebuild``
        self time hideable under decode. Without a baseline it is the
        self-contained bound ``min(async worker time, under budget)``:
        every microsecond of worker time could have hidden under the
        serving thread's ``under`` phases.
      * **measured** hiding is the realized temporal intersection of
        the worker spans with the serving thread's ``under`` intervals
        — time the async work actually ran concurrently with decode
        instead of stalling it.

    ``realized_frac = measured / predicted`` is the contract CI gates
    (>= 0.5): a pipeline that silently serializes (the serving thread
    blocking on every load) measures ~0 overlap and trips the gate even
    though end-to-end numbers may hide it in noise. When there is
    nothing to hide (``predicted == 0``) the fraction is vacuously 1.0;
    ``async_spans == 0`` means the pipeline never ran — callers should
    treat that as its own failure when async serving was expected."""
    events = load_trace(events)
    sps = spans(events)
    selfs = _self_times(sps)
    under = tuple(under)
    workers = [(s, st) for s, st in zip(sps, selfs) if span_tid(s) != 0
               and (async_names is None or s["name"] in set(async_names))]
    async_by_name: Dict[str, float] = {}
    for s, st in workers:
        async_by_name[s["name"]] = async_by_name.get(s["name"], 0.0) + st
    async_us = sum(async_by_name.values())
    under_sps = [s for s in sps if span_tid(s) == 0 and s["name"] in under]
    under_us = sum(st for s, st in zip(sps, selfs)
                   if span_tid(s) == 0 and s["name"] in under)
    merged = _merge_intervals([[s["ts"], s["ts"] + s["dur"]]
                               for s in under_sps])
    # measured hiding: worker-span *durations* against the under windows
    # (a worker span's wall time is concurrent whether or not it nests
    # other worker spans, so full dur — not self — is what overlaps)
    measured = sum(_intersect_us(s["ts"], s["ts"] + s["dur"], merged)
                   for s, _ in workers
                   if s.get("depth", 0) == 0 or span_tid(s) != 0)
    if baseline is not None:
        predicted = what_if(load_trace(baseline), overlap=serial_names,
                            under=serial_under)["hidden_us"]
    else:
        predicted = min(async_us, under_us)
    realized = measured / predicted if predicted > 1e-9 else 1.0
    return {"async_us": float(async_us), "under_us": float(under_us),
            "predicted_hidden_us": float(predicted),
            "measured_hidden_us": float(measured),
            "realized_frac": float(realized),
            "async_spans": len(workers),
            "async_by_name": async_by_name, "under": list(under)}


# ---------------------------------------------------------------------------
# Joining traces with analysis/hlo.py cost extraction
# ---------------------------------------------------------------------------

def modelled_us(cost: Dict[str, float], hw: Optional[HW] = None) -> float:
    """Roofline time (microseconds) for one execution of a program whose
    HLO cost dict (``analysis.hlo.program_cost`` / ``cost_summary``)
    is ``cost``: max of the compute and memory terms."""
    hw = hw or HW()
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes_accessed", 0.0))
    return max(flops / hw.peak_flops, nbytes / hw.hbm_bw) * 1e6


def join_costs(events: TraceLike, costs: Dict[str, Dict[str, float]],
               hw: Optional[HW] = None) -> Dict[str, Dict[str, float]]:
    """Per-phase measured vs modelled time.

    ``costs`` maps a span name (e.g. ``"decode"``) to the HLO cost dict
    of the program that span launches. Returns, per phase::

        {"count", "measured_us_total", "measured_us_mean",
         "model_us", "ratio"}       # ratio >> 1: host/dispatch-bound

    The per-op timeline: multiply a phase's model_us by its count to get
    the device-time floor for the whole run; the gap to measured self
    time is host overhead the what-if replay can target."""
    events = load_trace(events)
    sps = spans(events)
    selfs = _self_times(sps)
    agg: Dict[str, List[float]] = {}
    for s, st in zip(sps, selfs):
        agg.setdefault(s["name"], []).append(st)
    out: Dict[str, Dict[str, float]] = {}
    for name, cost in costs.items():
        samples = agg.get(name, [])
        model = modelled_us(cost, hw)
        total = sum(samples)
        mean = total / len(samples) if samples else 0.0
        out[name] = {"count": float(len(samples)),
                     "measured_us_total": total,
                     "measured_us_mean": mean,
                     "model_us": model,
                     "ratio": mean / model if model > 0 else float("inf")}
    return out
