"""Replay cost model over serving traces: attribution, timelines, what-if.

Input is a trace captured by ``analysis/trace.py`` — the in-memory event
list, a ``Tracer``, or a JSONL file it exported. The engines emit one
top-level ``step`` span per scheduling step with nested ``admit`` /
``prefill_chunk`` / ``decode`` / ``cow_copy`` / ``table_rebuild`` /
``fuse`` spans, so a serving run's wall clock decomposes into a per-step
timeline this module reconstructs and explains:

  * ``attribute(events)`` — where did the wall time go? Computes each
    span's SELF time (duration minus enclosed child spans, so nothing is
    double counted), sums it by span name and category, and reports the
    fraction of the observed window covered by top-level spans. The
    serving engines' coverage is the contract: >= 90% of a traced run's
    wall time must land in spans (pinned by tests) or the trace is lying
    about where time goes.
  * ``step_timeline(events)`` — the per-step record: every ``step`` span
    with its nested phases, reproducing the engine's scheduling loop
    tick by tick (step indices come from the span args, not guesswork).
  * ``critical_path(events)`` — the top-level spans ordered by self-time
    contribution; in a single-threaded host loop the critical path IS
    the serial span sequence, so this ranks what to attack first.
  * ``what_if(events, overlap=..., under=..., scale=...)`` — replay the
    timeline under a hypothesis: spans named in ``overlap`` are assumed
    to run concurrently with (hidden under) the ``under`` phase — e.g.
    "what if H2D table uploads overlapped decode" — and ``scale``
    multiplies a phase's self time (e.g. a kernel made 2x faster).
    Returns baseline vs replayed wall and the savings.
  * ``join_costs(events, costs, hw)`` — join measured span times with
    ``analysis/hlo.py`` cost extraction (``program_cost`` /
    ``cost_summary`` dicts): each phase gets a roofline model time
    ``max(flops/peak, bytes/bw)`` and the measured/model ratio — >> 1
    means the phase is host-bound, not device-bound.

All times are microseconds (the tracer's unit).
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Optional, Sequence, Union

from repro.analysis.roofline import HW

TraceLike = Union[str, Sequence[Dict[str, Any]], "object"]


def load_trace(source: TraceLike) -> List[Dict[str, Any]]:
    """Events (ts order) from a JSONL path, a Tracer, or an event list."""
    if hasattr(source, "events"):                 # a Tracer
        return list(source.events())
    if isinstance(source, str):
        events = []
        with open(source) as f:
            for line in f:
                line = line.strip()
                if line:
                    events.append(json.loads(line))
    else:
        events = list(source)
    return sorted(events, key=lambda e: e.get("ts", 0.0))


def spans(events: Iterable[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Complete spans only (ph == "X"), in ts order."""
    return sorted((e for e in events if e.get("ph") == "X"),
                  key=lambda e: (e["ts"], -e.get("dur", 0.0)))


def _self_times(sps: List[Dict[str, Any]]) -> List[float]:
    """Per-span self time: duration minus enclosed child spans.

    Single-threaded traces nest strictly (a child's interval lies inside
    its parent's), so an interval stack recovers the tree without
    trusting the recorded depth."""
    child = [0.0] * len(sps)
    stack: List[int] = []                  # indices of currently-open spans
    for i, s in enumerate(sps):
        end = s["ts"] + s["dur"]
        while stack and sps[stack[-1]]["ts"] + sps[stack[-1]]["dur"] \
                <= s["ts"] + 1e-9:
            stack.pop()
        if stack:
            child[stack[-1]] += s["dur"]
        stack.append(i)
        del end
    return [max(s["dur"] - c, 0.0) for s, c in zip(sps, child)]


def attribute(events: TraceLike,
              wall_us: Optional[float] = None) -> Dict[str, Any]:
    """Wall-time attribution: self time by span name/category + coverage.

    ``wall_us`` is the window to measure coverage against; when omitted
    it is the observed event window (first ts to last ts+dur). Coverage
    counts TOP-LEVEL spans only (depth 0): nested spans are already
    inside their parents' intervals."""
    events = load_trace(events)
    sps = spans(events)
    if not sps:
        return {"wall_us": float(wall_us or 0.0), "covered_us": 0.0,
                "coverage": 0.0, "by_name": {}, "by_cat": {}, "spans": 0}
    selfs = _self_times(sps)
    by_name: Dict[str, float] = {}
    by_cat: Dict[str, float] = {}
    for s, st in zip(sps, selfs):
        by_name[s["name"]] = by_name.get(s["name"], 0.0) + st
        by_cat[s["cat"]] = by_cat.get(s["cat"], 0.0) + st
    covered = sum(s["dur"] for s in sps if s.get("depth", 0) == 0)
    if wall_us is None:
        t0 = min(e["ts"] for e in events)
        t1 = max(e["ts"] + e.get("dur", 0.0) for e in events)
        wall_us = max(t1 - t0, 1e-9)
    return {"wall_us": float(wall_us), "covered_us": float(covered),
            "coverage": float(covered / max(wall_us, 1e-9)),
            "by_name": by_name, "by_cat": by_cat, "spans": len(sps)}


def step_timeline(events: TraceLike) -> List[Dict[str, Any]]:
    """Per-step reconstruction of the engine loop.

    Returns one record per ``step`` span, in step order::

        {"step": k, "ts": ..., "dur": ..., "phases": {"decode": us, ...},
         "events": [nested span/instant dicts]}

    The step index comes from the span's recorded args (the engines
    stamp ``step=self.step_count``)."""
    events = load_trace(events)
    steps = [e for e in spans(events) if e["name"] == "step"]
    out = []
    for s in steps:
        lo, hi = s["ts"], s["ts"] + s["dur"]
        inner = [e for e in events
                 if lo - 1e-9 <= e["ts"] and e["ts"] + e.get("dur", 0.0)
                 <= hi + 1e-9 and e is not s and e.get("ph") != "C"]
        phases: Dict[str, float] = {}
        for e in inner:
            if e.get("ph") == "X":
                phases[e["name"]] = phases.get(e["name"], 0.0) + e["dur"]
        out.append({"step": s["args"].get("step"), "ts": s["ts"],
                    "dur": s["dur"], "phases": phases, "events": inner})
    out.sort(key=lambda r: (r["step"] is None, r["step"], r["ts"]))
    return out


def critical_path(events: TraceLike, top: int = 10) -> List[Dict[str, Any]]:
    """Phases ranked by total self time — the serial loop's critical path."""
    att = attribute(events)
    ranked = sorted(att["by_name"].items(), key=lambda kv: -kv[1])
    total = sum(att["by_name"].values()) or 1.0
    return [{"name": n, "self_us": v, "frac": v / total}
            for n, v in ranked[:top]]


def what_if(events: TraceLike, *, overlap: Sequence[str] = (),
            under: str = "decode",
            scale: Optional[Dict[str, float]] = None,
            wall_us: Optional[float] = None) -> Dict[str, float]:
    """Replay the trace under a hypothesis.

    ``overlap`` names phases assumed to run concurrently with the
    ``under`` phase (async dispatch): their self time is hidden up to
    the ``under`` phase's own (scaled) self time — you cannot hide 40ms
    of uploads under 10ms of decode. ``scale`` multiplies named phases'
    self times (e.g. ``{"decode": 0.5}`` = a 2x faster decode step).
    Uncovered wall (host time outside any span) is carried through
    unchanged. Returns ``{"baseline_us", "replayed_us", "saved_us",
    "hidden_us", "speedup"}``."""
    events = load_trace(events)
    sps = spans(events)
    selfs = _self_times(sps)
    scale = scale or {}
    by_name: Dict[str, float] = {}
    for s, st in zip(sps, selfs):
        by_name[s["name"]] = by_name.get(s["name"], 0.0) + st
    att = attribute(events, wall_us=wall_us)
    baseline = att["wall_us"]
    uncovered = max(baseline - sum(by_name.values()), 0.0)
    scaled = {n: v * float(scale.get(n, 1.0)) for n, v in by_name.items()}
    over = sum(v for n, v in scaled.items() if n in set(overlap))
    budget = scaled.get(under, 0.0)
    hidden = min(over, budget)
    replayed = sum(scaled.values()) - hidden + uncovered
    return {"baseline_us": float(baseline), "replayed_us": float(replayed),
            "saved_us": float(baseline - replayed), "hidden_us": float(hidden),
            "speedup": float(baseline / max(replayed, 1e-9))}


# ---------------------------------------------------------------------------
# Joining traces with analysis/hlo.py cost extraction
# ---------------------------------------------------------------------------

def modelled_us(cost: Dict[str, float], hw: Optional[HW] = None) -> float:
    """Roofline time (microseconds) for one execution of a program whose
    HLO cost dict (``analysis.hlo.program_cost`` / ``cost_summary``)
    is ``cost``: max of the compute and memory terms."""
    hw = hw or HW()
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes_accessed", 0.0))
    return max(flops / hw.peak_flops, nbytes / hw.hbm_bw) * 1e6


def join_costs(events: TraceLike, costs: Dict[str, Dict[str, float]],
               hw: Optional[HW] = None) -> Dict[str, Dict[str, float]]:
    """Per-phase measured vs modelled time.

    ``costs`` maps a span name (e.g. ``"decode"``) to the HLO cost dict
    of the program that span launches. Returns, per phase::

        {"count", "measured_us_total", "measured_us_mean",
         "model_us", "ratio"}       # ratio >> 1: host/dispatch-bound

    The per-op timeline: multiply a phase's model_us by its count to get
    the device-time floor for the whole run; the gap to measured self
    time is host overhead the what-if replay can target."""
    events = load_trace(events)
    sps = spans(events)
    selfs = _self_times(sps)
    agg: Dict[str, List[float]] = {}
    for s, st in zip(sps, selfs):
        agg.setdefault(s["name"], []).append(st)
    out: Dict[str, Dict[str, float]] = {}
    for name, cost in costs.items():
        samples = agg.get(name, [])
        model = modelled_us(cost, hw)
        total = sum(samples)
        mean = total / len(samples) if samples else 0.0
        out[name] = {"count": float(len(samples)),
                     "measured_us_total": total,
                     "measured_us_mean": mean,
                     "model_us": model,
                     "ratio": mean / model if model > 0 else float("inf")}
    return out
