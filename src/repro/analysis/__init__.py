from repro.analysis.hlo import collective_bytes, cost_summary, memory_summary  # noqa: F401
from repro.analysis.roofline import HW, roofline_terms  # noqa: F401
from repro.analysis.trace import Tracer  # noqa: F401
