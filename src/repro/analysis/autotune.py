"""Measured-time (bm, kc) tile autotuning for the sidedelta kernel.

``kernels/sidedelta.py::plan_tiles`` picks its tile plan from a static
VMEM byte budget — a safe bound, not a measurement. This module closes
the loop: for each ``(S, n, m, K)`` shape class it sweeps the feasible
``(bm, kc)`` candidates (lane-aligned, within the same budget), times
the real ``sidedelta_rows`` dispatch, and persists the winners in a JSON
plan cache that ``plan_tiles`` consults before falling back to the
static heuristic (``sidedelta.install_plan_cache``; invalid entries are
rejected at lookup, so a stale cache degrades to the heuristic instead
of producing a broken kernel).

Typical flow (also what ``python -m repro.analysis.autotune`` runs)::

    from repro.analysis import autotune
    shapes = autotune.observed_shapes()     # shape classes plan_tiles saw
    plans = autotune.autotune(shapes)       # sweep + measure
    autotune.save_cache(plans, "benchmarks/plan_cache.json")
    autotune.install(plans)                 # live in this process

    # later processes:
    autotune.install(autotune.load_cache("benchmarks/plan_cache.json"))

Shape classes are discovered, not guessed: ``observe()`` wraps a
workload (an engine warmup, a bench) and records every distinct
``plan_tiles`` request made under it. Measurements use the engine's own
dispatch path (``sidedelta_rows`` under ``jit``) so the numbers include
exactly what serving pays — XLA-compiled off-TPU, Mosaic on TPU.
"""
from __future__ import annotations

import contextlib
import json
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

import importlib

# the kernels package re-exports a *function* named ``sidedelta`` (the op),
# shadowing the submodule at package level — resolve the module directly
SD = importlib.import_module("repro.kernels.sidedelta")

PlanKey = SD.PlanKey
Plan = Tuple[int, int]

_LANE = SD._LANE


# ---------------------------------------------------------------------------
# Shape-class discovery
# ---------------------------------------------------------------------------

_observed: "dict[PlanKey, int]" = {}


@contextlib.contextmanager
def observe():
    """Record every (S, n, m, K) shape class ``plan_tiles`` is asked to
    plan while the context is open (run your serving warmup inside)."""
    orig = SD.plan_tiles

    def recording(S, n, m, K, *, vmem_budget=SD.DEFAULT_VMEM_BUDGET,
                  x_itemsize=4):
        key = SD.plan_cache_key(S, n, m, K, vmem_budget, x_itemsize)
        _observed[key] = _observed.get(key, 0) + 1
        return orig(S, n, m, K, vmem_budget=vmem_budget,
                    x_itemsize=x_itemsize)

    SD.plan_tiles = recording
    try:
        yield
    finally:
        SD.plan_tiles = orig


def observed_shapes() -> List[PlanKey]:
    """Shape classes seen under ``observe()``, most-requested first."""
    return sorted(_observed, key=lambda k: -_observed[k])


def clear_observed() -> None:
    _observed.clear()


# ---------------------------------------------------------------------------
# Candidate enumeration + measurement
# ---------------------------------------------------------------------------

def candidates(key: PlanKey, max_candidates: int = 12) -> List[Plan]:
    """Feasible (bm, kc) plans for one shape class: lane-aligned tiles
    within the class's VMEM budget, static plan included, deduped."""
    S, n, m, K, budget, isize = key
    m_pad = SD._round_up(max(m, 1), _LANE)
    K_pad = SD._round_up(max(K, 1), _LANE)
    bms = sorted({bm for bm in (_LANE, 2 * _LANE, 4 * _LANE, 8 * _LANE,
                                16 * _LANE, m_pad)
                  if _LANE <= bm <= m_pad})
    kcs = sorted({min(kc, K_pad) for kc in (_LANE, 2 * _LANE, 4 * _LANE)})
    out = [SD.plan_tiles(S, n, m, K, vmem_budget=budget, x_itemsize=isize)]
    for bm in bms:
        for kc in kcs:
            plan = (bm, kc)
            if plan in out:
                continue
            if SD.plan_is_valid(S, n, m, K, bm, kc, vmem_budget=budget,
                                x_itemsize=isize):
                out.append(plan)
    return out[:max_candidates]


def measure_plan(key: PlanKey, plan: Plan, *, batch: int = 2,
                 adapters: int = 2, reps: int = 3, seed: int = 0,
                 interpret: bool = False) -> float:
    """Best-of-``reps`` seconds for one jitted ``sidedelta_rows`` step at
    this shape class under the given (bm, kc) override (one warmup rep
    compiles)."""
    import jax
    import jax.numpy as jnp

    S, n, m, K, budget, isize = key
    bm, kc = plan
    rng = np.random.default_rng(seed)
    dt = jnp.float32 if isize == 4 else jnp.bfloat16
    x = jnp.asarray(rng.standard_normal((batch, S, n)), dt)
    rows = jnp.asarray(rng.integers(0, n, (adapters, max(K, 1))), jnp.int32)
    cols = jnp.asarray(rng.integers(0, m, (adapters, max(K, 1))), jnp.int32)
    vals = jnp.asarray(rng.standard_normal((adapters, max(K, 1))),
                       jnp.float32)
    ids = jnp.asarray(rng.integers(0, adapters, (batch,)), jnp.int32)

    fn = jax.jit(lambda xx, ii: SD.sidedelta_rows(
        xx, rows, cols, vals, ii, m, interpret=interpret, bm=bm, kc=kc,
        vmem_budget=budget))
    jax.block_until_ready(fn(x, ids))            # compile
    best = float("inf")
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x, ids))
        best = min(best, time.perf_counter() - t0)
    return best


def autotune(shapes: Iterable[PlanKey], *, reps: int = 3, batch: int = 2,
             interpret: bool = False, verbose: bool = False,
             max_candidates: int = 12) -> Dict[PlanKey, Plan]:
    """Sweep each shape class and return the measured-best plan per key.

    Only classes where some candidate actually beat the static plan are
    interesting, but every swept class gets an entry — a cache hit that
    reproduces the static plan is still a skipped heuristic."""
    plans: Dict[PlanKey, Plan] = {}
    for key in shapes:
        best_plan, best_t = None, float("inf")
        for plan in candidates(key, max_candidates=max_candidates):
            t = measure_plan(key, plan, reps=reps, batch=batch,
                             interpret=interpret)
            if verbose:
                S, n, m, K = key[:4]
                print(f"  (S={S},n={n},m={m},K={K}) bm={plan[0]:5d} "
                      f"kc={plan[1]:4d}: {t * 1e6:9.1f} us")
            if t < best_t:
                best_plan, best_t = plan, t
        if best_plan is not None:
            plans[key] = best_plan
    return plans


# ---------------------------------------------------------------------------
# Persistence + installation
# ---------------------------------------------------------------------------

def save_cache(plans: Dict[PlanKey, Plan], path: str,
               meta: Optional[dict] = None) -> str:
    """JSON plan cache: ``{"S,n,m,K,budget,itemsize": [bm, kc], ...}``."""
    body = {",".join(str(x) for x in key): [int(bm), int(kc)]
            for key, (bm, kc) in sorted(plans.items())}
    with open(path, "w") as f:
        json.dump({"schema": 1, "meta": dict(meta or {}), "plans": body},
                  f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def load_cache(path: str) -> Dict[PlanKey, Plan]:
    with open(path) as f:
        doc = json.load(f)
    plans: Dict[PlanKey, Plan] = {}
    for key, plan in doc.get("plans", {}).items():
        parts = tuple(int(x) for x in key.split(","))
        if len(parts) == 6 and len(plan) == 2:
            plans[parts] = (int(plan[0]), int(plan[1]))
    return plans


def install(plans: Dict[PlanKey, Plan], replace: bool = False) -> int:
    """Make ``plan_tiles`` consult these plans (process-wide)."""
    return SD.install_plan_cache(plans, replace=replace)


def maybe_install_file(path: str) -> int:
    """Install a plan-cache file if it exists; returns entries installed
    (0 when the file is absent — callers need no existence check)."""
    import os
    if not os.path.exists(path):
        return 0
    return install(load_cache(path))


# ---------------------------------------------------------------------------
# CLI: observe a smoke serving workload, sweep, persist.
# ---------------------------------------------------------------------------

def _collect_smoke_shapes(arch: str, batch: int, prompt_len: int,
                          tokens: int, adapters: int) -> List[PlanKey]:
    """Run small multi-tenant and paged-engine workloads under
    ``observe()`` so the swept shape classes are exactly what the bench
    tier plans for — both engines, at the benches' f32 compute precision
    (the plan-cache key includes the input itemsize, so bf16-collected
    classes would never hit under the f32 benches)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_smoke_config
    from repro.hub import PagedServingEngine
    from repro.launch.serve import make_adapters
    from repro.models import layers, lm
    from repro.serving import MultiTenantEngine

    cfg = get_smoke_config(arch)
    clear_observed()
    with layers.compute_precision(jnp.float32):
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        packs = make_adapters(cfg, params, adapters, jax.random.PRNGKey(7),
                              multi_tenant=True)
        engine = MultiTenantEngine(cfg, params)
        for p in packs:
            engine.register(p)
        names = [packs[i % adapters].name for i in range(batch)]
        toks = jax.random.randint(jax.random.PRNGKey(1),
                                  (batch, prompt_len), 0, cfg.vocab_size)
        with observe():
            engine.generate({"tokens": toks}, names, tokens)

        # the paged engine plans different classes: S = chunk_size prefill
        # chunks and S = 1 decode over the live lane set
        pe = PagedServingEngine(cfg, params, slots=4, num_pages=64,
                                page_size=2, max_len=prompt_len + tokens + 2,
                                chunk_size=4)
        for p in packs:
            pe.register(p)
        rng = np.random.default_rng(0)
        with observe():
            for i in range(batch):
                pe.submit(rng.integers(0, cfg.vocab_size, prompt_len),
                          packs[i % adapters].name, max_tokens=tokens)
            pe.run()
    return observed_shapes()


# Representative full-scale serving classes swept alongside whatever the
# smoke warmup observes. The smoke classes pad to one lane tile, where the
# static plan is trivially right; at these sizes the heuristic's
# max-tiles-within-budget bet is measurably wrong on the XLA twin (1.5-2x,
# this is where an autotuned cache earns its keep). (S, n, m, K).
DEFAULT_EXTRA_SHAPES = ((16, 1024, 1024, 8000), (1, 2048, 2048, 16000))


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        description="Autotune sidedelta (bm, kc) plans for the smoke "
        "serving shape classes and write the plan cache JSON.")
    ap.add_argument("--arch", default="starcoder2-7b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=4)
    ap.add_argument("--adapters", type=int, default=3)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--shape", action="append", default=[],
                    metavar="S,n,m,K", help="extra shape class to sweep "
                    "(repeatable; replaces the built-in extras)")
    ap.add_argument("--out", default="benchmarks/plan_cache.json")
    args = ap.parse_args(argv)

    shapes = _collect_smoke_shapes(args.arch, args.batch, args.prompt_len,
                                   args.tokens, args.adapters)
    extras = ([tuple(int(x) for x in s.split(",")) for s in args.shape]
              if args.shape else DEFAULT_EXTRA_SHAPES)
    for S, n, m, K in extras:
        key = SD.plan_cache_key(S, n, m, K)
        if key not in shapes:
            shapes.append(key)
    print(f"observed {len(shapes)} shape classes "
          f"(incl. {len(extras)} full-scale extras); sweeping...")
    plans = autotune(shapes, reps=args.reps, verbose=True)
    static = {k: SD.plan_tiles(*k[:4], vmem_budget=k[4], x_itemsize=k[5])
              for k in plans}
    changed = sum(plans[k] != static[k] for k in plans)
    path = save_cache(plans, args.out,
                      meta={"arch": args.arch, "source": "autotune CLI",
                            "changed_vs_static": changed})
    print(f"wrote {path}: {len(plans)} plans "
          f"({changed} differ from the static heuristic)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
