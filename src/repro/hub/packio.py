"""Pack format v2: versioned on-disk serialization for ``AdapterPack``.

Layout of a ``.shpk`` file:

  magic "SHPKv2\\n\\0" (8 bytes)
  u64 little-endian header length
  header JSON  — name, alpha, value dtype, per-path array descriptors
                 (offsets into the payload), payload crc32
  payload      — the per-path idx/val blobs, back to back

Value storage modes (``values=``):

  f32   raw float32 values + raw int32 indices — byte-exact round trip.
  bf16  values truncated to bfloat16 (stored as u16), raw int32 indices.
  int8  values quantized symmetrically per path (q = round(v / scale),
        scale = max|v| / 127) and indices delta-compressed: each row of
        packed indices is sorted (values permuted with it — scatter-adds
        commute, so the adapter is unchanged), then the gaps are emitted as
        a uint8 stream where 255 means "add 255 and keep going". At SHiRA
        sparsities the mean gap is ~1/(1-sparsity), so almost every gap is
        one byte: ~2 bytes/entry against 8 for f32 (>= 3x smaller), which
        is what lets thousands of tenants stay disk- and HBM-resident.

Loading an int8 file with ``dequantize=False`` returns a :class:`QuantPack`
— the compressed resident form the ``AdapterStore`` budgets against —
whose ``dequantize()`` materializes a float32 ``AdapterPack`` for serving.

Writes are atomic (tmp file + ``os.replace``), same discipline as
``repro.checkpoint``: a preempted save never corrupts a published pack.
"""
from __future__ import annotations

import json
import os
import struct
import tempfile
import zlib
from dataclasses import dataclass
from typing import Dict, List, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.core.adapters import AdapterPack
from repro.runtime import faults

MAGIC = b"SHPKv2\n\0"
VERSION = 2
VALUE_MODES = ("f32", "bf16", "int8")


class PackFormatError(ValueError):
    """Raised for bad magic, unsupported versions, or checksum mismatch."""


# ---------------------------------------------------------------------------
# Delta coding of sorted packed indices (int8 mode)
# ---------------------------------------------------------------------------

def _delta_encode_row(ix: np.ndarray) -> np.ndarray:
    """Sorted (k,) int64 flat indices -> uint8 gap stream (255 = +255)."""
    gaps = np.diff(ix, prepend=0)
    counts = gaps // 255
    total = int(counts.sum()) + ix.shape[0]
    out = np.full((total,), 255, np.uint8)
    out[np.cumsum(counts + 1) - 1] = (gaps % 255).astype(np.uint8)
    return out


def _delta_decode_row(buf: np.ndarray, k: int) -> np.ndarray:
    """uint8 gap stream -> (k,) int64 sorted flat indices."""
    b = buf.astype(np.int64)
    csum = np.cumsum(np.where(b == 255, 255, b))
    idx = csum[b != 255]
    if idx.shape[0] != k:
        raise PackFormatError(
            f"index stream decodes to {idx.shape[0]} entries, expected {k}")
    return idx


# ---------------------------------------------------------------------------
# Quantized resident form
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class QuantEntry:
    lead: Tuple[int, ...]       # leading (layer-stack) dims of the idx/val
    k: int                      # entries per matrix
    idx_stream: np.ndarray      # uint8, all rows' gap streams back to back
    row_lens: Tuple[int, ...]   # byte length of each row's stream
    vals_q: np.ndarray          # int8 (nl, k), sorted-index order
    scale: float                # per-path dequant scale


@dataclass(frozen=True)
class QuantPack:
    """An int8-quantized adapter as stored on disk: ~2 bytes per nonzero.

    Immutable. ``dequantize()`` materializes the float32 ``AdapterPack``
    view for the engines; the store keeps THIS form resident and budgets
    against ``nbytes()``."""

    name: str
    entries: Dict[str, QuantEntry]
    alpha: float = 1.0

    def num_params(self) -> int:
        return int(sum(e.vals_q.size for e in self.entries.values()))

    def nbytes(self) -> int:
        return int(sum(e.idx_stream.size + e.vals_q.size + 4
                       for e in self.entries.values()))

    def int8_tables(self) -> Dict[str, Tuple[np.ndarray, np.ndarray, float]]:
        """Decode per-path (idx (nl, k) int64, vals_q (nl, k) int8, scale)
        WITHOUT dequantizing values to f32 — the form
        ``MultiTenantEngine(table_dtype="int8")`` builds its device tables
        from, so a store-int8 adapter reaches VMEM with its original
        quantization (one rounding, at pack time)."""
        out = {}
        for path, e in self.entries.items():
            nl = max(int(np.prod(e.lead)), 1) if e.lead else 1
            idx = np.empty((nl, e.k), np.int64)
            off = 0
            for r, ln in enumerate(e.row_lens):
                idx[r] = _delta_decode_row(e.idx_stream[off:off + ln], e.k)
                off += ln
            out[path] = (idx, e.vals_q, e.scale)
        return out

    def dequantize(self) -> AdapterPack:
        entries = {}
        for path, (idx, vq, scale) in self.int8_tables().items():
            e = self.entries[path]
            vals = vq.astype(np.float32) * scale
            entries[path] = (
                jnp.asarray(idx.astype(np.int32).reshape(e.lead + (e.k,))),
                jnp.asarray(vals.reshape(e.lead + (e.k,))))
        return AdapterPack(name=self.name, entries=entries, alpha=self.alpha)


# ---------------------------------------------------------------------------
# save
# ---------------------------------------------------------------------------

def _as_2d(a, dtype) -> Tuple[np.ndarray, Tuple[int, ...], int]:
    a = np.asarray(a)
    *lead, k = a.shape
    nl = max(int(np.prod(lead)), 1) if lead else 1
    return a.reshape(nl, k).astype(dtype), tuple(lead), k


def quantize_pack(pack: AdapterPack) -> QuantPack:
    """int8-quantize a pack in memory (the same transform ``save_pack``
    applies for ``values="int8"``): per-path symmetric scale, (idx, val)
    pairs sorted by index, gaps delta-coded to a uint8 stream."""
    entries = {}
    for path_key in sorted(pack.entries):
        idx, val = pack.entries[path_key]
        idx2, lead, k = _as_2d(idx, np.int64)
        val2 = np.asarray(val).reshape(idx2.shape).astype(np.float32)
        order = np.argsort(idx2, axis=-1, kind="stable")
        idx_sorted = np.take_along_axis(idx2, order, axis=-1)
        val_sorted = np.take_along_axis(val2, order, axis=-1)
        amax = float(np.max(np.abs(val_sorted))) if val_sorted.size else 0.0
        scale = amax / 127.0 if amax > 0 else 1.0
        vq = np.clip(np.rint(val_sorted / scale), -127, 127).astype(np.int8)
        rows = [_delta_encode_row(idx_sorted[r])
                for r in range(idx_sorted.shape[0])]
        stream = np.concatenate(rows) if rows else np.zeros((0,), np.uint8)
        entries[path_key] = QuantEntry(
            lead=lead, k=k, idx_stream=stream,
            row_lens=tuple(int(r.size) for r in rows), vals_q=vq,
            scale=scale)
    return QuantPack(name=pack.name, entries=entries, alpha=pack.alpha)


def save_pack(pack: AdapterPack, path: str, values: str = "f32") -> str:
    """Serialize ``pack`` to ``path`` in format v2. Returns ``path``."""
    if values not in VALUE_MODES:
        raise ValueError(f"values must be one of {VALUE_MODES}, got {values!r}")
    blobs: List[bytes] = []
    off = 0
    entries = {}
    qpack = quantize_pack(pack) if values == "int8" else None
    for path_key in sorted(pack.entries):
        idx, val = pack.entries[path_key]
        idx2, lead, k = _as_2d(idx, np.int64)
        val2 = np.asarray(val).reshape(idx2.shape).astype(np.float32)
        ent: Dict[str, object] = {"lead": list(lead), "k": k}

        if values == "int8":
            e = qpack.entries[path_key]
            ent["idx"] = {"enc": "d8", "off": off,
                          "len": int(e.idx_stream.size),
                          "row_lens": list(e.row_lens)}
            blobs.append(e.idx_stream.tobytes())
            off += e.idx_stream.size
            vb = e.vals_q.tobytes()
            ent["val"] = {"dtype": "int8", "off": off, "len": len(vb),
                          "scale": e.scale}
            blobs.append(vb)
            off += len(vb)
        else:
            ib = idx2.astype(np.int32).tobytes()
            ent["idx"] = {"enc": "i32", "off": off, "len": len(ib)}
            blobs.append(ib)
            off += len(ib)
            if values == "bf16":
                import ml_dtypes
                vb = val2.astype(ml_dtypes.bfloat16).view(np.uint16).tobytes()
                ent["val"] = {"dtype": "bfloat16", "off": off, "len": len(vb)}
            else:
                vb = val2.tobytes()
                ent["val"] = {"dtype": "float32", "off": off, "len": len(vb)}
            blobs.append(vb)
            off += len(vb)
        entries[path_key] = ent

    payload = b"".join(blobs)
    header = {
        "version": VERSION,
        "name": pack.name,
        "alpha": float(pack.alpha),
        "values": values,
        "payload_len": len(payload),
        "payload_crc32": zlib.crc32(payload) & 0xFFFFFFFF,
        "entries": entries,
    }
    hb = json.dumps(header).encode("utf-8")
    d = os.path.dirname(os.path.abspath(path)) or "."
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".shpk.tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(MAGIC)
            f.write(struct.pack("<Q", len(hb)))
            f.write(hb)
            f.write(payload)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return path


# ---------------------------------------------------------------------------
# load
# ---------------------------------------------------------------------------

def _read_header(f) -> dict:
    magic = f.read(len(MAGIC))
    if magic != MAGIC:
        raise PackFormatError(f"bad magic {magic!r}: not a v2 adapter pack")
    raw = f.read(8)
    if len(raw) != 8:
        raise PackFormatError("truncated pack: header length missing")
    (hlen,) = struct.unpack("<Q", raw)
    hb = f.read(hlen)
    if len(hb) != hlen:
        raise PackFormatError(f"truncated pack header: {len(hb)}/{hlen} "
                              "bytes")
    try:
        header = json.loads(hb.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise PackFormatError(f"unreadable pack header: {e}") from e
    if header.get("version") != VERSION:
        raise PackFormatError(f"unsupported pack version "
                              f"{header.get('version')!r}")
    return header


def peek_pack(path: str) -> dict:
    """Header metadata only (name/alpha/values/entries) — no payload read.
    This is what lets the AdapterStore register thousands of packs lazily."""
    with open(path, "rb") as f:
        return _read_header(f)


def load_pack(path: str, dequantize: bool = True
              ) -> Union[AdapterPack, QuantPack]:
    """Read a v2 pack file. f32 round trips bit-exactly; int8 files return
    the compressed ``QuantPack`` when ``dequantize=False``."""
    with open(path, "rb") as f:
        header = _read_header(f)
        payload = f.read()
    # fault injection flips a payload byte here so the REAL crc32 check
    # below is what rejects it — corruption takes the production path
    payload = faults.corrupt_payload(path, payload)
    if len(payload) != header["payload_len"]:
        raise PackFormatError(
            f"payload truncated: {len(payload)} bytes, header says "
            f"{header['payload_len']}")
    crc = zlib.crc32(payload) & 0xFFFFFFFF
    if crc != header["payload_crc32"]:
        raise PackFormatError(
            f"payload checksum mismatch: {crc:#x} != "
            f"{header['payload_crc32']:#x} (corrupted pack)")

    mode = header["values"]
    if mode == "int8":
        qentries = {}
        for path_key, ent in header["entries"].items():
            lead, k = tuple(ent["lead"]), ent["k"]
            nl = max(int(np.prod(lead)), 1) if lead else 1
            io = ent["idx"]
            stream = np.frombuffer(
                payload[io["off"]:io["off"] + io["len"]], np.uint8)
            vo = ent["val"]
            vq = np.frombuffer(
                payload[vo["off"]:vo["off"] + vo["len"]],
                np.int8).reshape(nl, k)
            qentries[path_key] = QuantEntry(
                lead=lead, k=k, idx_stream=stream,
                row_lens=tuple(io["row_lens"]), vals_q=vq,
                scale=vo["scale"])
        qp = QuantPack(name=header["name"], entries=qentries,
                       alpha=header["alpha"])
        return qp.dequantize() if dequantize else qp

    entries = {}
    for path_key, ent in header["entries"].items():
        lead, k = tuple(ent["lead"]), ent["k"]
        nl = max(int(np.prod(lead)), 1) if lead else 1
        io, vo = ent["idx"], ent["val"]
        idx = np.frombuffer(payload[io["off"]:io["off"] + io["len"]],
                            np.int32).reshape(nl, k)
        raw = payload[vo["off"]:vo["off"] + vo["len"]]
        if vo["dtype"] == "bfloat16":
            import ml_dtypes
            val = np.frombuffer(raw, np.uint16).view(
                ml_dtypes.bfloat16).astype(np.float32).reshape(nl, k)
        else:
            val = np.frombuffer(raw, np.float32).reshape(nl, k)
        entries[path_key] = (jnp.asarray(idx.reshape(lead + (k,))),
                             jnp.asarray(val.reshape(lead + (k,))))
    return AdapterPack(name=header["name"], entries=entries,
                       alpha=header["alpha"])
