"""AdapterStore: the adapter registry every engine loads through.

A store maps adapter ids to pack files on disk (format v2, ``packio``) and
keeps a bounded working set resident in memory. Engines never open files
themselves — ``SwitchEngine``, ``MultiTenantEngine``, and the benchmarks ask
the store by name and get back an immutable ``AdapterPack`` handle:

  store = AdapterStore(root, budget_bytes=64 << 20)
  store.add(pack, values="int8")        # serialize + register
  store.register_file("a0.shpk")        # register an existing file (lazy)
  engine.register(store.get("a0"))      # or engine.register("a0")

Residency is hierarchical — three tiers, each LRU under its own budget:

  * **disk** — the registered ``.shpk`` files (unbounded; never dropped).
  * **host-RAM raw tier** (``budget_bytes``) — the resident form is
    whatever the file stores: f32 packs stay f32, int8 packs stay in
    their ~2-byte/entry ``QuantPack`` form, so an int8 store holds >=3x
    more tenants in the same budget. Loading past the budget evicts
    least-recently-used residents (files remain; a later ``get``
    reloads). Packs added with ``pin=True`` — or added in-memory with no
    backing file — are never evicted.
  * **host staging tier** (``staging_bytes``, optional) — decoded,
    upload-ready f32 ``AdapterPack`` buffers cached after the first
    dequant, playing the role of pinned host staging memory for the H2D
    path: a ``get`` that hits staging skips the dequant entirely. Purely
    derived data, so it is evictable regardless of pinning and disabled
    by default (``staging_bytes=None``).

The device-table tier lives in the engines (``MultiTenantEngine`` slot
tables, ``FusedLRU``); the store feeds it.

Async prefetch: ``prefetch(name)`` starts the disk load (and decode into
staging) on a small worker pool and returns a ``PrefetchHandle``
immediately, so serving engines can begin an adapter's load the moment
its request enters the admission queue and overlap it with in-flight
decode. Worker loads record ``prefetch.disk`` spans (the synchronous
path keeps the ``disk_load`` name, so the replay model can tell stall
from overlap); submits emit ``prefetch.hit`` / ``prefetch.miss``
instants and a ``store.inflight_bytes`` counter. While a handle is
outstanding its adapter is *pinned against eviction* (a refcount on
in-flight loads): LRU pressure from concurrent loads can never drop a
pack another request is about to consume. Duplicate prefetches of one
name share a single disk read.

Handles are immutable by contract: entries are jax/np arrays shared with
the store's resident copy; engines must never write into them (they never
do — loading is a scatter-add into the engine's own weights).

Thread-safety: all tier bookkeeping is guarded by one reentrant lock;
disk reads and dequants happen outside it. ``get``/``get_raw`` join an
in-flight load of the same name instead of issuing a second read.

Failure model (``runtime/faults.py``, full ladder in
``src/repro/runtime/README.md``): disk loads are retried with capped
exponential backoff (``load_retries`` x ``retry_backoff_s``); a pack
that exhausts its retries is **quarantined** — later ``get`` /
``get_raw`` / ``prefetch`` of that name fail fast with
``AdapterUnavailable`` until ``clear_quarantine`` — and the failed load
surfaces as a typed ``StoreError``. ``PrefetchHandle.result()`` never
leaks a raw worker exception (it wraps them in ``StoreError``) and
never strands the eviction pin: the pin is released on every terminal
path (success, worker failure, cancel) and kept only on ``result``
timeout, where the handle stays live.
"""
from __future__ import annotations

import os
import threading
import time
from collections import OrderedDict
from concurrent.futures import CancelledError, Future, ThreadPoolExecutor
from concurrent.futures import TimeoutError as FutTimeoutError
from typing import Dict, List, Optional, Union  # noqa: F401 (Union: annot.)

from repro.analysis import trace
from repro.core.adapters import AdapterPack
from repro.core.switching import split_version, versioned_id
from repro.hub.packio import (PackFormatError, QuantPack, load_pack,
                              peek_pack, quantize_pack, save_pack)
from repro.runtime import faults
from repro.runtime.faults import AdapterUnavailable, ServingError, StoreError


class PrefetchHandle:
    """An in-flight (or already-satisfied) adapter load.

    ``result()`` blocks until the load lands and returns the pack in the
    requested form; ``done()`` polls. ``cancel()`` abandons interest —
    the disk read is skipped if it has not started yet; either way the
    handle's eviction pin is dropped. Exactly one of ``result`` /
    ``cancel`` / ``release`` releases the pin (all are idempotent).

    ``cold`` records whether the adapter was non-resident at submit time
    — the benches use it to split TTFT into cold-miss vs hot lanes.
    """

    __slots__ = ("store", "name", "cold", "dequantize", "_fut", "_released")

    def __init__(self, store: "AdapterStore", name: str, cold: bool,
                 dequantize: bool, fut: Optional[Future]):
        self.store = store
        self.name = name
        self.cold = cold
        self.dequantize = dequantize
        self._fut = fut                    # None = was resident at submit
        self._released = False

    def done(self) -> bool:
        return self._fut is None or self._fut.done()

    def result(self, timeout: Optional[float] = None) \
            -> Union[AdapterPack, QuantPack]:
        """The loaded pack (raw form, or dequantized when the handle was
        created with ``dequantize=True``). Releases the eviction pin on
        every terminal outcome — success or failure — so a failed
        prefetch can never block eviction; a load failure surfaces as a
        typed ``StoreError`` (or the worker's own ``ServingError``),
        never the raw worker exception. The one non-terminal outcome is
        a ``timeout=`` expiry: the ``TimeoutError`` is re-raised with
        the pin still held and the handle stays usable."""
        if self._fut is not None:
            try:
                self._fut.result(timeout=timeout)
            except CancelledError:
                pass          # another handle's abort raced us; reload below
            except FutTimeoutError:
                raise         # still loading — keep the pin, handle lives on
            except ServingError:
                self.release()
                raise         # already typed (StoreError/AdapterUnavailable)
            except Exception as e:
                self.release()
                raise StoreError(f"prefetch of adapter {self.name!r} "
                                 f"failed: {e}", name=self.name) from e
        try:
            # re-read through the tiers so LRU recency is recorded and a
            # staged dequant is reused; the pin guarantees residency
            if self.dequantize:
                return self.store.get(self.name)
            return self.store.get_raw(self.name)
        finally:
            self.release()

    def cancel(self) -> bool:
        """Abandon the prefetch (request aborted). Returns True when the
        disk read was skipped entirely."""
        skipped = False
        if self._fut is not None and not self._released:
            skipped = self.store._cancel_inflight(self.name, self._fut)
        self.release()
        return skipped

    def release(self) -> None:
        """Drop the eviction pin without consuming the result."""
        if not self._released:
            self._released = True
            self.store._unpin_inflight(self.name)


class AdapterStore:
    def __init__(self, root: Optional[str] = None,
                 budget_bytes: Optional[int] = None,
                 staging_bytes: Optional[int] = None,
                 workers: int = 2,
                 load_retries: int = 2,
                 retry_backoff_s: float = 0.01):
        self.root = root
        if root is not None:
            os.makedirs(root, exist_ok=True)
        self.budget_bytes = budget_bytes
        self.staging_bytes = staging_bytes
        self.workers = max(int(workers), 1)
        self.load_retries = max(int(load_retries), 0)
        self.retry_backoff_s = retry_backoff_s
        self._paths: Dict[str, Optional[str]] = {}    # id -> file (None = mem)
        self._latest: Dict[str, int] = {}             # base name -> newest v
        self._pinned: set = set()
        # id -> resident AdapterPack | QuantPack, LRU order (oldest first)
        self._resident: "OrderedDict[str, Union[AdapterPack, QuantPack]]" \
            = OrderedDict()
        # id -> decoded f32 AdapterPack staging buffers, LRU order
        self._staging: "OrderedDict[str, AdapterPack]" = OrderedDict()
        self._lock = threading.RLock()
        self._pool: Optional[ThreadPoolExecutor] = None
        self._inflight: Dict[str, int] = {}           # eviction pins (refcnt)
        self._futs: Dict[str, Future] = {}            # dedup in-flight loads
        self._fut_est: Dict[str, int] = {}            # submit-time byte est.
        self._inflight_bytes = 0
        self._quarantined: Dict[str, str] = {}        # id -> failure reason
        self._shutdown = False
        self.loads = 0                                # disk loads (cache miss)
        self.evictions = 0
        self.staging_hits = 0
        self.prefetch_hits = 0                        # submit found resident
        self.prefetch_misses = 0                      # submit went to disk
        self.retries = 0                              # load attempts retried
        self.load_failures = 0                        # loads that quarantined

    # ------------------------------------------------------------------
    # Registration
    # ------------------------------------------------------------------

    def add(self, pack: AdapterPack, values: str = "f32",
            pin: bool = False) -> str:
        """Serialize ``pack`` into the store's root (or keep it in memory if
        the store has no root) and register it. Returns the adapter id."""
        if self.root is None:
            if values == "bf16":
                raise ValueError("bf16 pack storage needs a file-backed "
                                 "store (root=None holds f32 or int8)")
            form = quantize_pack(pack) if values == "int8" else pack
            with self._lock:
                self._paths[pack.name] = None
                self._pinned.add(pack.name)           # nothing to reload from
                self._note_version(pack.name)
                self._admit(pack.name, form)
            return pack.name
        path = os.path.join(self.root, f"{pack.name}.shpk")
        save_pack(pack, path, values=values)
        with self._lock:
            self._paths[pack.name] = path
            if pin:
                self._pinned.add(pack.name)
            self._note_version(pack.name)
            self._resident.pop(pack.name, None)       # re-add replaces
            self._staging.pop(pack.name, None)
        return pack.name

    def register_file(self, path: str, name: Optional[str] = None,
                      pin: bool = False) -> str:
        """Register an existing pack file without reading its payload."""
        name = name or peek_pack(path)["name"]
        with self._lock:
            self._paths[name] = path
            if pin:
                self._pinned.add(name)
            self._note_version(name)
            self._resident.pop(name, None)
            self._staging.pop(name, None)
        return name

    # ------------------------------------------------------------------
    # Versioned publish / newest-wins resolution
    # ------------------------------------------------------------------
    # The continuous-personalization loop republishes a retrained adapter
    # under the same logical name. Each publish gets a fresh immutable id
    # ``base@v`` (monotonic per base); lookups of the bare name resolve to
    # the newest version, while anything already holding a concrete
    # ``base@v`` id keeps reading exactly that version — which is how the
    # serving engines pin in-flight requests across a hot-swap.

    def _note_version(self, name: str) -> None:
        # caller holds self._lock
        base, v = split_version(name)
        if v is not None and v > self._latest.get(base, 0):
            self._latest[base] = v

    def publish(self, pack: AdapterPack, values: str = "f32",
                pin: bool = False) -> str:
        """Register ``pack`` as the next version of its (base) name.

        Returns the versioned id ``name@v``. A pack whose name is already
        versioned publishes the *next* version of its base name."""
        base, _ = split_version(pack.name)
        with self._lock:
            v = self._latest.get(base, 0) + 1
            self._latest[base] = v            # reserve against racing publish
        vid = versioned_id(base, v)
        self.add(AdapterPack(name=vid, entries=pack.entries,
                             alpha=pack.alpha), values=values, pin=pin)
        trace.instant("store.publish", cat="store", name=vid)
        return vid

    def resolve(self, name: str) -> str:
        """Newest-wins id resolution: a bare name with published versions
        resolves to ``name@latest``; versioned (or unversioned-only) names
        come back unchanged."""
        base, v = split_version(name)
        if v is not None:
            return name
        with self._lock:
            latest = self._latest.get(name)
        return versioned_id(name, latest) if latest else name

    def latest_version(self, base: str) -> Optional[int]:
        with self._lock:
            return self._latest.get(base)

    def versions(self, base: str) -> List[str]:
        """Registered versioned ids of ``base``, oldest first."""
        with self._lock:
            vs = [(v, n) for n in self._paths
                  for b, v in [split_version(n)] if b == base and v]
        return [n for _, n in sorted(vs)]

    def pin_use(self, name: str) -> str:
        """Refcounted eviction pin for a version an engine is serving from
        (the same pin prefetch handles use — ``evict`` refuses pinned
        packs). Returns the concrete id pinned; pass it to
        ``unpin_use`` when the last in-flight request drains."""
        name = self.resolve(name)
        self._pin_inflight(name)
        return name

    def unpin_use(self, name: str) -> None:
        self._unpin_inflight(name)

    # ------------------------------------------------------------------
    # Lookup
    # ------------------------------------------------------------------

    def names(self) -> List[str]:
        return sorted(self._paths)

    def __contains__(self, name: str) -> bool:
        return name in self._paths or name in self._latest

    def is_resident(self, name: str) -> bool:
        """Host-RAM-tier hit test (no LRU touch) — what the serving engines
        use to stamp a request cold/hot at submit time."""
        name = self.resolve(name)
        with self._lock:
            return name in self._resident

    def get(self, name: str) -> AdapterPack:
        """Immutable pack handle; loads from disk (and evicts LRU residents
        past the byte budget) on a miss. Quantized packs dequantize at
        this boundary — through the staging tier when one is configured.
        A bare name resolves newest-wins (see ``resolve``)."""
        name = self.resolve(name)
        with self._lock:
            staged = self._staging.get(name)
            if staged is not None:
                self._staging.move_to_end(name)
                self.staging_hits += 1
                # keep raw-tier recency in step so eviction order is sane
                if name in self._resident:
                    self._resident.move_to_end(name)
                return staged
        form = self.get_raw(name)
        if isinstance(form, QuantPack):
            return self._stage(name, form)
        return form

    def get_raw(self, name: str) -> Union[AdapterPack, QuantPack]:
        """The resident form as stored: an int8 pack comes back as its
        ``QuantPack`` (no f32 dequant round trip) — what
        ``MultiTenantEngine(table_dtype="int8")`` builds device tables
        from; f32/bf16 packs come back as plain ``AdapterPack``s. Same
        residency/LRU accounting as ``get``. Joins an in-flight prefetch
        of the same name instead of reading the file twice."""
        name = self.resolve(name)
        if name not in self._paths:
            raise KeyError(f"unknown adapter {name!r}; registered: "
                           f"{self.names()}")
        self._check_quarantine(name)
        with self._lock:
            form = self._resident.get(name)
            if form is not None:
                self._resident.move_to_end(name)
                return form
            fut = self._futs.get(name)
        if fut is not None:
            try:
                return fut.result()
            except (CancelledError, Exception):
                pass                  # cancelled/failed: fall through, reload
            with self._lock:
                form = self._resident.get(name)
                if form is not None:
                    self._resident.move_to_end(name)
                    return form
        # synchronous load; pin so concurrent worker admits can't evict
        # the pack between our _admit and the caller seeing it
        self._pin_inflight(name)
        try:
            return self._load(name, span="disk_load")
        finally:
            self._unpin_inflight(name)

    # ------------------------------------------------------------------
    # Async prefetch
    # ------------------------------------------------------------------

    def prefetch(self, name: str, dequantize: bool = False) \
            -> PrefetchHandle:
        """Start loading ``name`` in the background; returns immediately.

        If the pack is already resident this is a hit: the handle is
        already done and ``result()`` is instant. Otherwise the disk
        read (+ decode into staging, when ``dequantize`` and a staging
        tier exist) runs on the store's worker pool, recorded as a
        ``prefetch.disk`` span on that worker's tid. The adapter is
        pinned against eviction until the handle is released."""
        name = self.resolve(name)
        if name not in self._paths:
            raise KeyError(f"unknown adapter {name!r}; registered: "
                           f"{self.names()}")
        self._check_quarantine(name)
        with self._lock:
            self._pin_inflight(name)
            if name in self._resident:
                self._resident.move_to_end(name)
                self.prefetch_hits += 1
                trace.instant("prefetch.hit", cat="store", name=name)
                return PrefetchHandle(self, name, cold=False,
                                      dequantize=dequantize, fut=None)
            self.prefetch_misses += 1
            trace.instant("prefetch.miss", cat="store", name=name)
            fut = self._futs.get(name)
            if fut is None and not self._shutdown:
                path = self._paths[name]
                assert path is not None, f"in-memory pack {name!r} lost"
                try:
                    est = os.path.getsize(path)
                except OSError:
                    est = 0
                self._inflight_bytes += est
                self._fut_est[name] = est
                trace.counter("store.inflight_bytes", self._inflight_bytes,
                              cat="store")
                if self._pool is None:
                    self._pool = ThreadPoolExecutor(
                        max_workers=self.workers,
                        thread_name_prefix="shira-prefetch")
                fut = self._pool.submit(self._prefetch_job, name,
                                        dequantize, est)
                self._futs[name] = fut
            # a shut-down store hands back a workerless handle: fut=None
            # makes result() load synchronously through the same tiers
            return PrefetchHandle(self, name, cold=True,
                                  dequantize=dequantize, fut=fut)

    def _prefetch_job(self, name: str, dequantize: bool, est: int):
        try:
            faults.on_worker(name)
            form = self._load(name, span="prefetch.disk")
            if dequantize and isinstance(form, QuantPack):
                self._stage(name, form, span="prefetch.decode")
            return form
        finally:
            with self._lock:
                self._futs.pop(name, None)
                self._fut_est.pop(name, None)
                self._inflight_bytes -= est
                trace.counter("store.inflight_bytes", self._inflight_bytes,
                              cat="store")

    def _cancel_inflight(self, name: str, fut: Future) -> bool:
        """Try to cancel a not-yet-started load. Only succeeds when this
        is the load's sole outstanding pin (other handles sharing the
        future keep it alive); cleans up the dedup/byte bookkeeping the
        skipped job would have."""
        with self._lock:
            if self._inflight.get(name, 0) > 1:
                return False          # someone else still wants this load
            if self._futs.get(name) is not fut or not fut.cancel():
                return False
            self._futs.pop(name, None)
            est = self._fut_est.pop(name, 0)
            self._inflight_bytes -= est
            trace.counter("store.inflight_bytes", self._inflight_bytes,
                          cat="store")
            return True

    def shutdown(self, wait: bool = True) -> None:
        """Retire the prefetch worker pool — deterministic and idempotent.

        ``wait=True`` drains: every submitted load runs to completion
        before this returns. ``wait=False`` cancels every load that has
        not started (cleaning up its dedup entry and in-flight byte
        estimate under the lock, so no bookkeeping is stranded by a job
        that will never run) and leaves already-running loads to finish
        on the pool's threads. Either way no new pool is ever created
        afterwards: later ``prefetch`` calls return workerless handles
        that load synchronously on ``result()``, and a concurrent
        ``PrefetchHandle.cancel()`` racing this teardown settles on one
        of the two deterministic outcomes (job cancelled here with its
        books balanced, or job runs and the handle's pin is released by
        the normal terminal path). Eviction pins are owned by handles
        and engine version-pins, never by the pool, so shutdown itself
        can never strand a refcount."""
        with self._lock:
            self._shutdown = True
            pool, self._pool = self._pool, None
            if not wait:
                for name, fut in list(self._futs.items()):
                    if fut.cancel():
                        self._futs.pop(name, None)
                        est = self._fut_est.pop(name, 0)
                        self._inflight_bytes -= est
                        trace.counter("store.inflight_bytes",
                                      self._inflight_bytes, cat="store")
        if pool is not None:
            pool.shutdown(wait=wait)

    # ------------------------------------------------------------------
    # Residency accounting
    # ------------------------------------------------------------------

    def resident_bytes(self) -> int:
        with self._lock:
            return sum(f.nbytes() for f in self._resident.values())

    def resident_names(self) -> List[str]:
        """LRU order, oldest first."""
        with self._lock:
            return list(self._resident)

    def staged_bytes(self) -> int:
        with self._lock:
            return sum(p.nbytes() for p in self._staging.values())

    def staged_names(self) -> List[str]:
        with self._lock:
            return list(self._staging)

    def inflight_names(self) -> List[str]:
        """Adapters currently pinned by outstanding loads/handles."""
        with self._lock:
            return sorted(self._inflight)

    def _pin_inflight(self, name: str) -> None:
        with self._lock:
            self._inflight[name] = self._inflight.get(name, 0) + 1

    def _unpin_inflight(self, name: str) -> None:
        with self._lock:
            n = self._inflight.get(name, 0) - 1
            if n <= 0:
                self._inflight.pop(name, None)
            else:
                self._inflight[name] = n

    def _load(self, name: str, span: str) -> Union[AdapterPack, QuantPack]:
        """One disk load through the degradation ladder: retried with
        capped exponential backoff on I/O / format errors, quarantined
        (then ``StoreError``) once retries are exhausted."""
        self._check_quarantine(name)
        path = self._paths[name]
        assert path is not None, f"in-memory pack {name!r} lost"
        last: Optional[Exception] = None
        for attempt in range(self.load_retries + 1):
            if attempt:
                with self._lock:
                    self.retries += 1
                trace.instant("store.retry", cat="store", name=name,
                              attempt=attempt)
                time.sleep(min(self.retry_backoff_s * (2 ** (attempt - 1)),
                               0.25))
            try:
                with trace.span(span, cat="store", name=name) as sp:
                    faults.on_disk_read(name)
                    form = load_pack(path, dequantize=False)
                    sp.set(bytes=form.nbytes())
                break
            except (OSError, PackFormatError) as e:
                last = e
        else:
            with self._lock:
                self.load_failures += 1
            self.quarantine(name, reason=str(last))
            raise StoreError(
                f"failed to load adapter {name!r} after "
                f"{self.load_retries + 1} attempts: {last}",
                name=name) from last
        with self._lock:
            self.loads += 1
            self._admit(name, form)
        return form

    # ------------------------------------------------------------------
    # Quarantine (degradation ladder: retry -> quarantine -> fail fast)
    # ------------------------------------------------------------------

    def _check_quarantine(self, name: str) -> None:
        with self._lock:
            reason = self._quarantined.get(name)
        if reason is not None:
            raise AdapterUnavailable(
                f"adapter {name!r} is quarantined ({reason}); "
                f"clear_quarantine() to retry", name=name)

    def quarantine(self, name: str, reason: str = "manual") -> None:
        """Mark ``name`` unservable: resident/staged forms are dropped and
        every later load fails fast with ``AdapterUnavailable`` until
        ``clear_quarantine``. Called automatically when a load exhausts
        its retries."""
        name = self.resolve(name)
        with self._lock:
            self._quarantined[name] = reason
            self._resident.pop(name, None)
            self._staging.pop(name, None)
        trace.instant("store.quarantine", cat="store", name=name,
                      reason=reason)

    def clear_quarantine(self, name: str) -> bool:
        """Re-admit a quarantined pack (e.g. after the file was repaired).
        Returns True when the name was quarantined."""
        name = self.resolve(name)
        with self._lock:
            return self._quarantined.pop(name, None) is not None

    def quarantined(self) -> List[str]:
        with self._lock:
            return sorted(self._quarantined)

    def _stage(self, name: str, form: QuantPack,
               span: str = "dequant") -> AdapterPack:
        """Dequantize through the staging tier (cache when configured)."""
        with self._lock:
            staged = self._staging.get(name)
            if staged is not None:
                self._staging.move_to_end(name)
                self.staging_hits += 1
                return staged
        with trace.span(span, cat="store", name=name):
            pack = form.dequantize()
        if self.staging_bytes is None:
            return pack
        with self._lock:
            self._staging[name] = pack
            self._staging.move_to_end(name)
            while self.staged_bytes() > self.staging_bytes:
                victim = next((n for n in self._staging
                               if n != name and n not in self._inflight),
                              None)
                if victim is None:
                    break
                del self._staging[victim]
                trace.instant("store.stage_evict", cat="store", name=victim)
        return pack

    def _admit(self, name: str, form) -> None:
        with self._lock:
            self._resident[name] = form
            self._resident.move_to_end(name)
            if self.budget_bytes is None:
                return
            while self.resident_bytes() > self.budget_bytes:
                # never evict the newcomer, pinned packs, or packs with an
                # in-flight load/handle (a racing prefetch's result must
                # stay resident until its handle is consumed)
                victim = next((n for n in self._resident
                               if n != name and n not in self._pinned
                               and n not in self._inflight), None)
                if victim is None:
                    break        # only newcomer/pinned/in-flight left: keep
                del self._resident[victim]
                self._staging.pop(victim, None)
                self.evictions += 1
                trace.instant("store.evict", cat="store", name=victim)

    def evict(self, name: str) -> bool:
        """Drop a resident form explicitly (the file stays registered).
        Refused while the adapter has an in-flight load or handle."""
        name = self.resolve(name)
        with self._lock:
            if (name in self._resident
                    and self._paths.get(name) is not None
                    and name not in self._inflight):
                del self._resident[name]
                self._staging.pop(name, None)
                self.evictions += 1
                return True
            return False
