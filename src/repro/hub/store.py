"""AdapterStore: the adapter registry every engine loads through.

A store maps adapter ids to pack files on disk (format v2, ``packio``) and
keeps a bounded working set resident in memory. Engines never open files
themselves — ``SwitchEngine``, ``MultiTenantEngine``, and the benchmarks ask
the store by name and get back an immutable ``AdapterPack`` handle:

  store = AdapterStore(root, budget_bytes=64 << 20)
  store.add(pack, values="int8")        # serialize + register
  store.register_file("a0.shpk")        # register an existing file (lazy)
  engine.register(store.get("a0"))      # or engine.register("a0")

Residency: the resident form is whatever the file stores — f32 packs stay
f32, int8 packs stay in their ~2-byte/entry ``QuantPack`` form and are only
dequantized at the ``get`` boundary, so an int8 store holds >=3x more
tenants in the same budget. When loading a pack would exceed
``budget_bytes``, least-recently-used residents are dropped (their files
remain; a later ``get`` reloads). Packs added with ``pin=True`` — or added
in-memory with no backing file — are never evicted.

Handles are immutable by contract: entries are jax/np arrays shared with
the store's resident copy; engines must never write into them (they never
do — loading is a scatter-add into the engine's own weights).
"""
from __future__ import annotations

import os
from collections import OrderedDict
from typing import Dict, List, Optional, Union  # noqa: F401 (Union: annot.)

from repro.analysis import trace
from repro.core.adapters import AdapterPack
from repro.hub.packio import (QuantPack, load_pack, peek_pack,
                              quantize_pack, save_pack)


class AdapterStore:
    def __init__(self, root: Optional[str] = None,
                 budget_bytes: Optional[int] = None):
        self.root = root
        if root is not None:
            os.makedirs(root, exist_ok=True)
        self.budget_bytes = budget_bytes
        self._paths: Dict[str, Optional[str]] = {}    # id -> file (None = mem)
        self._pinned: set = set()
        # id -> resident AdapterPack | QuantPack, LRU order (oldest first)
        self._resident: "OrderedDict[str, Union[AdapterPack, QuantPack]]" \
            = OrderedDict()
        self.loads = 0                                # disk loads (cache miss)
        self.evictions = 0

    # ------------------------------------------------------------------
    # Registration
    # ------------------------------------------------------------------

    def add(self, pack: AdapterPack, values: str = "f32",
            pin: bool = False) -> str:
        """Serialize ``pack`` into the store's root (or keep it in memory if
        the store has no root) and register it. Returns the adapter id."""
        if self.root is None:
            if values == "bf16":
                raise ValueError("bf16 pack storage needs a file-backed "
                                 "store (root=None holds f32 or int8)")
            form = quantize_pack(pack) if values == "int8" else pack
            self._paths[pack.name] = None
            self._pinned.add(pack.name)               # nothing to reload from
            self._admit(pack.name, form)
            return pack.name
        path = os.path.join(self.root, f"{pack.name}.shpk")
        save_pack(pack, path, values=values)
        self._paths[pack.name] = path
        if pin:
            self._pinned.add(pack.name)
        self._resident.pop(pack.name, None)           # re-add replaces
        return pack.name

    def register_file(self, path: str, name: Optional[str] = None,
                      pin: bool = False) -> str:
        """Register an existing pack file without reading its payload."""
        name = name or peek_pack(path)["name"]
        self._paths[name] = path
        if pin:
            self._pinned.add(name)
        self._resident.pop(name, None)
        return name

    # ------------------------------------------------------------------
    # Lookup
    # ------------------------------------------------------------------

    def names(self) -> List[str]:
        return sorted(self._paths)

    def __contains__(self, name: str) -> bool:
        return name in self._paths

    def get(self, name: str) -> AdapterPack:
        """Immutable pack handle; loads from disk (and evicts LRU residents
        past the byte budget) on a miss."""
        form = self.get_raw(name)
        return form.dequantize() if isinstance(form, QuantPack) else form

    def get_raw(self, name: str) -> Union[AdapterPack, QuantPack]:
        """The resident form as stored: an int8 pack comes back as its
        ``QuantPack`` (no f32 dequant round trip) — what
        ``MultiTenantEngine(table_dtype="int8")`` builds device tables
        from; f32/bf16 packs come back as plain ``AdapterPack``s. Same
        residency/LRU accounting as ``get``."""
        if name not in self._paths:
            raise KeyError(f"unknown adapter {name!r}; registered: "
                           f"{self.names()}")
        form = self._resident.get(name)
        if form is None:
            path = self._paths[name]
            assert path is not None, f"in-memory pack {name!r} lost"
            with trace.span("disk_load", cat="store", name=name) as sp:
                form = load_pack(path, dequantize=False)
                sp.set(bytes=form.nbytes())
            self.loads += 1
            self._admit(name, form)
        else:
            self._resident.move_to_end(name)
        return form

    # ------------------------------------------------------------------
    # Residency accounting
    # ------------------------------------------------------------------

    def resident_bytes(self) -> int:
        return sum(f.nbytes() for f in self._resident.values())

    def resident_names(self) -> List[str]:
        """LRU order, oldest first."""
        return list(self._resident)

    def _admit(self, name: str, form) -> None:
        self._resident[name] = form
        self._resident.move_to_end(name)
        if self.budget_bytes is None:
            return
        while self.resident_bytes() > self.budget_bytes:
            victim = next((n for n in self._resident
                           if n != name and n not in self._pinned), None)
            if victim is None:
                break            # only the newcomer/pinned left: keep it
            del self._resident[victim]
            self.evictions += 1
            trace.instant("store.evict", cat="store", name=victim)

    def evict(self, name: str) -> bool:
        """Drop a resident form explicitly (the file stays registered)."""
        if name in self._resident and self._paths.get(name) is not None:
            del self._resident[name]
            self.evictions += 1
            return True
        return False
