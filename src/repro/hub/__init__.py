"""repro.hub — one adapter-lifecycle API (train -> pack -> store -> serve).

The paper's deployment story (§3.2, Fig. 3) is that a SHiRA adapter is a
cheap artifact: 1-2% of the weights you can load, fuse, and switch at will.
This package is the single surface that story runs through:

                 train (core.init_adapter / materialize)
                     |
                     v  pack_from_shira / pack_from_delta
               AdapterPack  ----------------------------------+
                     |                                        |
        save_pack    |   load_pack (f32 bit-exact /           |
        (format v2,  |    bf16 / int8 ~2 B per nonzero)       |
         checksum)   v                                        |
               .shpk file on disk                             |
                     |                                        |
                     v  register_file / add                   |
               AdapterStore  (LRU residency under a           |
                     |        byte budget; immutable handles) |
                     v                                        v
        +------------+--------------+------------------+
        |                           |                  |
   SwitchEngine.load("id")   MultiTenantEngine    ServingEngine.submit(
   (rapid switch: sparse     .register("id")       prompt, adapter) -> future
    scatter, paper Fig. 5)   (batched side-       (continuous batching:
        |                     deltas, FusedLRU     per-slot adapter ids +
        v                     group fuse/demote)   positions, slot recycling
   fused inference                 |               on EOS)
                                   +------ one shared base + Pallas
                                           ``sidedelta`` forward ------+

Everything downstream of ``AdapterPack`` also accepts adapter *ids*: attach
an ``AdapterStore`` and the engines resolve names to resident packs on
demand, so a fleet of thousands of tenants pays only for its working set
(int8 packs keep >=3x more tenants resident in the same budget).
"""
from repro.hub.packio import (PackFormatError, QuantPack,  # noqa: F401
                              load_pack, peek_pack, save_pack)
from repro.hub.serving import (PagedServingEngine, ServeFuture,  # noqa: F401
                               ServingEngine)
from repro.hub.store import AdapterStore, PrefetchHandle  # noqa: F401
# the serving failure taxonomy (see src/repro/runtime/README.md) — what
# ServeFuture.result() raises and the store's degradation ladder emits
from repro.runtime.faults import (AdapterUnavailable, RequestShed,  # noqa: F401
                                  ServingError, SlotPoisoned, StoreError,
                                  TableBuildError)
