"""ServingEngine: request-level serving with continuous batching.

The fixed-batch multi-tenant path (``MultiTenantEngine.generate``) decodes a
*batch* as one unit: every request enters at step 0 and the whole batch runs
until the longest request finishes. This engine serves *requests*:

  fut = engine.submit(prompt_tokens, adapter="a0", max_tokens=32)
  engine.run()                 # or step() from your own loop
  out = fut.result()           # (n,) int32 generated tokens

Internally there are ``slots`` decode lanes sharing ONE jitted decode step
and one cache allocation. Each slot carries its own adapter id (routed
through the MultiTenantEngine side-delta tables — an adapter name, an
adapter stack, or base) and its own cache position: the decode step takes a
(B,) position vector (``models.attention`` per-slot decode), so lanes at
different depths coexist in one forward pass. When a request hits EOS or
its token budget, its future resolves and the slot is recycled to the next
queued request at the following step — no drain barrier, which is what
keeps utilization high under mixed-length traffic.

Admission runs the request's prefill at batch 1 with its own adapter and
splices the resulting KV/SSM cache into the slot's lane of the shared cache
(``dynamic_update_slice`` along the batch axis). Greedy decode is used
throughout, so a request's tokens are identical to what the fixed-batch
engine produces for the same prompt+adapter — the parity tests pin this
token-for-token.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.switching import FusedLRU, Tenant, normalize_tenant
from repro.models import lm
from repro.serving.multitenant import MultiTenantEngine


class ServeFuture:
    """Resolves when the request's final token is generated."""

    def __init__(self, rid: int, adapter: Tenant, max_tokens: int):
        self.rid = rid
        self.adapter = adapter
        self.max_tokens = max_tokens
        self.tokens: List[int] = []
        self.submitted_step: Optional[int] = None
        self.finished_step: Optional[int] = None
        self._done = False

    def done(self) -> bool:
        return self._done

    def result(self) -> np.ndarray:
        if not self._done:
            raise RuntimeError(f"request {self.rid} still in flight "
                               f"({len(self.tokens)}/{self.max_tokens} tokens)"
                               " — drive the engine with step()/run()")
        return np.asarray(self.tokens, np.int32)


class _Pending:
    def __init__(self, fut: ServeFuture, prompt: np.ndarray,
                 eos_id: Optional[int]):
        self.fut = fut
        self.prompt = prompt
        self.eos_id = eos_id


def _slot_insert(big, small, slot: int):
    """Splice a batch-1 cache tree into lane ``slot`` of the shared cache.

    The batch axis differs per leaf kind (KV caches carry scan-stack dims in
    front, hybrid mamba caches two of them) — it is recovered per leaf as
    the unique axis where the shapes differ (1 vs slots)."""
    def leaf(bg, sm):
        diff = [ax for ax, (a, b) in enumerate(zip(bg.shape, sm.shape))
                if a != b]
        if not diff:          # slots == 1: the lane IS the whole cache
            return sm.astype(bg.dtype)
        assert len(diff) == 1, (bg.shape, sm.shape)
        return jax.lax.dynamic_update_slice_in_dim(
            bg, sm.astype(bg.dtype), slot, axis=diff[0])
    return jax.tree.map(leaf, big, small)


class ServingEngine:
    """Continuous-batching front end over the multi-tenant side-delta path."""

    def __init__(self, cfg, params, *, slots: int = 4, cache_size: int = 128,
                 scheduler: Optional[FusedLRU] = None, store=None,
                 table_dtype: str = "f32",
                 interpret: Optional[bool] = None):
        if cfg.encoder_only:
            raise ValueError("encoder-only archs have no decode serving path")
        self.cfg = cfg
        self.slots = slots
        # the batch-axis splice recovers the lane axis as "the axis whose
        # size differs"; cache_size == slots would make it ambiguous
        self.cache_size = cache_size + 1 if cache_size == slots else cache_size
        self.engine = MultiTenantEngine(cfg, params, scheduler=scheduler,
                                        store=store, table_dtype=table_dtype,
                                        interpret=interpret)
        self.caches = lm.init_cache(cfg, slots, self.cache_size)
        self._active: List[Optional[_Pending]] = [None] * slots
        self._pos = np.zeros((slots,), np.int32)      # next cache write index
        self._last = np.zeros((slots,), np.int32)     # last generated token
        self._queue: "deque[_Pending]" = deque()
        self._rid = 0
        self.step_count = 0
        self.tokens_out = 0
        self.decode_slot_waste = 0    # idle-lane decode steps (utilization)

    # ------------------------------------------------------------------
    # Request API
    # ------------------------------------------------------------------

    def register(self, pack) -> None:
        self.engine.register(pack)

    def submit(self, prompt_tokens, adapter: Tenant = None,
               max_tokens: int = 16,
               eos_id: Optional[int] = None) -> ServeFuture:
        """Queue one request; returns its future. ``adapter`` is a registered
        adapter id, a stack of ids, or None for the base model."""
        prompt = np.asarray(prompt_tokens, np.int32).reshape(-1)
        prefix = (self.cfg.num_prefix_embeds
                  if self.cfg.modality == "vision" else 0)
        need = prompt.shape[0] + prefix + max_tokens
        if need > self.cache_size:
            raise ValueError(f"prompt ({prompt.shape[0]}) + max_tokens "
                             f"({max_tokens}) needs {need} cache slots, "
                             f"engine has {self.cache_size}")
        if max_tokens < 1:
            raise ValueError("max_tokens must be >= 1")
        adapter = normalize_tenant(adapter)
        from repro.core.switching import tenant_members
        for m in tenant_members(adapter):
            if m not in self.engine.packs:
                store = self.engine.store
                if store is not None and m in store:
                    self.engine.register(m)   # lazy: pull it from the store
                else:
                    raise KeyError(f"request names unregistered adapter "
                                   f"{m!r}")
        fut = ServeFuture(self._rid, adapter, max_tokens)
        self._rid += 1
        self._queue.append(_Pending(fut, prompt, eos_id))
        return fut

    def pending(self) -> int:
        return len(self._queue) + sum(p is not None for p in self._active)

    # ------------------------------------------------------------------
    # Scheduling loop
    # ------------------------------------------------------------------

    def _batch_for(self, prompt: np.ndarray) -> Dict[str, Any]:
        batch = {"tokens": jnp.asarray(prompt[None])}
        if self.cfg.modality == "vision":
            batch["patch_embeds"] = jnp.zeros(
                (1, self.cfg.num_prefix_embeds, self.cfg.d_model))
        return batch

    def _finish(self, slot: int) -> None:
        p = self._active[slot]
        p.fut.finished_step = self.step_count
        p.fut._done = True
        self._active[slot] = None
        self._pos[slot] = 0
        self._last[slot] = 0

    def _emit(self, slot: int, token: int) -> None:
        """Record one generated token. ``_pos`` is NOT touched here — it
        always points at the cache index the next decode step writes to."""
        p = self._active[slot]
        p.fut.tokens.append(int(token))
        self.tokens_out += 1
        self._last[slot] = token
        if (len(p.fut.tokens) >= p.fut.max_tokens
                or (p.eos_id is not None and int(token) == p.eos_id)):
            self._finish(slot)

    def _admit(self, slot: int, p: _Pending) -> None:
        names: List[Tenant] = [p.fut.adapter]
        ids = self.engine.ids_for(names)
        wp = self.engine.wrapped_params(ids)
        logits, c1 = self.engine._prefill(wp, self._batch_for(p.prompt),
                                          self.cache_size)
        self.caches = _slot_insert(self.caches, c1, slot)
        prefix = (self.cfg.num_prefix_embeds
                  if self.cfg.modality == "vision" else 0)
        self._active[slot] = p
        p.fut.submitted_step = self.step_count
        self._pos[slot] = p.prompt.shape[0] + prefix
        first = int(np.argmax(np.asarray(logits[0])))
        self._emit(slot, first)

    def step(self) -> bool:
        """Admit queued requests into free slots, then run one decode step
        over every occupied lane. Returns False when fully drained."""
        for slot in range(self.slots):
            if self._active[slot] is None and self._queue:
                self._admit(slot, self._queue.popleft())
        live = [s for s in range(self.slots) if self._active[s] is not None]
        if not live:
            return bool(self._queue)
        self.step_count += 1
        self.decode_slot_waste += self.slots - len(live)
        names = [self._active[s].fut.adapter
                 if self._active[s] is not None else None
                 for s in range(self.slots)]
        # the scheduler sees only live lanes: idle slots are not base-model
        # traffic, and counting them would dilute every tenant's share
        self.engine.schedule([names[s] for s in live])
        ids = self.engine.ids_for(names)
        wp = self.engine.wrapped_params(ids)
        toks = jnp.asarray(self._last[:, None])
        logits, self.caches = self.engine._decode(
            wp, toks, self.caches, jnp.asarray(self._pos))
        nxt = np.asarray(jnp.argmax(logits, -1), np.int32)
        for s in live:
            self._pos[s] += 1          # this step's KV landed at _pos[s]
            self._emit(s, int(nxt[s]))
        return True

    def run(self, max_steps: int = 100_000) -> float:
        """Drive step() until every queued request resolved; returns
        wall-clock seconds."""
        t0 = time.perf_counter()
        for _ in range(max_steps):
            if not self.step() and not self._queue \
                    and all(p is None for p in self._active):
                break
        else:
            raise RuntimeError(f"run() hit max_steps={max_steps} with "
                               f"{self.pending()} requests in flight")
        return time.perf_counter() - t0
