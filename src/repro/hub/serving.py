"""Request-level serving: continuous batching over lanes or over pages.

Two engines share one request API (``submit`` -> ``ServeFuture``,
``step()``/``run()`` drive the loop; greedy decode; per-request adapters
routed through ``MultiTenantEngine``'s side-delta tables):

**ServingEngine** — the lane engine. ``slots`` decode lanes share one
jitted decode step and one contiguous cache allocation; every lane owns a
full ``cache_size`` KV stripe. Admission prefloods the request at batch 1
and splices the resulting cache into the lane's batch row — the splice uses
explicit per-leaf batch-axis metadata from ``lm.cache_batch_axes`` (KV
leaves carry scan-stack dims in front of batch; hybrid mamba leaves two of
them), never shape inference. Capacity is the number of free *lanes*: a
lane is busy for a request's whole lifetime even though a short request
uses a sliver of its stripe. That stranded memory is what the paged engine
removes.

**PagedServingEngine** — the paged engine (dense/moe text models). KV
memory is one global page pool per layer stack (``lm.init_paged_cache``;
optionally int8 ``QuantKV`` pages) and each request owns a *block table*
mapping logical KV blocks to physical pages, so resident bytes track
actual tokens, not worst-case stripes:

  - **Admission is gated on free pages, not free lanes**: a request enters
    when ``PagePool.can_alloc`` covers its page budget (prompt +
    max_tokens - 1 rounded up to pages, plus COW reserve); otherwise it
    waits FIFO. A slot is just a row in the batched decode step.
  - **Prefix sharing (COW)**: prompt prefixes are hashed per page boundary
    (salted by the request's adapter stack — identical tokens under
    different adapters produce different KV) into the pool's registry
    after prefill; a later request with the same adapter and prefix maps
    the shared pages into its table instead of recomputing them. Shared pages (refcount > 1) are immutable — the engine resolves
    every write range with ``_ensure_writable``, copying a shared page to a
    fresh one (``copy_page``) before the first divergent write. Cold
    registry entries are evicted LRU when the free list runs dry.
  - **Chunked prefill**: prompts prefill in fixed ``chunk_size`` slices,
    one chunk per engine step, interleaved with the decode pass over live
    lanes — a long prompt never stalls live decode by more than one step.
    Chunks are padded to a single static shape (one jit trace); padding
    rows write to the pinned scratch page 0 and are masked out of
    attention.

Greedy decode throughout, so both engines are token-for-token identical to
the fixed-batch engine for the same prompt+adapter (pinned by the parity
tests, including through shared-prefix admission).

**Async adapter prefetch** (``async_prefetch=True``, both engines): a cold
request's adapter starts loading the moment it enters the admission queue
— the disk read on the store's prefetch workers (``prefetch.disk`` spans),
the device-table build + H2D upload on the engine's build worker
(``prefetch.h2d``), both overlapping the in-flight decode steps. Admission
is FIFO-gated on the prefetch landing; hot tenants keep decoding off the
previous tables (``MultiTenantEngine.ids_covered``) while a cold rebuild
is in flight, and FusedLRU transitions are deferred until their
post-transition tables are built in the background (``schedule(defer=)``).
When there is no live decode to hide behind, the engine blocks on the head
request (``prefetch.stall`` spans — the cost async could not hide;
``replay.verify_overlap`` reports the fraction it did). Per-request token
output is identical on every path — same prefill/decode math, same builder
— and with the flag off (default) the engines are byte-for-byte the old
synchronous code path.
"""
from __future__ import annotations

import functools
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import trace
from repro.core.switching import (FusedLRU, Tenant, normalize_tenant,
                                  prior_version, split_version,
                                  tenant_members)
from repro.models import lm
from repro.runtime import faults
from repro.runtime.faults import (AdapterUnavailable, EngineWatchdog,
                                  RequestShed, ServingError, SlotPoisoned,
                                  StoreError, TableBuildError)
from repro.serving.multitenant import MultiTenantEngine

_NO_FALLBACK = object()     # degradation ladder exhausted (sentinel)


class ServeFuture:
    """Resolves when the request's final token is generated — or fails
    with the request's *typed* terminal error (``runtime/faults.py``
    taxonomy: ``RequestShed`` when admission shed it, ``SlotPoisoned``
    when its decode slot was quarantined, ``StoreError`` /
    ``AdapterUnavailable`` when its adapter could not be served and the
    fallback ladder was exhausted). ``degraded`` marks a request the
    ladder downgraded (previous version or base model);
    ``degraded_from`` records what it originally resolved to."""

    def __init__(self, rid: int, adapter: Tenant, max_tokens: int):
        self.rid = rid
        self.adapter = adapter
        self.max_tokens = max_tokens
        self.tokens: List[int] = []
        self.submitted_step: Optional[int] = None
        self.finished_step: Optional[int] = None
        self.submit_time: Optional[float] = None
        self.finish_time: Optional[float] = None
        self.deadline_s: Optional[float] = None  # queue-time budget (shed)
        self.ttft: Optional[float] = None     # seconds to first token
        self.first_token_step: Optional[int] = None
        self.cold = False     # adapter needed a disk load at submit time
        self.cancelled = False
        self.error: Optional[Exception] = None   # typed terminal failure
        self.degraded = False                    # served below what was asked
        self.degraded_from: Optional[Tenant] = None
        self._done = False
        self._event = threading.Event()

    def done(self) -> bool:
        return self._done

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        """The generated tokens. A shed/cancelled/poisoned/failed request
        raises its typed terminal error instead of pretending to be in
        flight. ``timeout=`` waits (bounded) for a terminal state —
        useful when another thread drives the engine; the default stays
        non-blocking, exactly the old contract."""
        if timeout is not None and not self._done:
            self._event.wait(timeout)
        if self.error is not None:
            raise self.error
        if self.cancelled:
            raise RuntimeError(f"request {self.rid} was cancelled")
        if not self._done:
            raise RuntimeError(f"request {self.rid} still in flight "
                               f"({len(self.tokens)}/{self.max_tokens} tokens)"
                               " — drive the engine with step()/run()")
        return np.asarray(self.tokens, np.int32)


class _Pending:
    def __init__(self, fut: ServeFuture, prompt: np.ndarray,
                 eos_id: Optional[int], handles=None):
        self.fut = fut
        self.prompt = prompt
        self.eos_id = eos_id
        self.handles = handles or []   # in-flight store prefetches


def _slot_insert(big, small, slot: int, axes):
    """Splice a batch-1 cache tree into lane ``slot`` of the shared cache.

    ``axes`` is the matching pytree of per-leaf batch-axis indices from
    ``lm.cache_batch_axes`` — KV leaves carry scan-stack dims in front of
    batch and hybrid mamba leaves carry two, so the axis is metadata, not
    something to infer from shapes (which is ambiguous whenever any other
    dim equals ``slots``)."""
    def leaf(bg, sm, ax):
        return jax.lax.dynamic_update_slice_in_dim(
            bg, sm.astype(bg.dtype), slot, axis=ax)
    return jax.tree.map(leaf, big, small, axes)


def _prefix_salt(adapter: Tenant) -> bytes:
    """Prefix-registry namespace for one request's adapter stack. KV pages
    hold the output of the forward pass that wrote them, so identical
    tokens under different adapters must never share pages."""
    return repr(adapter).encode()


def _resolve_adapter(engine: MultiTenantEngine, adapter: Tenant) -> Tenant:
    """Normalize + validate a request's tenant, lazily pulling members from
    the attached AdapterStore. Bare names resolve to the store's newest
    published version first (``engine.resolve``), so the returned tenant
    holds concrete ``name@v`` ids."""
    adapter = normalize_tenant(engine.resolve(normalize_tenant(adapter)))
    for m in tenant_members(adapter):
        if m not in engine.packs:
            store = engine.store
            if store is not None and m in store:
                engine.register(m)       # lazy: pull it from the store
            else:
                raise KeyError(f"request names unregistered adapter {m!r}")
    return adapter


class _EngineCommon:
    """Request bookkeeping shared by the lane and paged engines."""

    async_prefetch = False    # overridden per instance by the constructors

    def register(self, pack) -> None:
        self.engine.register(pack)

    # -- fault tolerance ------------------------------------------------
    #
    # The degradation ladder (full writeup: src/repro/runtime/README.md).
    # Store-level failures (StoreError after retries, AdapterUnavailable
    # for a quarantined pack) are handled *per request*: the fallback
    # policy walks the request down to the last-good published version
    # (``fallback="previous"``: name@v -> name@v-1 -> ... -> base) or
    # straight to the base model (``"base"``; adapter stacks always fall
    # straight to base — a partially-applied stack is a different model,
    # not a degraded one). A downgraded request is flagged
    # ``fut.degraded`` and keeps decoding; only a request the ladder
    # cannot place fails, with its typed error on the future. Admission
    # failures (bounded queue, queue deadline) shed with ``RequestShed``
    # — never a silent drop. ``nan_guard`` (off by default: the guard
    # reads logits back, which the pre-PR hot path never did) quarantines
    # a slot whose logits go non-finite while the rest of the batch keeps
    # decoding.

    def _init_faults(self, max_queue: Optional[int], fallback: str,
                     nan_guard: bool) -> None:
        if fallback not in ("previous", "base", "none"):
            raise ValueError(f"unknown fallback policy {fallback!r} "
                             "(previous | base | none)")
        self.max_queue = max_queue
        self.fallback = fallback
        self.nan_guard = nan_guard
        self.watchdog = EngineWatchdog()
        self.shed = 0          # requests rejected/expired by admission
        self.degraded = 0      # requests served below what they asked for
        self.poisoned = 0      # slots quarantined on non-finite logits
        self.failed = 0        # requests terminated with a typed error

    def _fail_fut(self, fut: ServeFuture, err: Exception,
                  count_failed: bool = True) -> None:
        """Terminal failure: typed error onto the future, versions
        unpinned, waiters released."""
        fut.error = err
        fut._done = True
        fut._event.set()
        if count_failed:
            self.failed += 1
        self._unpin_versions(fut)

    def _fallback_candidate(self, cand: Tenant):
        """The next rung down the ladder from ``cand``, or the
        ``_NO_FALLBACK`` sentinel when there is nowhere left to go.
        ``None`` (the base model) is a real candidate — it always
        serves."""
        if self.fallback == "none" or cand is None:
            return _NO_FALLBACK
        members = tenant_members(normalize_tenant(cand))
        if len(members) == 1 and self.fallback == "previous":
            prev = prior_version(self.engine.resolve(members[0]))
            store = self.engine.store
            while prev is not None:
                if prev in self.engine.packs or \
                        (store is not None and prev in store
                         and prev not in getattr(store, "quarantined",
                                                 list)()):
                    return prev
                prev = prior_version(prev)
        return None            # base model: the ladder's floor

    def _degrade_submit(self, fut: ServeFuture, orig: Tenant,
                        err: ServingError):
        """Walk the ladder at submit time (the sync path's inline load
        failed). Returns the prepared (adapter, handles, cold) of the
        rung that worked, or None after failing the future typed."""
        cand = self._fallback_candidate(orig)
        while cand is not _NO_FALLBACK:
            try:
                adapter, handles, cold = self._prepare_adapter(cand)
            except (StoreError, AdapterUnavailable):
                cand = self._fallback_candidate(cand)
                continue
            fut.degraded = True
            fut.degraded_from = normalize_tenant(orig)
            self.degraded += 1
            trace.instant("degrade", cat="serving", rid=fut.rid,
                          to=repr(adapter), err=type(err).__name__)
            return adapter, handles, cold
        self._fail_fut(fut, err)
        return None

    def _degrade_queued(self, p, err: ServingError) -> bool:
        """Walk the ladder for a queued request whose async prefetch
        failed: release the dead handles, re-pin and re-prefetch the
        fallback. False when the ladder is exhausted (the request left
        the queue with its typed error)."""
        for h in p.handles:
            h.release()
        p.handles = []
        self._unpin_versions(p.fut)
        orig = p.fut.adapter
        cand = self._fallback_candidate(orig)
        while cand is not _NO_FALLBACK:
            try:
                adapter, handles, _ = self._prepare_adapter(cand)
            except (StoreError, AdapterUnavailable):
                cand = self._fallback_candidate(cand)
                continue
            p.fut.adapter = adapter
            p.handles = handles
            if p.fut.degraded_from is None:
                p.fut.degraded_from = orig
            p.fut.degraded = True
            self._pin_versions(p.fut)
            self.degraded += 1
            trace.instant("degrade", cat="serving", rid=p.fut.rid,
                          to=repr(adapter), err=type(err).__name__)
            return True
        try:
            self._queue.remove(p)
        except ValueError:
            pass
        self._fail_fut(p.fut, err)
        return False

    def _expired(self, fut: ServeFuture,
                 now: Optional[float] = None) -> bool:
        return (fut.deadline_s is not None
                and fut.submit_time is not None
                and ((now or time.perf_counter())
                     - fut.submit_time) > fut.deadline_s)

    def _shed_queued(self, p, reason: str) -> None:
        """Remove a queued request with a typed ``RequestShed`` (cancels
        its prefetches; versions unpinned via the failure path)."""
        try:
            self._queue.remove(p)
        except ValueError:
            pass
        for h in p.handles:
            h.cancel()
        p.handles = []
        self.shed += 1
        trace.instant(f"shed.{reason}", cat="serving", rid=p.fut.rid)
        self._fail_fut(p.fut, RequestShed(
            f"request {p.fut.rid} shed ({reason})", rid=p.fut.rid,
            reason=reason), count_failed=False)

    def _shed_expired(self) -> None:
        """Expire queued requests past their deadline — every step, so a
        deadlined request is never silently parked in the queue."""
        now = time.perf_counter()
        for p in [p for p in self._queue if self._expired(p.fut, now)]:
            self._shed_queued(p, "deadline")

    def health(self) -> Dict[str, Any]:
        """Liveness + degradation snapshot: the watchdog's stall view,
        queue/lane occupancy, fault counters, store quarantine list."""
        store = self.engine.store
        return {
            "watchdog": self.watchdog.snapshot(),
            "queued": len(self._queue),
            "active": sum(a is not None for a in self._active),
            "step_count": self.step_count,
            "tokens_out": self.tokens_out,
            "shed": self.shed,
            "degraded": self.degraded,
            "poisoned": self.poisoned,
            "failed": self.failed,
            "quarantined": (list(store.quarantined())
                            if store is not None
                            and hasattr(store, "quarantined") else []),
        }

    def _next_tokens(self, logits, live: List[int]):
        """Greedy argmax + the poison/NaN-guard path. With no injector
        and ``nan_guard`` off this is byte-for-byte the pre-PR argmax —
        logits never come back to the host."""
        pslot = faults.poison_logits(self.step_count)
        if pslot is None and not self.nan_guard:
            return np.asarray(jnp.argmax(logits, -1), np.int32), ()
        lg = np.array(logits, np.float32)   # writable host copy
        if pslot is not None and live:
            lg[live[pslot % len(live)]] = np.nan
        bad = tuple(s for s in live
                    if not np.isfinite(lg[s]).all()) if self.nan_guard else ()
        nxt = np.nan_to_num(lg, nan=0.0, posinf=0.0,
                            neginf=0.0).argmax(-1).astype(np.int32)
        return nxt, bad

    # -- versioned hot-swap --------------------------------------------
    #
    # Requests are pinned to the adapter *version* they resolved to at
    # submit (``_prepare_adapter``): ``_vpins`` counts in-flight requests
    # per ``name@v`` and the store's own inflight refcount keeps the pack
    # eviction-proof while pinned. When the last request on a superseded
    # version drains, ``_evict_stale`` retires it from the engine tables
    # and the store's resident tier — the hot-swap completes without ever
    # touching an in-flight request's weights.

    def _pin_versions(self, fut) -> None:
        store = self.engine.store
        fut._vpins = []
        if store is None or not hasattr(store, "pin_use"):
            return
        for m in tenant_members(fut.adapter):
            if split_version(m)[1] is None:
                continue                 # unversioned: nothing to retire
            store.pin_use(m)
            self._vpins[m] = self._vpins.get(m, 0) + 1
            fut._vpins.append(m)

    def _unpin_versions(self, fut) -> None:
        store = self.engine.store
        for m in getattr(fut, "_vpins", ()):
            left = self._vpins.get(m, 0) - 1
            if left > 0:
                self._vpins[m] = left
            else:
                self._vpins.pop(m, None)
            store.unpin_use(m)
        fut._vpins = []
        self._evict_stale()

    def _evict_stale(self) -> None:
        """Retire every registered ``name@v`` that is both superseded (the
        store has published a newer version) and drained (no in-flight
        request pinned to it)."""
        store = self.engine.store
        if store is None or not hasattr(store, "latest_version"):
            return
        for name in list(self.engine.packs):
            base, v = split_version(name)
            if v is None:
                continue
            latest = store.latest_version(base)
            if latest is None or latest <= v or self._vpins.get(name, 0):
                continue
            self.engine.unregister(name)
            store.evict(name)
            trace.instant("hotswap.evict", cat="store", name=name,
                          superseded_by=latest)

    # -- async prefetch pipeline ---------------------------------------
    #
    # With ``async_prefetch=True`` a cold request's adapter starts loading
    # the moment it enters the admission queue: the disk read runs on the
    # store's worker pool, the device-table build on the engine's build
    # worker, and both overlap the in-flight decode steps. The request is
    # only admitted once its packs are registered, and hot tenants keep
    # decoding off the previous tables (``ids_covered``) while the rebuild
    # is in flight. With the flag off (default) nothing below runs and the
    # engines behave exactly as the synchronous path always has.

    def _prepare_adapter(self, adapter):
        """Submit-side adapter resolution. Sync mode registers (and
        disk-loads) inline, exactly as before; async mode only *starts*
        the loads and hands the handles to the queued request. Returns
        (normalized adapter, handles, cold). Version resolution happens
        HERE, at arrival: bare names map to the store's newest published
        version, and that concrete ``name@v`` rides the request's future
        for its whole lifetime — a publish mid-stream never moves an
        in-flight request."""
        adapter = normalize_tenant(self.engine.resolve(
            normalize_tenant(adapter)))
        store = self.engine.store
        members = tenant_members(adapter)
        cold = any(m not in self.engine.packs
                   and not (store is not None
                            and getattr(store, "is_resident",
                                        lambda _n: True)(m))
                   for m in members)
        if not self.async_prefetch:
            return _resolve_adapter(self.engine, adapter), [], cold
        handles = []
        for m in members:
            if m in self.engine.packs:
                # already in the device-table tier: nothing to load
                trace.instant("prefetch.hit", cat="store", name=m,
                              tier="tables")
                continue
            if store is None or m not in store:
                raise KeyError(f"request names unregistered adapter {m!r}")
            handles.append(store.prefetch(
                m, dequantize=self.engine.table_dtype != "int8"))
        return adapter, handles, cold

    def _drain_prefetches(self) -> None:
        """Register every queued request whose prefetch has landed, and
        keep a background table build moving for any pending dirt or
        deferred fused transition. Never blocks."""
        if not self.async_prefetch:
            return
        for p in list(self._queue):
            if p.handles and all(h.done() for h in p.handles):
                try:
                    for h in p.handles:
                        self.engine.register(h.result())
                    p.handles = []
                except ServingError as e:
                    # failed load: walk the request down the fallback
                    # ladder (or fail it typed) — never crash the loop
                    self._degrade_queued(p, e)
        self.engine.kick_async_build()

    def _stall_for_head(self) -> None:
        """No live decode to hide behind: block on the head request's
        prefetch so admission can proceed. The span is the measured cost
        async serving could NOT hide."""
        p = self._queue[0]
        with trace.span("prefetch.stall", cat="store", rid=p.fut.rid):
            while p.handles:
                try:
                    for h in p.handles:
                        self.engine.register(h.result())
                    p.handles = []
                except ServingError as e:
                    if not self._degrade_queued(p, e):
                        return        # head failed out of the queue, typed
                    # else: p now carries the fallback's handles; block on
                    # those too (still the head, still nothing live)
        self.engine.kick_async_build()

    def _admittable(self, p, had_live: bool) -> bool:
        """FIFO admission gate: a request past its deadline is shed right
        here (typed, never silently dropped); with the async pipeline a
        request enters only once its packs are registered, and — while a
        table rebuild is in flight — only if the current tables already
        cover its tenant (hot) or there is no live decode the stall could
        disturb."""
        if self._expired(p.fut):
            self._shed_queued(p, "deadline")
            return False
        if not self.async_prefetch:
            return True
        if p.handles:
            return False              # disk load still in flight
        if self.engine.tables_ready():
            return True
        if self.engine.ids_covered([p.fut.adapter]):
            return True
        return not had_live

    def cancel(self, fut: ServeFuture) -> bool:
        """Abort a still-queued request: drop it from the queue and cancel
        any in-flight prefetch (the disk read is skipped when it has not
        started). Admitted requests cannot be cancelled."""
        for p in self._queue:
            if p.fut is fut:
                self._queue.remove(p)
                for h in p.handles:
                    h.cancel()
                p.handles = []
                fut.cancelled = True
                trace.instant("prefetch.cancel", cat="store", rid=fut.rid)
                self._fail_fut(fut, RequestShed(
                    f"request {fut.rid} was cancelled", rid=fut.rid,
                    reason="cancelled"), count_failed=False)
                return True
        return False

    def shutdown(self, include_store: bool = False) -> None:
        """Join the engine's background build worker (and optionally the
        store's prefetch pool — stores may be shared, so opt-in)."""
        self.engine.shutdown()
        store = self.engine.store
        if include_store and store is not None \
                and hasattr(store, "shutdown"):
            store.shutdown()

    def pending(self) -> int:
        return len(self._queue) + sum(p is not None for p in self._active)

    def kv_cache_bytes(self) -> int:
        return sum(int(x.nbytes) for x in jax.tree.leaves(self.caches))

    def _emit(self, slot: int, token: int) -> None:
        """Record one generated token. ``_pos`` is NOT touched here — it
        always points at the cache index the next decode step writes to."""
        p = self._active[slot]
        p.fut.tokens.append(int(token))
        self.tokens_out += 1
        if len(p.fut.tokens) == 1:
            p.fut.first_token_step = self.step_count
            if p.fut.submit_time is not None:
                p.fut.ttft = time.perf_counter() - p.fut.submit_time
        self._last[slot] = token
        if (len(p.fut.tokens) >= p.fut.max_tokens
                or (p.eos_id is not None and int(token) == p.eos_id)):
            self._finish(slot)

    def run(self, max_steps: int = 100_000) -> float:
        """Drive step() until every queued request resolved; returns
        wall-clock seconds."""
        t0 = time.perf_counter()
        for _ in range(max_steps):
            if not self.step() and not self._queue \
                    and all(p is None for p in self._active):
                break
        else:
            raise RuntimeError(f"run() hit max_steps={max_steps} with "
                               f"{self.pending()} requests in flight")
        return time.perf_counter() - t0


class ServingEngine(_EngineCommon):
    """Continuous-batching front end over the multi-tenant side-delta path."""

    def __init__(self, cfg, params, *, slots: int = 4, cache_size: int = 128,
                 scheduler: Optional[FusedLRU] = None, store=None,
                 table_dtype: str = "f32",
                 interpret: Optional[bool] = None,
                 async_prefetch: bool = False, slot_pad: int = 1,
                 max_queue: Optional[int] = None,
                 fallback: str = "previous", nan_guard: bool = False):
        if cfg.encoder_only:
            raise ValueError("encoder-only archs have no decode serving path")
        self.cfg = cfg
        self.async_prefetch = async_prefetch
        self.slots = slots
        self.cache_size = cache_size
        self.engine = MultiTenantEngine(cfg, params, scheduler=scheduler,
                                        store=store, table_dtype=table_dtype,
                                        interpret=interpret,
                                        slot_pad=slot_pad)
        self.caches = lm.init_cache(cfg, slots, cache_size)
        self._axes = lm.cache_batch_axes(cfg)
        self._active: List[Optional[_Pending]] = [None] * slots
        self._pos = np.zeros((slots,), np.int32)      # next cache write index
        self._last = np.zeros((slots,), np.int32)     # last generated token
        self._queue: "deque[_Pending]" = deque()
        self._rid = 0
        self._vpins: Dict[str, int] = {}   # name@v -> in-flight requests
        self.step_count = 0
        self.tokens_out = 0
        self.decode_slot_waste = 0    # idle-lane decode steps (utilization)
        self._init_faults(max_queue, fallback, nan_guard)

    # ------------------------------------------------------------------
    # Request API
    # ------------------------------------------------------------------

    def submit(self, prompt_tokens, adapter: Tenant = None,
               max_tokens: int = 16,
               eos_id: Optional[int] = None,
               deadline_s: Optional[float] = None) -> ServeFuture:
        """Queue one request; returns its future. ``adapter`` is a registered
        adapter id, a stack of ids, or None for the base model.
        ``deadline_s`` bounds *queue* time: a request still unadmitted
        that long after submit is shed with ``RequestShed``. A full
        bounded queue (``max_queue=``) sheds at submit — the returned
        future carries the typed error instead of a silent drop."""
        if max_tokens < 1:
            raise ValueError("max_tokens must be >= 1")
        prompt = np.asarray(prompt_tokens, np.int32).reshape(-1)
        prefix = (self.cfg.num_prefix_embeds
                  if self.cfg.modality == "vision" else 0)
        # the final generated token is returned but never written back to
        # the cache, so a request needs one slot less than prompt+max_tokens
        need = prompt.shape[0] + prefix + max_tokens - 1
        if need > self.cache_size:
            raise ValueError(f"prompt ({prompt.shape[0]}) + max_tokens "
                             f"({max_tokens}) needs {need} cache slots, "
                             f"engine has {self.cache_size}")
        # arrival is stamped BEFORE adapter resolution: the sync path's
        # inline disk load is queue time the request actually waited
        t_sub = time.perf_counter()
        fut = ServeFuture(self._rid, normalize_tenant(adapter), max_tokens)
        self._rid += 1
        fut.submit_time = t_sub
        fut.deadline_s = deadline_s
        if self.max_queue is not None and len(self._queue) >= self.max_queue:
            self.shed += 1
            trace.instant("shed.queue_full", cat="serving", rid=fut.rid)
            self._fail_fut(fut, RequestShed(
                f"request {fut.rid} shed (queue_full: "
                f"{len(self._queue)} >= {self.max_queue})", rid=fut.rid,
                reason="queue_full"), count_failed=False)
            return fut
        try:
            adapter, handles, cold = self._prepare_adapter(adapter)
        except (StoreError, AdapterUnavailable) as e:
            prepared = self._degrade_submit(fut, adapter, e)
            if prepared is None:
                return fut             # ladder exhausted: typed failure
            adapter, handles, cold = prepared
        fut.adapter = adapter
        fut.cold = cold
        self._pin_versions(fut)
        self._queue.append(_Pending(fut, prompt, eos_id, handles))
        return fut

    # ------------------------------------------------------------------
    # Scheduling loop
    # ------------------------------------------------------------------

    def _batch_for(self, prompt: np.ndarray) -> Dict[str, Any]:
        batch = {"tokens": jnp.asarray(prompt[None])}
        if self.cfg.modality == "vision":
            batch["patch_embeds"] = jnp.zeros(
                (1, self.cfg.num_prefix_embeds, self.cfg.d_model))
        return batch

    def _finish(self, slot: int) -> None:
        p = self._active[slot]
        p.fut.finished_step = self.step_count
        p.fut.finish_time = time.perf_counter()
        p.fut._done = True
        p.fut._event.set()
        self._active[slot] = None
        self._pos[slot] = 0
        self._last[slot] = 0
        self._unpin_versions(p.fut)

    def _poison(self, slot: int) -> None:
        """Quarantine ONE decode slot whose logits went non-finite: its
        request fails typed, the lane is freed, the rest of the batch
        never stops decoding."""
        p = self._active[slot]
        self.poisoned += 1
        trace.instant("slot.poison", cat="serving", rid=p.fut.rid,
                      slot=slot, step=self.step_count)
        self._active[slot] = None
        self._pos[slot] = 0
        self._last[slot] = 0
        self._fail_fut(p.fut, SlotPoisoned(
            f"request {p.fut.rid} poisoned: non-finite logits on slot "
            f"{slot} at step {self.step_count}", rid=p.fut.rid,
            step=self.step_count))

    def _admit(self, slot: int, p: _Pending) -> None:
        with trace.span("admit", rid=p.fut.rid, slot=slot,
                        prompt=int(p.prompt.shape[0])):
            names: List[Tenant] = [p.fut.adapter]
            stale = self.async_prefetch
            ids = self.engine.ids_for(names, stale_ok=stale)
            wp = self.engine.wrapped_params(ids, stale_ok=stale)
            logits, c1 = self.engine._prefill(wp, self._batch_for(p.prompt),
                                              self.cache_size)
            self.caches = [_slot_insert(big, small, slot, ax)
                           for big, small, ax
                           in zip(self.caches, c1, self._axes)]
            prefix = (self.cfg.num_prefix_embeds
                      if self.cfg.modality == "vision" else 0)
            self._active[slot] = p
            p.fut.submitted_step = self.step_count
            self._pos[slot] = p.prompt.shape[0] + prefix
            first = int(np.argmax(np.asarray(logits[0])))
            self._emit(slot, first)

    def step(self) -> bool:
        """Admit queued requests into free slots, then run one decode step
        over every occupied lane. Returns False when fully drained."""
        with trace.span("step", engine="lane") as sp:
            t0 = time.perf_counter()
            faults.on_engine_step(self.step_count)
            self._shed_expired()
            self._drain_prefetches()
            had_live = any(a is not None for a in self._active)
            if self.async_prefetch and not had_live and self._queue \
                    and self._queue[0].handles:
                self._stall_for_head()
            for slot in range(self.slots):
                if self._active[slot] is None and self._queue:
                    if not self._admittable(self._queue[0], had_live):
                        break          # FIFO: head's prefetch still landing
                    p = self._queue.popleft()
                    try:
                        self._admit(slot, p)
                    except TableBuildError:
                        # build failed (simulated OOM): requeue at the
                        # head and retry on the next step's fresh build
                        self._queue.appendleft(p)
                        trace.instant("fault.build_backoff", cat="tables")
                        break
            live = [s for s in range(self.slots)
                    if self._active[s] is not None]
            if not live:
                return bool(self._queue)
            self.step_count += 1
            sp.set(step=self.step_count, live=len(live))
            self.decode_slot_waste += self.slots - len(live)
            names = [self._active[s].fut.adapter
                     if self._active[s] is not None else None
                     for s in range(self.slots)]
            # the scheduler sees only live lanes: idle slots are not
            # base-model traffic, and counting them would dilute every
            # tenant's share
            self.engine.schedule([names[s] for s in live],
                                 defer=self.async_prefetch)
            try:
                with trace.span("decode", live=len(live)):
                    stale = self.async_prefetch
                    ids = self.engine.ids_for(names, stale_ok=stale)
                    wp = self.engine.wrapped_params(ids, stale_ok=stale)
                    toks = jnp.asarray(self._last[:, None])
                    logits, self.caches = self.engine._decode(
                        wp, toks, self.caches, jnp.asarray(self._pos))
                    nxt, bad = self._next_tokens(logits, live)
            except TableBuildError:
                # nothing was emitted and no position advanced: the whole
                # decode retries next step against a fresh table build
                trace.instant("fault.build_backoff", cat="tables")
                return True
            for s in live:
                self._pos[s] += 1      # this step's KV landed at _pos[s]
                if s in bad:
                    self._poison(s)
                else:
                    self._emit(s, int(nxt[s]))
            self.watchdog.record(time.perf_counter() - t0)
            return True


# ---------------------------------------------------------------------------
# Paged engine
# ---------------------------------------------------------------------------

class _PagedRequest:
    __slots__ = ("fut", "prompt", "eos_id", "need", "nblk", "state", "done",
                 "pages", "reserve", "handles")

    def __init__(self, fut: ServeFuture, prompt: np.ndarray,
                 eos_id: Optional[int], need: int, nblk: int, handles=None):
        self.fut = fut
        self.prompt = prompt
        self.eos_id = eos_id
        self.need = need          # KV rows this request may write
        self.nblk = nblk          # block-table entries it needs
        self.state = "prefill"
        self.done = 0             # prompt tokens already in the cache
        self.pages: List[int] = []     # block-table pages (1 ref each)
        self.reserve: List[int] = []   # preallocated COW spares
        self.handles = handles or []   # in-flight store prefetches


class PagedServingEngine(_EngineCommon):
    """Continuous batching over a paged KV pool with COW prefix sharing and
    chunked-prefill admission. Dense/moe text models only (SSM state is O(1)
    per request; vision prefixes are not token-addressed)."""

    def __init__(self, cfg, params, *, slots: int = 4, num_pages: int = 64,
                 page_size: int = 8, max_len: Optional[int] = None,
                 chunk_size: Optional[int] = None,
                 scheduler: Optional[FusedLRU] = None, store=None,
                 table_dtype: str = "f32", quant_kv: bool = False,
                 interpret: Optional[bool] = None,
                 async_prefetch: bool = False, slot_pad: int = 1,
                 max_queue: Optional[int] = None,
                 fallback: str = "previous", nan_guard: bool = False):
        if cfg.encoder_only:
            raise ValueError("encoder-only archs have no decode serving path")
        from repro.serving.kvcache import PagePool, copy_page, pages_for
        self.cfg = cfg
        self.async_prefetch = async_prefetch
        self.slots = slots
        self.num_pages = num_pages
        self.page_size = page_size
        self.max_len = max_len or (num_pages - 1) * page_size
        self.max_blocks = pages_for(self.max_len, page_size)
        self.chunk_size = chunk_size or page_size
        self.engine = MultiTenantEngine(cfg, params, scheduler=scheduler,
                                        store=store, table_dtype=table_dtype,
                                        interpret=interpret,
                                        slot_pad=slot_pad)
        self.pool = PagePool(num_pages, page_size)
        self.caches = lm.init_paged_cache(cfg, num_pages, page_size,
                                          quant=quant_kv)
        self._bt = np.zeros((slots, self.max_blocks), np.int32)
        self._pos = np.zeros((slots,), np.int32)
        self._last = np.zeros((slots,), np.int32)
        self._active: List[Optional[_PagedRequest]] = [None] * slots
        self._queue: "deque[_PagedRequest]" = deque()
        self._rid = 0
        self._vpins: Dict[str, int] = {}   # name@v -> in-flight requests
        self.step_count = 0
        self.tokens_out = 0
        self.decode_slot_waste = 0
        self.prefill_chunks = 0
        self._init_faults(max_queue, fallback, nan_guard)
        self.peak_resident = 0        # max concurrently admitted requests
        self.peak_used_pages = 0      # incl. evictable registry-only pages
        self.peak_ws_pages = 0        # pages pinned by admitted requests

        from repro.models import layers as L

        def _dec(p, t, c, pos, bt):
            with L.sidedelta_backend(interpret):
                return lm.decode_step(p, self.cfg, t, c, pos, block_tables=bt)

        def _pfc(p, toks, c, bt, start, valid):
            with L.sidedelta_backend(interpret):
                return lm.prefill_chunk(p, self.cfg, toks, c, bt, start,
                                        valid)

        self._decode = jax.jit(_dec)
        self._prefill_chunk = jax.jit(_pfc)
        self._copy = jax.jit(functools.partial(copy_page, page_axis=1))

    def page_bytes(self) -> int:
        """Device bytes of ONE physical page across the whole layer stack."""
        return self.kv_cache_bytes() // self.num_pages

    # ------------------------------------------------------------------
    # Request API
    # ------------------------------------------------------------------

    def submit(self, prompt_tokens, adapter: Tenant = None,
               max_tokens: int = 16,
               eos_id: Optional[int] = None,
               deadline_s: Optional[float] = None) -> ServeFuture:
        from repro.serving.kvcache import pages_for
        if max_tokens < 1:
            raise ValueError("max_tokens must be >= 1")
        prompt = np.asarray(prompt_tokens, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("empty prompt")
        # the final generated token is never written back: one row less
        need = prompt.shape[0] + max_tokens - 1
        if need > self.max_len:
            raise ValueError(f"prompt ({prompt.shape[0]}) + max_tokens "
                             f"({max_tokens}) needs {need} KV rows, engine "
                             f"caps requests at {self.max_len}")
        nblk = pages_for(need, self.page_size)
        if nblk > self.num_pages - 1:
            raise ValueError(f"request needs {nblk} pages, pool has "
                             f"{self.num_pages - 1}")
        # arrival stamp precedes adapter resolution (see ServingEngine.submit)
        t_sub = time.perf_counter()
        fut = ServeFuture(self._rid, normalize_tenant(adapter), max_tokens)
        self._rid += 1
        fut.submit_time = t_sub
        fut.deadline_s = deadline_s
        if self.max_queue is not None and len(self._queue) >= self.max_queue:
            self.shed += 1
            trace.instant("shed.queue_full", cat="serving", rid=fut.rid)
            self._fail_fut(fut, RequestShed(
                f"request {fut.rid} shed (queue_full: "
                f"{len(self._queue)} >= {self.max_queue})", rid=fut.rid,
                reason="queue_full"), count_failed=False)
            return fut
        try:
            adapter, handles, cold = self._prepare_adapter(adapter)
        except (StoreError, AdapterUnavailable) as e:
            prepared = self._degrade_submit(fut, adapter, e)
            if prepared is None:
                return fut             # ladder exhausted: typed failure
            adapter, handles, cold = prepared
        fut.adapter = adapter
        fut.cold = cold
        self._pin_versions(fut)
        self._queue.append(_PagedRequest(fut, prompt, eos_id, need, nblk,
                                         handles))
        return fut

    # ------------------------------------------------------------------
    # Page plumbing
    # ------------------------------------------------------------------

    def _try_admit(self, slot: int, r: _PagedRequest) -> bool:
        """Map the request into ``slot`` if the pool can cover its pages:
        unshared blocks, plus a COW reserve for the boundary page (when the
        prefix match ends inside a shared page) and for the prompt tail
        (prefix registration re-shares it, so the first decode write must
        copy). Takes no pages on failure."""
        p = self.page_size
        L_ = r.prompt.shape[0]
        with trace.span("admit", rid=r.fut.rid, slot=slot,
                        prompt=int(L_)) as sp:
            shared_len, shared = self.pool.match_prefix(
                r.prompt, salt=_prefix_salt(r.fut.adapter))
            cow = int(shared_len < len(shared) * p)
            cow += int(r.need > L_ and L_ % p != 0)
            n_owned = r.nblk - len(shared)
            if not self.pool.can_alloc(n_owned + cow):
                self.pool.release(shared)
                sp.set(admitted=False)
                return False
            fresh = self.pool.alloc(n_owned + cow)
            owned, r.reserve = fresh[:n_owned], fresh[n_owned:]
            row = list(shared) + owned
            r.pages = list(row)
            self._bt[slot, :] = 0
            self._bt[slot, :len(row)] = row
            r.state = "prefill"
            r.done = shared_len
            self._active[slot] = r
            r.fut.submitted_step = self.step_count
            sp.set(admitted=True, shared_len=int(shared_len),
                   pages=len(row), reserve=len(r.reserve))
            return True

    def _ensure_writable(self, slot: int, lo: int, hi: int) -> None:
        """COW every shared page under write range [lo, hi)."""
        p = self.page_size
        r = self._active[slot]
        for blk in range(lo // p, (hi - 1) // p + 1):
            pg = int(self._bt[slot, blk])
            if not self.pool.is_shared(pg):
                continue
            dst = r.reserve.pop() if r.reserve else self.pool.alloc(1)[0]
            with trace.span("cow_copy", cat="pages", slot=slot,
                            src=pg, dst=int(dst)):
                self.caches = self._copy(self.caches, pg, dst)
            self._bt[slot, blk] = dst
            r.pages[r.pages.index(pg)] = dst
            self.pool.release([pg])
            self.pool.cow_copies += 1

    def _finish(self, slot: int) -> None:
        r = self._active[slot]
        r.fut.finished_step = self.step_count
        r.fut.finish_time = time.perf_counter()
        r.fut._done = True
        r.fut._event.set()
        self.pool.release(r.pages + r.reserve)
        r.pages, r.reserve = [], []
        self._active[slot] = None
        self._bt[slot, :] = 0
        self._pos[slot] = 0
        self._last[slot] = 0
        self._unpin_versions(r.fut)

    def _poison(self, slot: int) -> None:
        """Quarantine ONE decode slot (non-finite logits): fail the
        request typed, free its pages, keep the rest of the batch live."""
        r = self._active[slot]
        self.poisoned += 1
        trace.instant("slot.poison", cat="serving", rid=r.fut.rid,
                      slot=slot, step=self.step_count)
        self.pool.release(r.pages + r.reserve)
        r.pages, r.reserve = [], []
        self._active[slot] = None
        self._bt[slot, :] = 0
        self._pos[slot] = 0
        self._last[slot] = 0
        self._fail_fut(r.fut, SlotPoisoned(
            f"request {r.fut.rid} poisoned: non-finite logits on slot "
            f"{slot} at step {self.step_count}", rid=r.fut.rid,
            step=self.step_count))

    def _prefill_step(self, slot: int) -> None:
        from repro.serving.kvcache import pages_for
        r = self._active[slot]
        L_ = r.prompt.shape[0]
        lo = r.done
        hi = min(L_, lo + self.chunk_size)
        with trace.span("prefill_chunk", slot=slot, lo=int(lo), hi=int(hi)):
            self._ensure_writable(slot, lo, hi)
            toks = np.zeros((1, self.chunk_size), np.int32)
            toks[0, :hi - lo] = r.prompt[lo:hi]
            stale = self.async_prefetch
            ids = self.engine.ids_for([r.fut.adapter], stale_ok=stale)
            wp = self.engine.wrapped_params(ids, stale_ok=stale)
            logits, self.caches = self._prefill_chunk(
                wp, jnp.asarray(toks), self.caches,
                jnp.asarray(self._bt[slot:slot + 1]),
                jnp.int32(lo), jnp.int32(hi - lo))
        r.done = hi
        self.prefill_chunks += 1
        if hi == L_:
            # registry refs re-share the prompt pages (incl. the pristine
            # partial tail); the COW reserve covers the first decode write
            self.pool.register_prefix(
                r.prompt, [int(x) for x in
                           self._bt[slot, :pages_for(L_, self.page_size)]],
                salt=_prefix_salt(r.fut.adapter))
            r.state = "live"
            self._pos[slot] = L_
            self._emit(slot, int(np.argmax(np.asarray(logits[0]))))

    # ------------------------------------------------------------------
    # Scheduling loop
    # ------------------------------------------------------------------

    def step(self) -> bool:
        """FIFO-admit while pages last, run ONE prefill chunk, then one
        decode step over every live lane. Returns False when drained."""
        with trace.span("step", engine="paged") as sp:
            t0 = time.perf_counter()
            faults.on_engine_step(self.step_count)
            self._shed_expired()
            self._drain_prefetches()
            had_live = any(a is not None for a in self._active)
            if self.async_prefetch and not had_live and self._queue \
                    and self._queue[0].handles:
                self._stall_for_head()
            for slot in range(self.slots):
                if self._active[slot] is None and self._queue:
                    if not self._admittable(self._queue[0], had_live):
                        break          # FIFO: head's prefetch still landing
                    if not self._try_admit(slot, self._queue[0]):
                        break
                    self._queue.popleft()
            pf = [s for s in range(self.slots) if self._active[s] is not None
                  and self._active[s].state == "prefill"]
            live = [s for s in range(self.slots)
                    if self._active[s] is not None
                    and self._active[s].state == "live"]
            self.peak_resident = max(self.peak_resident, len(pf) + len(live))
            self.peak_used_pages = max(self.peak_used_pages,
                                       self.pool.used_pages())
            # working set = distinct pages pinned by admitted requests
            # (block tables, shared prefixes counted once, COW reserves).
            # Registry-only pages are excluded: they are an LRU cache,
            # reclaimable on demand.
            ws = set()
            for s in pf + live:
                ws.update(int(x) for x in self._bt[s] if x)
                ws.update(self._active[s].reserve)
            self.peak_ws_pages = max(self.peak_ws_pages, len(ws))
            if not pf and not live:
                return bool(self._queue)
            self.step_count += 1
            sp.set(step=self.step_count, prefill=len(pf), live=len(live))
            trace.counter("free_pages", self.pool.free_pages(),
                          cat="pages")
            trace.counter("resident", len(pf) + len(live))
            if pf:
                try:
                    self._prefill_step(pf[0])
                except TableBuildError:
                    # chunk not applied (r.done untouched): retried next
                    # step against a fresh table build
                    trace.instant("fault.build_backoff", cat="tables")
            if live:
                self.decode_slot_waste += self.slots - len(live)
                live_set = set(live)
                names = [self._active[s].fut.adapter
                         if s in live_set else None
                         for s in range(self.slots)]
                self.engine.schedule([names[s] for s in live],
                                     defer=self.async_prefetch)
                try:
                    with trace.span("decode", live=len(live)):
                        stale = self.async_prefetch
                        ids = self.engine.ids_for(names, stale_ok=stale)
                        wp = self.engine.wrapped_params(ids, stale_ok=stale)
                        for s in live:
                            self._ensure_writable(s, int(self._pos[s]),
                                                  int(self._pos[s]) + 1)
                        # idle / still-prefilling lanes decode against the
                        # scratch page
                        mask = np.zeros((self.slots,), bool)
                        mask[live] = True
                        bt = np.where(mask[:, None], self._bt, 0)
                        pos = np.where(mask, self._pos, 0)
                        logits, self.caches = self._decode(
                            wp, jnp.asarray(self._last[:, None]), self.caches,
                            jnp.asarray(pos), jnp.asarray(bt))
                        nxt, bad = self._next_tokens(logits, live)
                except TableBuildError:
                    # no emit, no position advance: whole decode retries
                    trace.instant("fault.build_backoff", cat="tables")
                    return True
                for s in live:
                    self._pos[s] += 1  # this step's KV landed at _pos[s]
                    if s in bad:
                        self._poison(s)
                    else:
                        self._emit(s, int(nxt[s]))
            self.watchdog.record(time.perf_counter() - t0)
            return True
