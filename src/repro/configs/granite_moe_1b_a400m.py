"""granite-moe-1b-a400m [moe] — 32 experts top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base]

24L d_model=1024 16H (GQA kv=8), per-expert d_ff=512, vocab=49155.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8,
    d_ff=512, vocab_size=49155,
    moe=MoEConfig(num_experts=32, top_k=8, d_ff=512, capacity_factor=1.25),
    tie_embeddings=True,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=32,
    vocab_size=128,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff=32, capacity_factor=1.25),
)
