"""paligemma-3b [vlm] — SigLIP + gemma backbone. [arXiv:2407.07726]

18L d_model=2048 8H (MQA kv=1), d_ff=16384, vocab=257216. The vision
frontend is a STUB per the assignment: ``input_specs`` provides 256
precomputed patch embeddings of d_model, prepended as a fully-visible
prefix (prefix-LM attention).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1,
    head_dim=256, d_ff=16384, vocab_size=257216,
    modality="vision", num_prefix_embeds=256,
    act="gelu", tie_embeddings=True,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
    d_ff=128, vocab_size=512, num_prefix_embeds=16,
)
