"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, 2 shared + 64 routed top-6.
[arXiv:2405.04434]

27L d_model=2048 16H, per-expert d_ff=1408, vocab=102400. Layer 0 uses a dense
FFN (d_ff=10944) as in the HF config. MLA: q projected directly
(q_lora_rank=0 in the Lite variant), kv_lora_rank=512, nope/rope head dims
128/64, v_head_dim=128.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=102400, attn_type="mla",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=64, top_k=6, num_shared=2, d_ff=1408,
                  first_dense_layers=1, first_dense_d_ff=10944,
                  capacity_factor=1.25),
    fsdp=True,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, d_ff=32,
    vocab_size=128, fsdp=False,
    mla=MLAConfig(kv_lora_rank=32, q_lora_rank=0, qk_nope_head_dim=16,
                  qk_rope_head_dim=8, v_head_dim=16),
    moe=MoEConfig(num_experts=4, top_k=2, num_shared=1, d_ff=32,
                  first_dense_layers=1, first_dense_d_ff=64,
                  capacity_factor=1.25),
)
