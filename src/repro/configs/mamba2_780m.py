"""mamba2-780m [ssm] — SSD, attention-free. [arXiv:2405.21060]

48L d_model=1536 (d_inner=3072, 48 heads of 64, d_state=128), vocab=50280.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, num_heads=48, num_kv_heads=48,
    d_ff=0, vocab_size=50280, attn_type="none",
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64, d_conv=4, n_groups=1,
                  chunk=128),
    tie_embeddings=True,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=4, d_model=128, num_heads=4, vocab_size=256,
    ssm=SSMConfig(d_state=16, expand=2, head_dim=64, d_conv=4, n_groups=1,
                  chunk=32),
)
