"""Configuration dataclasses for models, shapes, meshes and adapters.

Every assigned architecture gets a ``ModelConfig`` in its own module under
``repro/configs``; the registry maps ``--arch`` ids to those configs plus the
set of input shapes that are applicable to the family (encoder-only archs have
no decode step; pure full-attention archs skip long_500k).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell: (seq_len, global_batch, kind)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


# The four assigned LM-family shapes.
SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0           # routed experts
    top_k: int = 0
    num_shared: int = 0            # always-on shared experts (DeepSeek-V2 style)
    d_ff: int = 0                  # per-expert hidden dim
    first_dense_layers: int = 0    # leading layers that use a dense FFN instead
    first_dense_d_ff: int = 0      # hidden dim of those dense layers
    capacity_factor: float = 1.25  # train-time token capacity per expert
    router_jitter: float = 0.0


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2)."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0           # 0 => project q directly from d_model
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD mixer."""

    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    d_conv: int = 4
    n_groups: int = 1
    chunk: int = 128               # SSD chunk length
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 => d_model // num_heads
    attn_type: str = "gqa"         # gqa | mla | none
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    act: str = "silu"              # silu (SwiGLU) | gelu (vanilla MLP)
    tie_embeddings: bool = False
    causal: bool = True            # False for encoder-only (hubert)
    encoder_only: bool = False
    logit_softcap: float = 0.0

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None

    # Hybrid (zamba2): a single *shared* attention block applied after every
    # ``hybrid_attn_every`` SSM layers (weights reused at every site).
    hybrid_attn_every: int = 0

    # Modality frontends (stubs: input_specs provides precomputed embeddings).
    modality: str = "text"         # text | vision | audio
    num_prefix_embeds: int = 0     # e.g. image patches prepended (paligemma)

    # Distribution policy.
    fsdp: bool = False             # shard params along the data axis too
    remat: str = "full"            # full | dots | none — layer-scan remat policy
    # Head-group padding (optimized variants, §Perf): pad q heads per kv
    # group (and optionally kv heads) with zero-init dead heads so the head
    # dim shards evenly over 16-way TP instead of replicating attention.
    pad_heads_to: int = 0          # padded total q heads (0 = exact config)
    pad_kv_to: int = 0             # padded kv heads
    # Repeat kv heads to the q-head count before attention so the head dim
    # shards as one flat axis (a (KV, G) split cannot absorb a single 16-way
    # mesh axis). Costs a local repeat; buys fully-sharded attention.
    attn_repeat_kv: bool = False

    # Sub-quadratic? (drives long_500k applicability)
    @property
    def subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    # Embedding tables are padded to a multiple of 256 (Megatron-style) so the
    # vocab dim shards evenly over 16-way TP; pad logits are masked to -inf.
    @property
    def padded_vocab(self) -> int:
        m = 256
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class AdapterConfig:
    """The paper's contribution, as a first-class config."""

    kind: str = "none"             # none | shira | lora | dora | shira-dora
    mask: str = "wm"               # struct | rand | wm | grad | snip  (shira masks)
    sparsity: float = 0.99         # fraction of *zeros* in the mask (1-2% trainable)
    rank: int = 32                 # lora/dora rank
    alpha: float = 1.0             # inference-time strength W + alpha * S
    lora_alpha: float = 64.0       # lora scaling numerator (alpha/rank)
    target_modules: Tuple[str, ...] = (
        "wq", "wk", "wv", "wo", "w_up", "w_gate", "w_down",
        "in_proj", "out_proj", "w_dkv", "w_uk", "w_uv",
    )
    # struct-mask knobs
    struct_rows: int = 8           # trainable rows per matrix (rank-1-ish part)
    struct_cols: int = 8
    # packed mode keeps optimizer state only for the nz set (paper App. D)
    packed: bool = True
    # beyond-paper: compress the cross-pod gradient all-reduce to the nz set
    sparse_grad_sync: bool = False


@dataclass(frozen=True)
class TrainConfig:
    seed: int = 0
    learning_rate: float = 5e-4
    weight_decay: float = 0.0
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 10
    schedule: str = "linear"       # linear | cosine | constant
    total_steps: int = 300
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    microbatch: int = 0            # 0 => no gradient accumulation


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeSpec
    adapter: AdapterConfig = field(default_factory=AdapterConfig)
    train: TrainConfig = field(default_factory=TrainConfig)
