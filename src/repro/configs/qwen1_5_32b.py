"""qwen1.5-32b [dense] — QKV bias. [hf:Qwen/Qwen1.5-32B]

64L d_model=5120 40H (kv=40), d_ff=27392, vocab=152064.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=40, num_kv_heads=40,
    d_ff=27392, vocab_size=152064, qkv_bias=True, fsdp=True,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
    vocab_size=256, fsdp=False,
)
