"""Architecture registry: ``--arch <id>`` ids -> ModelConfig + applicable shapes.

Applicability rules (recorded in DESIGN.md §Arch-applicability):
  * ``long_500k`` needs sub-quadratic sequence mixing -> only ssm/hybrid run it.
  * encoder-only archs (hubert) have no decode step -> skip decode shapes.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import SHAPES, ModelConfig, ShapeSpec

ARCH_IDS = [
    "mamba2-780m",
    "granite-moe-1b-a400m",
    "deepseek-v2-lite-16b",
    "zamba2-2.7b",
    "paligemma-3b",
    "hubert-xlarge",
    "qwen1.5-32b",
    "starcoder2-7b",
    "deepseek-coder-33b",
    "granite-34b",
]

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
            for a in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(_MODULES[arch])
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(_MODULES[arch])
    return mod.SMOKE_CONFIG


def applicable_shapes(arch: str) -> List[ShapeSpec]:
    cfg = get_config(arch)
    out = []
    for s in SHAPES.values():
        if cfg.encoder_only and s.kind == "decode":
            continue
        if s.name == "long_500k" and not cfg.subquadratic:
            continue
        out.append(s)
    return out


def all_cells() -> List[tuple]:
    return [(a, s.name) for a in ARCH_IDS for s in applicable_shapes(a)]
