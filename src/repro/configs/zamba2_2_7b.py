"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention block.
[arXiv:2411.15242]

54L d_model=2560 (d_inner=5120, 80 heads of 64, d_state=64); one *shared*
attention+MLP block (32H kv=32, d_ff=10240) applied after every 6 mamba
layers, fed concat(hidden, embedding).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=10240, vocab_size=32000,
    ssm=SSMConfig(d_state=64, expand=2, head_dim=64, d_conv=4, n_groups=1,
                  chunk=128),
    hybrid_attn_every=6,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=4, d_model=128, num_heads=4, num_kv_heads=4, d_ff=256,
    vocab_size=256, hybrid_attn_every=2,
    ssm=SSMConfig(d_state=16, expand=2, head_dim=32, d_conv=4, n_groups=1,
                  chunk=32),
)
