"""hubert-xlarge [audio] — encoder-only, w2v2 architecture. [arXiv:2106.07447]

48L d_model=1280 16H (kv=16), d_ff=5120, vocab(=target classes)=504.
The conv feature extractor is a STUB: ``input_specs`` provides precomputed
frame embeddings (B, S, d_model). Bidirectional attention; no decode step.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
    d_ff=5120, vocab_size=504,
    modality="audio", causal=False, encoder_only=True, act="gelu",
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
    vocab_size=32,
)
