from repro.configs.base import (AdapterConfig, MLAConfig, ModelConfig,  # noqa: F401
                                MoEConfig, RunConfig, SHAPES, ShapeSpec,
                                SSMConfig, TrainConfig)
from repro.configs.registry import (ARCH_IDS, all_cells,  # noqa: F401
                                    applicable_shapes, get_config,
                                    get_smoke_config)
