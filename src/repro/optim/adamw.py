"""AdamW over arbitrary trainable pytrees + LR schedules.

The *same* optimizer serves dense training and every adapter mode, because
the trainable tree already reflects the mode:

  * full / hook-mode SHiRA : trainable = the model parameters. Hook mode
    Hadamard-masks the grads (paper App. C) — moments stay dense.
  * packed SHiRA (App. D)  : trainable = (…, K) packed values, so the
    moments are packed too — that is the paper's 16% peak-memory saving,
    and under data parallelism the gradient all-reduce is over the packed
    values only (our beyond-paper collective compression; see EXPERIMENTS
    §Perf).
  * LoRA / DoRA            : trainable = {A, B[, m]} factor trees.

``sparse_adamw`` in repro/kernels fuses the packed update into one Pallas
kernel; this module is the reference implementation used under jit.
"""
from __future__ import annotations

import math
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adamw_init(trainable) -> AdamWState:
    zeros = lambda t: jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), t)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(trainable),
                      nu=zeros(trainable))


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves))) if leaves else jnp.zeros(())


def batched_global_norm(tree, batch: int) -> jax.Array:
    """Per-row global norms for a pytree whose leaves all carry the same
    leading stacked axis of size ``batch`` — the multi-adapter trainer's
    per-adapter gradient clip uses this to reproduce exactly the norm each
    adapter's grads would have in its own single-adapter run."""
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32).reshape(batch, -1)),
                      axis=1)
              for x in jax.tree.leaves(tree)]
    if not leaves:
        return jnp.zeros((batch,))
    return jnp.sqrt(jnp.sum(jnp.stack(leaves, axis=0), axis=0))


def adamw_update(grads, state: AdamWState, trainable, tcfg: TrainConfig,
                 lr: jax.Array) -> Tuple[Any, AdamWState, dict]:
    gnorm = global_norm(grads)
    if tcfg.grad_clip > 0:
        scale = jnp.minimum(1.0, tcfg.grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    step = state.step + 1
    b1, b2 = tcfg.beta1, tcfg.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / c1
        vh = v / c2
        delta = mh / (jnp.sqrt(vh) + tcfg.eps)
        if tcfg.weight_decay:
            delta = delta + tcfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat = jax.tree.map(upd, grads, state.mu, state.nu, trainable)
    new_p = jax.tree.map(lambda t: t[0], flat,
                         is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3)
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3)
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3)
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm}


def lr_schedule(tcfg: TrainConfig) -> Callable[[jax.Array], jax.Array]:
    base = tcfg.learning_rate
    warm = max(tcfg.warmup_steps, 1)
    total = max(tcfg.total_steps, warm + 1)

    def fn(step):
        s = step.astype(jnp.float32)
        warm_lr = base * (s + 1.0) / warm
        frac = jnp.clip((s - warm) / (total - warm), 0.0, 1.0)
        if tcfg.schedule == "cosine":
            post = base * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        elif tcfg.schedule == "linear":
            post = base * (1.0 - frac)
        else:
            post = jnp.full_like(s, base)
        return jnp.where(s < warm, warm_lr, post)

    return fn
