from repro.optim.adamw import (AdamWState, adamw_init, adamw_update,  # noqa: F401
                               batched_global_norm, global_norm, lr_schedule)
