from repro.optim.adamw import (AdamWState, adamw_init, adamw_update,  # noqa: F401
                               global_norm, lr_schedule)
