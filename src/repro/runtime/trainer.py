"""The trainer: adapter-aware, preemption-safe, checkpointed train loop.

One Trainer serves every mode:

  adapter.kind == "none"          -> full finetuning (trainable = params)
  adapter.kind == "shira", packed -> paper App. D: trainable = packed values
  adapter.kind == "shira", hook   -> paper App. C: trainable = params,
                                     gradients Hadamard-masked
  adapter.kind in lora/dora/...   -> factor trees

The jitted step is pure: (state, batch, masks?) -> (state, metrics); all
fault-tolerance (checkpoint cadence, preemption recovery, straggler
monitoring) lives in the host loop around it.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.checkpoint import CheckpointManager
from repro.configs.base import RunConfig
from repro.data import batch_iterator
from repro.models import lm
from repro.optim import adamw_init, adamw_update, lr_schedule
from repro.runtime.ft import SimulatedPreemption, StragglerMonitor


@dataclass
class TrainerConfig:
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    adapter_only_ckpt: bool = True   # packed adapters are ~1-2% of the model


class Trainer:
    def __init__(self, run: RunConfig, tcfg: TrainerConfig = TrainerConfig(),
                 init_key: int = 0, calib_grads=None, base_params=None):
        self.run = run
        self.tcfg = tcfg
        self.cfg = run.model
        self.acfg = run.adapter
        key = jax.random.PRNGKey(init_key)
        self.base = (base_params if base_params is not None
                     else lm.init_params(self.cfg, key))
        self.hook_mode = (self.acfg.kind == "shira" and not self.acfg.packed)

        if self.acfg.kind == "none":
            self.trainable0, self.aux = self.base, None
            self.frozen = None
        elif self.hook_mode:
            self.trainable0 = self.base
            self.masks = core.make_dense_masks(self.base, self.acfg, key,
                                               calib_grads)
            self.aux = None
            self.frozen = None
        else:
            self.trainable0, self.aux = core.init_adapter(
                key, self.base, self.acfg, calib_grads)
            self.frozen = self.base
        self.schedule = lr_schedule(run.train)
        self._step_fn = None
        self.monitor = StragglerMonitor(n_hosts=1)
        self.ckpt = (CheckpointManager(tcfg.ckpt_dir, tcfg.keep)
                     if tcfg.ckpt_dir else None)

    # -- state ---------------------------------------------------------------

    def init_state(self) -> Dict[str, Any]:
        opt = adamw_init(self.trainable0)
        return {"trainable": self.trainable0, "mu": opt.mu, "nu": opt.nu,
                "step": jnp.zeros((), jnp.int32)}

    # -- the pure step --------------------------------------------------------

    def _materialize(self, trainable):
        if self.acfg.kind == "none" or self.hook_mode:
            return trainable
        return core.materialize(self.frozen, trainable, self.aux, self.acfg,
                                alpha=1.0)

    def build_step(self) -> Callable:
        cfg, run, acfg = self.cfg, self.run, self.acfg
        hook = self.hook_mode
        masks = self.masks if hook else None

        def step_fn(state, batch):
            lr = self.schedule(state["step"])

            def loss_fn(trainable):
                eff = self._materialize(trainable)
                loss, metrics = lm.train_loss(eff, cfg, batch)
                return loss, metrics

            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state["trainable"])
            if hook:
                grads = core.mask_grads(grads, masks)
            from repro.optim.adamw import AdamWState
            new_t, opt, om = adamw_update(
                grads, AdamWState(state["step"], state["mu"], state["nu"]),
                state["trainable"], run.train, lr)
            new_state = {"trainable": new_t, "mu": opt.mu, "nu": opt.nu,
                         "step": opt.step}
            metrics = {**metrics, **om, "loss": loss, "lr": lr}
            return new_state, metrics

        return jax.jit(step_fn, donate_argnums=(0,))

    # -- host loop ------------------------------------------------------------

    def fit(self, steps: int, batches: Optional[Iterator] = None,
            state: Optional[dict] = None, resume: bool = True,
            fault_injector: Optional[Callable[[int], None]] = None,
            log: Optional[Callable[[str], None]] = print) -> Dict[str, Any]:
        if self._step_fn is None:
            self._step_fn = self.build_step()
        if batches is None:
            batches = batch_iterator(self.cfg, self.run.shape,
                                     seed=self.run.train.seed)
        state = state or self.init_state()
        start = 0
        if resume and self.ckpt and self.ckpt.latest_step() is not None:
            restored = self.ckpt.restore({"state": state})
            state, start = restored["state"], restored["step"]
            if log:
                log(f"[trainer] resumed from step {start}")

        history = []
        it = iter(batches)
        # skip already-consumed batches deterministically on resume
        for _ in range(start):
            next(it)
        s = start
        while s < steps:
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            t0 = time.perf_counter()
            try:
                if fault_injector is not None:
                    fault_injector(s)
                state, metrics = self._step_fn(state, batch)
                jax.block_until_ready(metrics["loss"])
            except SimulatedPreemption:
                if not self.ckpt or self.ckpt.latest_step() is None:
                    # restart from scratch
                    state = self.init_state()
                    it = iter(batch_iterator(self.cfg, self.run.shape,
                                             seed=self.run.train.seed))
                    s = 0
                    if log:
                        log("[trainer] preempted, no checkpoint: restarting")
                    continue
                restored = self.ckpt.restore({"state": state})
                state, s = restored["state"], restored["step"]
                it = iter(batch_iterator(self.cfg, self.run.shape,
                                         seed=self.run.train.seed,
                                         start_step=s))
                if log:
                    log(f"[trainer] preempted: restored step {s}")
                continue
            dt = time.perf_counter() - t0
            self.monitor.record(0, dt)
            history.append({k: float(v) for k, v in metrics.items()})
            if log and (s % self.tcfg.log_every == 0 or s == steps - 1):
                log(f"[trainer] step {s:5d} loss={history[-1]['loss']:.4f} "
                    f"lr={history[-1]['lr']:.2e} {dt*1e3:.0f}ms")
            s += 1
            if self.ckpt and (s % self.tcfg.ckpt_every == 0 or s == steps):
                self.ckpt.save(s, {"state": state}, meta={"arch": self.cfg.name})
        return {"state": state, "history": history}

    # -- adapter export --------------------------------------------------------

    def export_pack(self, state, name: str = "adapter") -> core.AdapterPack:
        if self.acfg.kind == "shira" and not self.hook_mode:
            return core.pack_from_shira(name, state["trainable"], self.aux)
        if self.hook_mode:
            return core.pack_from_delta(name, self.base, state["trainable"],
                                        self.acfg)
        raise ValueError(f"pack export is for SHiRA; kind={self.acfg.kind}")

    def publish(self, store, state, name: str = "adapter", *,
                step: Optional[int] = None, values: str = "f32") -> str:
        """Export the current adapter and push it into ``store`` as a fresh
        version (``name@v`` — ``AdapterStore.publish``). When the trainer
        checkpoints, the versioned pack is also snapshotted into the step
        dir (committed by the next ``ckpt.save``). Live serving engines
        resolve bare names newest-wins, so this is the hot-swap trigger."""
        from repro.analysis import trace
        pack = self.export_pack(state, name)
        with trace.span("publish.swap", cat="train", name=name):
            vid = store.publish(pack, values=values)
            if self.ckpt is not None:
                s = int(state["step"]) if step is None else step
                self.ckpt.save_adapter(s, core.AdapterPack(
                    vid, pack.entries, pack.alpha), values=values)
        return vid
