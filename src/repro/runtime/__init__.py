from repro.runtime.ft import (SimulatedPreemption, StragglerMonitor,  # noqa: F401
                              StragglerReport)
from repro.runtime.faults import (AdapterUnavailable, EngineWatchdog,  # noqa: F401
                                  FaultInjector, FaultPlan, RequestShed,
                                  ServingError, SlotPoisoned, StoreError,
                                  TableBuildError)
from repro.runtime.trainer import Trainer, TrainerConfig  # noqa: F401
