from repro.runtime.ft import (SimulatedPreemption, StragglerMonitor,  # noqa: F401
                              StragglerReport)
from repro.runtime.trainer import Trainer, TrainerConfig  # noqa: F401
