"""Serving fault model: typed errors, deterministic injection, watchdog.

``runtime/ft.py`` covers *training-side* host faults (stragglers,
preemption, elasticity). This module is its serving twin — the failure
model for the adapter-serving path (``hub.AdapterStore``, the hub
engines, ``serving.MultiTenantEngine``), in three parts:

**1. Error taxonomy.** Every failure the serving stack can surface to a
request is a subclass of :class:`ServingError`:

  * :class:`StoreError` — an adapter pack could not be loaded (disk I/O
    failure, corrupt/truncated payload, a dead prefetch worker). Carries
    ``.name`` — the adapter id that failed.
  * :class:`AdapterUnavailable` — the adapter is *known* but cannot be
    served right now: it was quarantined after repeated load failures
    (fail-fast until ``AdapterStore.clear_quarantine``).
  * :class:`RequestShed` — admission control rejected or expired the
    request (submit-queue full, per-request deadline passed in queue).
    Shed requests are never silently dropped: the typed error lands on
    their ``ServeFuture``.
  * :class:`SlotPoisoned` — non-finite logits were detected on the
    request's decode slot; only that slot is quarantined, the rest of
    the batch keeps decoding.
  * :class:`TableBuildError` — a device side-delta table build failed
    (simulated OOM under injection); the engines back off and retry the
    build on the next step instead of crashing the serving loop.

**2. Deterministic fault injection.** A :class:`FaultInjector` built
from a seeded :class:`FaultPlan` is installed module-wide (the same
null-object switchboard as ``analysis.trace``: with no injector
installed every hook is one module-global load and a fast return, so
the serving hot path pays nothing). Decisions are *stateless draws* —
``hash(seed, site, key, attempt)`` — so a given (adapter, attempt)
fails identically regardless of thread scheduling: the chaos bench is
reproducible even though loads run on worker pools. Hook points:

  ==========================  ============================================
  hook                        threaded through
  ==========================  ============================================
  ``on_disk_read(name)``      ``AdapterStore._load``: injected I/O
                              latency and :class:`InjectedIOError`
  ``corrupt_payload(...)``    ``hub.packio.load_pack``: flips a payload
                              byte so the *real* crc32 check rejects it
  ``on_worker(name)``         ``AdapterStore._prefetch_job``: prefetch
                              worker death (:class:`WorkerDeath`)
  ``on_table_build()``        ``MultiTenantEngine._build_tables``:
                              simulated OOM (:class:`TableBuildError`)
  ``poison_logits(step)``     hub engines' decode: NaN the chosen live
                              slot's logits at the chosen step
  ``on_engine_step(step)``    hub engines' ``step()``: raise
                              ``SimulatedPreemption`` (crash recovery)
  ==========================  ============================================

Injected instants land in the trace as ``fault.*`` events (cat
``fault``) so the replay model can attribute degradation windows.

**3. Watchdog.** :class:`EngineWatchdog` is the serving-side reuse of
``ft.StragglerMonitor``'s EWMA shape for a single engine loop: it
tracks an EWMA of step wall time and flags a *stall* when the gap since
the last completed step exceeds ``stall_ratio`` x the EWMA (with an
absolute floor, so cold-compile steps don't false-positive). The hub
engines export it through ``health()`` together with their
shed/degraded/poisoned counters and the store's quarantine list.

The full degradation ladder (retry -> quarantine -> fallback -> shed)
is documented in ``src/repro/runtime/README.md``.
"""
from __future__ import annotations

import hashlib
import time
import zlib
from dataclasses import dataclass, field
from threading import Lock
from typing import Dict, Optional

from repro.analysis import trace
from repro.runtime.ft import SimulatedPreemption  # noqa: F401 (re-export)

__all__ = [
    "ServingError", "StoreError", "AdapterUnavailable", "RequestShed",
    "SlotPoisoned", "TableBuildError", "InjectedIOError", "WorkerDeath",
    "FaultPlan", "FaultInjector", "EngineWatchdog", "SimulatedPreemption",
    "install", "uninstall", "active", "enabled",
    "on_disk_read", "corrupt_payload", "on_worker", "on_table_build",
    "poison_logits", "on_engine_step",
]


# ---------------------------------------------------------------------------
# Error taxonomy
# ---------------------------------------------------------------------------

class ServingError(RuntimeError):
    """Base of every typed serving failure a request can observe."""


class StoreError(ServingError):
    """An adapter pack failed to load (I/O, corruption, worker death)."""

    def __init__(self, msg: str, name: Optional[str] = None):
        super().__init__(msg)
        self.name = name


class AdapterUnavailable(ServingError):
    """The adapter is quarantined (or otherwise unservable) right now."""

    def __init__(self, msg: str, name: Optional[str] = None):
        super().__init__(msg)
        self.name = name


class RequestShed(ServingError):
    """Admission control rejected/expired the request (never silent)."""

    def __init__(self, msg: str, rid: Optional[int] = None,
                 reason: str = ""):
        super().__init__(msg)
        self.rid = rid
        self.reason = reason


class SlotPoisoned(ServingError):
    """Non-finite logits on this request's slot; the slot was quarantined."""

    def __init__(self, msg: str, rid: Optional[int] = None,
                 step: Optional[int] = None):
        super().__init__(msg)
        self.rid = rid
        self.step = step


class TableBuildError(ServingError):
    """Device table build failed (e.g. simulated OOM); retried next step."""


class InjectedIOError(OSError):
    """Injected disk-read failure (looks like a real I/O error to the
    store's retry ladder)."""


class WorkerDeath(RuntimeError):
    """Injected prefetch-worker death (a *raw* error on purpose: the
    handle/typing layer must convert it to ``StoreError``)."""


# ---------------------------------------------------------------------------
# Deterministic fault injection
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FaultPlan:
    """Seeded description of what to inject, all off by default.

    Probabilities are per *draw* (one disk read, one worker job, one
    table build); draws are stateless hashes of (seed, site, key,
    attempt), so the plan reproduces exactly across runs and thread
    schedules. ``poison_step`` poisons the logits of ONE live slot
    (``poison_slot``-th live lane, modulo the live count) at that
    engine step; ``preempt_step`` raises ``SimulatedPreemption`` out of
    ``step()`` — the crash-recovery tests' kill switch."""

    seed: int = 0
    disk_fail_p: float = 0.0        # P[disk read raises InjectedIOError]
    corrupt_p: float = 0.0          # P[payload byte flipped before crc32]
    io_latency_s: float = 0.0       # injected latency per disk read
    worker_death_p: float = 0.0     # P[prefetch worker dies mid-job]
    build_fail_p: float = 0.0       # P[table build raises TableBuildError]
    poison_step: Optional[int] = None
    poison_slot: int = 0
    preempt_step: Optional[int] = None


class FaultInjector:
    """Executes a :class:`FaultPlan`; install via ``faults.install``.

    Thread-safe. ``counts`` tallies injected events by kind (what the
    chaos bench reports)."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.counts: Dict[str, int] = {}
        self._attempts: Dict[tuple, int] = {}   # (site, key) -> draw count
        self._poison_fired = False
        self._preempt_fired = False
        self._lock = Lock()

    # -- deterministic draws -------------------------------------------

    def _draw(self, site: str, key: str) -> float:
        """Uniform [0, 1) from (seed, site, key, attempt#) — independent
        of thread scheduling; a retried key gets a fresh draw. sha256,
        not crc32: crc is linear, so draws for consecutive attempts
        would differ by a XOR *constant* — correlated enough that a
        retry could never succeed where the first attempt failed."""
        with self._lock:
            n = self._attempts.get((site, key), 0)
            self._attempts[(site, key)] = n + 1
        digest = hashlib.sha256(
            f"{self.plan.seed}:{site}:{key}:{n}".encode()).digest()
        return int.from_bytes(digest[:4], "big") / 2.0 ** 32

    def _count(self, kind: str) -> None:
        with self._lock:
            self.counts[kind] = self.counts.get(kind, 0) + 1
        trace.instant(f"fault.{kind}", cat="fault")

    # -- hook bodies ---------------------------------------------------

    def on_disk_read(self, name: str) -> None:
        if self.plan.io_latency_s > 0:
            self._count("io_latency")
            time.sleep(self.plan.io_latency_s)
        if self.plan.disk_fail_p > 0 \
                and self._draw("disk", name) < self.plan.disk_fail_p:
            self._count("disk_fail")
            raise InjectedIOError(f"injected disk-read failure for "
                                  f"adapter {name!r}")

    def corrupt_payload(self, path: str, payload: bytes) -> bytes:
        if self.plan.corrupt_p > 0 and payload \
                and self._draw("corrupt", path) < self.plan.corrupt_p:
            self._count("corrupt")
            pos = zlib.crc32(path.encode()) % len(payload)
            flipped = bytearray(payload)
            flipped[pos] ^= 0xFF
            return bytes(flipped)
        return payload

    def on_worker(self, name: str) -> None:
        if self.plan.worker_death_p > 0 \
                and self._draw("worker", name) < self.plan.worker_death_p:
            self._count("worker_death")
            raise WorkerDeath(f"injected prefetch-worker death loading "
                              f"{name!r}")

    def on_table_build(self) -> None:
        if self.plan.build_fail_p > 0 \
                and self._draw("build", "tables") < self.plan.build_fail_p:
            self._count("build_fail")
            raise TableBuildError("injected device-table build failure "
                                  "(simulated OOM)")

    def poison_logits(self, step: int) -> Optional[int]:
        """Fires ONCE, at the first decode whose step reaches
        ``poison_step`` (an exact-step match would silently miss when
        that step had no live decode)."""
        if self.plan.poison_step is None or step < self.plan.poison_step \
                or self._poison_fired:
            return None
        self._poison_fired = True
        self._count("poison")
        return self.plan.poison_slot

    def on_engine_step(self, step: int) -> None:
        """Fires ONCE, at the first engine step reaching
        ``preempt_step`` — a rebuilt engine restarting from step 0 is
        not re-killed by the same injector."""
        if self.plan.preempt_step is None or step < self.plan.preempt_step \
                or self._preempt_fired:
            return
        self._preempt_fired = True
        self._count("preempt")
        raise SimulatedPreemption(f"injected preemption at engine "
                                  f"step {step}")


# ---------------------------------------------------------------------------
# Module-level switchboard (the hooks the serving path calls)
# ---------------------------------------------------------------------------

_injector: Optional[FaultInjector] = None


def install(plan_or_injector) -> FaultInjector:
    """Install (and return) the active injector. Hooks fire until
    ``uninstall()``. Accepts a ``FaultPlan`` or a ``FaultInjector``."""
    global _injector
    if isinstance(plan_or_injector, FaultPlan):
        plan_or_injector = FaultInjector(plan_or_injector)
    _injector = plan_or_injector
    return _injector


def uninstall() -> Optional[FaultInjector]:
    """Disable injection; returns the injector that was active (if any)."""
    global _injector
    inj, _injector = _injector, None
    return inj


def active() -> Optional[FaultInjector]:
    return _injector


def enabled() -> bool:
    return _injector is not None


def on_disk_read(name: str) -> None:
    inj = _injector
    if inj is not None:
        inj.on_disk_read(name)


def corrupt_payload(path: str, payload: bytes) -> bytes:
    inj = _injector
    if inj is None:
        return payload
    return inj.corrupt_payload(path, payload)


def on_worker(name: str) -> None:
    inj = _injector
    if inj is not None:
        inj.on_worker(name)


def on_table_build() -> None:
    inj = _injector
    if inj is not None:
        inj.on_table_build()


def poison_logits(step: int) -> Optional[int]:
    inj = _injector
    if inj is None:
        return None
    return inj.poison_logits(step)


def on_engine_step(step: int) -> None:
    inj = _injector
    if inj is not None:
        inj.on_engine_step(step)


# ---------------------------------------------------------------------------
# Engine watchdog
# ---------------------------------------------------------------------------

@dataclass
class EngineWatchdog:
    """EWMA step-stall detector for one serving loop — the single-engine
    reuse of ``ft.StragglerMonitor``'s shape (EWMA + a ratio guard so
    tiny variance never false-positives).

    The engine calls ``record(seconds)`` after every completed step;
    ``snapshot(now)`` exports the health view: the loop is *stalled*
    when the time since the last completed step exceeds
    ``max(stall_ratio * ewma, min_stall_s)``. ``clock`` is injectable
    for deterministic tests."""

    alpha: float = 0.3
    stall_ratio: float = 10.0
    min_stall_s: float = 1.0
    clock: "object" = time.monotonic
    steps: int = 0
    ewma_s: Optional[float] = None
    last_step_s: Optional[float] = None
    last_end_t: Optional[float] = field(default=None, repr=False)

    def record(self, seconds: float) -> None:
        self.steps += 1
        self.last_step_s = seconds
        self.ewma_s = (seconds if self.ewma_s is None
                       else self.alpha * seconds
                       + (1 - self.alpha) * self.ewma_s)
        self.last_end_t = self.clock()

    def since_last_step(self, now: Optional[float] = None) -> float:
        if self.last_end_t is None:
            return 0.0
        return max((self.clock() if now is None else now)
                   - self.last_end_t, 0.0)

    def stalled(self, now: Optional[float] = None) -> bool:
        if self.ewma_s is None:
            return False
        gap = self.since_last_step(now)
        return gap > max(self.stall_ratio * self.ewma_s, self.min_stall_s)

    def snapshot(self, now: Optional[float] = None) -> Dict[str, object]:
        return {"steps": self.steps, "ewma_step_s": self.ewma_s,
                "last_step_s": self.last_step_s,
                "since_last_step_s": self.since_last_step(now),
                "stalled": self.stalled(now)}
