"""Fault-tolerance primitives: straggler detection, preemption, elasticity.

These are *host-level* mechanisms (device-level resilience is covered by
checkpoint/restart + the resharding restore). On a real multi-pod job every
host runs the same SPMD program; the coordinator-side logic here consumes
per-host step timings and decides:

  * stragglers: hosts whose EWMA step time z-scores out of the fleet
    distribution -> flagged for data re-assignment or replacement,
  * bounded-staleness barrier: how long to wait for lagging hosts before
    declaring them failed (and restarting from the last checkpoint),
  * elasticity: on a fleet-size change, training resumes from the latest
    checkpoint on a rebuilt mesh (repro/launch/mesh.py) — the checkpoint
    format is mesh-independent by construction.

All of it is deterministic, dependency-free and unit-tested with synthetic
clocks (no real multi-host fabric exists in this container).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional


class SimulatedPreemption(RuntimeError):
    """Raised by fault injectors in tests/examples to emulate a node loss."""


@dataclass
class StragglerReport:
    step: int
    host_ewma: Dict[int, float]
    stragglers: List[int]
    fleet_mean: float
    fleet_std: float

    @property
    def healthy(self) -> bool:
        return not self.stragglers


class StragglerMonitor:
    """EWMA per-host step-time tracker with z-score straggler flagging.

    A host is a straggler when its EWMA step time exceeds
    ``fleet_mean + z_thresh * fleet_std`` AND is ``min_ratio`` x the fleet
    mean (the second guard avoids flagging noise when variance is tiny).
    """

    def __init__(self, n_hosts: int, alpha: float = 0.3,
                 z_thresh: float = 3.0, min_ratio: float = 1.3):
        self.n_hosts = n_hosts
        self.alpha = alpha
        self.z_thresh = z_thresh
        self.min_ratio = min_ratio
        self.ewma: Dict[int, float] = {}
        self.step = 0

    def record(self, host: int, seconds: float) -> None:
        prev = self.ewma.get(host)
        self.ewma[host] = (seconds if prev is None
                           else self.alpha * seconds + (1 - self.alpha) * prev)

    def end_step(self) -> StragglerReport:
        self.step += 1
        vals = list(self.ewma.values())
        mean = sum(vals) / max(len(vals), 1)
        var = sum((v - mean) ** 2 for v in vals) / max(len(vals), 1)
        std = math.sqrt(var)
        stragglers = [h for h, v in self.ewma.items()
                      if v > mean + self.z_thresh * std
                      and v > self.min_ratio * mean]
        return StragglerReport(self.step, dict(self.ewma), sorted(stragglers),
                               mean, std)

    def rebalance_plan(self, report: StragglerReport,
                       shards_per_host: int) -> Dict[int, int]:
        """Propose data-shard counts per host inversely proportional to the
        EWMA step time (straggler mitigation by work re-assignment)."""
        if not report.host_ewma:
            return {}
        inv = {h: 1.0 / max(v, 1e-9) for h, v in report.host_ewma.items()}
        total_inv = sum(inv.values())
        total_shards = shards_per_host * len(inv)
        plan = {h: max(1, round(total_shards * w / total_inv))
                for h, w in inv.items()}
        # fix rounding drift deterministically
        drift = total_shards - sum(plan.values())
        for h in sorted(plan, key=lambda x: -inv[x]):
            if drift == 0:
                break
            plan[h] += 1 if drift > 0 else -1
            drift += -1 if drift > 0 else 1
        return plan


@dataclass
class BoundedBarrier:
    """Decide whether to keep waiting for lagging hosts or declare failure."""

    timeout_s: float = 300.0
    grace_ratio: float = 5.0      # wait up to grace_ratio * fleet mean step

    def should_abort(self, waited_s: float, fleet_mean_step_s: float) -> bool:
        return (waited_s > self.timeout_s
                or waited_s > self.grace_ratio * max(fleet_mean_step_s, 1e-3))
