"""Mamba2 mixer via SSD (state-space duality), chunked for TPU.

Training / prefill use the chunked SSD algorithm: quadratic attention-like
compute inside chunks of length ``chunk`` plus an associative scan over chunk
states (so a 500k-token sequence never materialises an S x S object).
Decode is the O(1) recurrent update on a (B, H, P, N) state plus a rolling
depthwise-conv window.

Sharding note (found via the dry-run, recorded in EXPERIMENTS §Perf): the
reference implementation fuses z/x/B/C/dt into ONE in_proj and slices the
output. Under tensor parallelism the slice boundaries don't align with the
shard boundaries, so GSPMD reshards (all-gathers) the full projection every
layer. We therefore keep SEPARATE projections: in_z / in_x (column-sharded,
d_inner), in_bc (replicated, 2*g*n is tiny), in_dt (column-sharded, H) —
depthwise conv splits the same way (conv_x sharded, conv_bc replicated).
This is numerically identical and shard-aligned end to end.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import compute_dtype, dense, glorot


class MambaCache(NamedTuple):
    ssm: jax.Array      # (B, H, P, N) f32
    conv_x: jax.Array   # (B, d_conv - 1, d_inner)
    conv_bc: jax.Array  # (B, d_conv - 1, 2 * g * n)


def dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    bc_dim = 2 * s.n_groups * s.d_state
    return d_inner, n_heads, bc_dim


def init_mamba(key, cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_inner, n_heads, bc_dim = dims(cfg)
    ks = jax.random.split(key, 8)
    dt = jnp.exp(jax.random.uniform(ks[0], (n_heads,), jnp.float32)
                 * (math.log(s.dt_max) - math.log(s.dt_min))
                 + math.log(s.dt_min))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    conv_scale = 1.0 / math.sqrt(s.d_conv)
    return {
        "in_z": glorot(ks[1], (d, d_inner)),
        "in_x": glorot(ks[2], (d, d_inner)),
        "in_bc": glorot(ks[3], (d, bc_dim)),
        "in_dt": glorot(ks[4], (d, n_heads)),
        "conv_x_w": jax.random.normal(ks[5], (s.d_conv, d_inner),
                                      jnp.float32) * conv_scale,
        "conv_x_b": jnp.zeros((d_inner,), jnp.float32),
        "conv_bc_w": jax.random.normal(ks[6], (s.d_conv, bc_dim),
                                       jnp.float32) * conv_scale,
        "conv_bc_b": jnp.zeros((bc_dim,), jnp.float32),
        "A_log": jnp.log(jnp.arange(1, n_heads + 1, dtype=jnp.float32)),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": dt_bias,
        "norm": {"scale": jnp.ones((d_inner,), jnp.float32)},
        "out_proj": glorot(ks[7], (d_inner, d)),
    }


def _causal_conv(x, w, b):
    """x: (B, S, C); w: (K, C) depthwise. Unrolled over the tiny K."""
    K = w.shape[0]
    S = x.shape[1]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(xp[:, i:i + S, :] * w[i].astype(x.dtype) for i in range(K))
    return jax.nn.silu((y + b.astype(x.dtype)).astype(jnp.float32)
                       ).astype(compute_dtype())


def _project(params, cfg, u):
    """u: (B, S, d) -> z, x_raw, bc_raw, dt  (pre-conv, pre-softplus-dt)."""
    z = dense(u, params["in_z"])
    x_raw = dense(u, params["in_x"])
    bc_raw = dense(u, params["in_bc"])
    dt_raw = dense(u, params["in_dt"])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    return z, x_raw, bc_raw, dt


def _gated_out(params, cfg, y, z):
    """RMSNorm(y * silu(z)) @ out_proj."""
    g = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(g * g, axis=-1, keepdims=True)
    g = g * jax.lax.rsqrt(var + cfg.norm_eps) * params["norm"]["scale"]
    return dense(g.astype(compute_dtype()), params["out_proj"])


# ---------------------------------------------------------------------------
# Chunked SSD
# ---------------------------------------------------------------------------

def _segsum(x):
    """x: (..., L) log-decays -> (..., L, L) lower-tri cumulative sums."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(L)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int,
                initial_state=None) -> Tuple[jax.Array, jax.Array]:
    """SSD scan. x: (b,s,h,p); dt: (b,s,h); A: (h,); B,C: (b,s,g,n).

    Returns (y: (b,s,h,p), final_state: (b,h,p,n)). Everything f32 inside.
    """
    b, s_orig, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hg = h // g
    # Pad to a chunk multiple: dt=0 at pad positions => decay 1, no state
    # update, so the scan semantics are unchanged (pad outputs are sliced off).
    pad = (-s_orig) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s = s_orig + pad
    nc = s // chunk

    xf = (x.astype(jnp.float32) * dt[..., None])          # X * dt
    dA = dt * A[None, None, :]                            # (b,s,h) log decays
    xc = xf.reshape(b, nc, chunk, g, hg, p)
    dAc = dA.reshape(b, nc, chunk, h)
    Bc = B.astype(jnp.float32).reshape(b, nc, chunk, g, n)
    Cc = C.astype(jnp.float32).reshape(b, nc, chunk, g, n)

    dA_cs = jnp.cumsum(dAc, axis=2)                       # (b,nc,l,h)

    # --- intra-chunk (attention-like) ---
    Ldec = jnp.exp(_segsum(jnp.moveaxis(dAc, 3, 2)))      # (b,nc,h,l,l)
    Ldec = jnp.moveaxis(Ldec, 2, 4)                       # (b,nc,l,l,h)
    CB = jnp.einsum("bclgn,bcsgn->bclsg", Cc, Bc)         # (b,nc,l,l,g)
    att = CB.reshape(b, nc, chunk, chunk, g, 1) * \
        Ldec.reshape(b, nc, chunk, chunk, g, hg)
    y_diag = jnp.einsum("bclsgh,bcsghp->bclghp", att, xc)

    # --- chunk states ---
    decay_to_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)   # (b,nc,l,h)
    dte = decay_to_end.reshape(b, nc, chunk, g, hg)
    states = jnp.einsum("bclgn,bclgh,bclghp->bcghpn", Bc, dte, xc)
    states = states.reshape(b, nc, h, p, n)

    # --- associative scan over chunks ---
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])             # (b,nc,h)
    if initial_state is not None:
        init = initial_state.astype(jnp.float32)[:, None]  # (b,1,h,p,n)
        states = jnp.concatenate([init, states], axis=1)
        chunk_decay = jnp.concatenate(
            [jnp.ones((b, 1, h), jnp.float32), chunk_decay], axis=1)

    def combine(lhs, rhs):
        a1, s1 = lhs
        a2, s2 = rhs
        return a1 * a2, s2 + a2[..., None, None] * s1

    acc_decay, acc_states = jax.lax.associative_scan(
        combine, (chunk_decay, states), axis=1)
    if initial_state is not None:
        acc_states = acc_states[:, 1:]
    final_state = acc_states[:, -1]                       # (b,h,p,n)
    # state entering chunk c = accumulated state through chunk c-1
    prev = jnp.concatenate(
        [jnp.zeros_like(acc_states[:, :1]) if initial_state is None
         else initial_state.astype(jnp.float32)[:, None],
         acc_states[:, :-1]], axis=1)                     # (b,nc,h,p,n)

    # --- inter-chunk output ---
    out_decay = jnp.exp(dA_cs).reshape(b, nc, chunk, g, hg)
    prevg = prev.reshape(b, nc, g, hg, p, n)
    y_off = jnp.einsum("bclgn,bclgh,bcghpn->bclghp", Cc, out_decay, prevg)

    y = (y_diag + y_off).reshape(b, s, h, p)[:, :s_orig]
    return y.astype(compute_dtype()), final_state


# ---------------------------------------------------------------------------
# Module entry points
# ---------------------------------------------------------------------------

def _ssd_from_parts(params, cfg, xBC_x, xBC_bc, dt, B_, S_):
    s = cfg.ssm
    d_inner, n_heads, bc_dim = dims(cfg)
    x = xBC_x.reshape(B_, S_, n_heads, s.head_dim)
    gn = s.n_groups * s.d_state
    Bm = xBC_bc[..., :gn].reshape(B_, S_, s.n_groups, s.d_state)
    Cm = xBC_bc[..., gn:].reshape(B_, S_, s.n_groups, s.d_state)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    y, final_state = ssd_chunked(x, dt, A, Bm, Cm, s.chunk)
    y = y + (params["D"].astype(jnp.float32)[None, None, :, None]
             * x.astype(jnp.float32)).astype(compute_dtype())
    return y, final_state


def mamba_train(params, cfg: ModelConfig, u):
    d_inner, _, _ = dims(cfg)
    B_, S_, _ = u.shape
    z, x_raw, bc_raw, dt = _project(params, cfg, u)
    xx = _causal_conv(x_raw, params["conv_x_w"], params["conv_x_b"])
    bc = _causal_conv(bc_raw, params["conv_bc_w"], params["conv_bc_b"])
    y, _ = _ssd_from_parts(params, cfg, xx, bc, dt, B_, S_)
    return _gated_out(params, cfg, y.reshape(B_, S_, d_inner), z)


def mamba_prefill(params, cfg: ModelConfig, u) -> Tuple[jax.Array, MambaCache]:
    s = cfg.ssm
    d_inner, _, _ = dims(cfg)
    B_, S_, _ = u.shape
    z, x_raw, bc_raw, dt = _project(params, cfg, u)
    conv_x_state = x_raw[:, S_ - (s.d_conv - 1):, :].astype(compute_dtype())
    conv_bc_state = bc_raw[:, S_ - (s.d_conv - 1):, :].astype(compute_dtype())
    xx = _causal_conv(x_raw, params["conv_x_w"], params["conv_x_b"])
    bc = _causal_conv(bc_raw, params["conv_bc_w"], params["conv_bc_b"])
    y, final_state = _ssd_from_parts(params, cfg, xx, bc, dt, B_, S_)
    out = _gated_out(params, cfg, y.reshape(B_, S_, d_inner), z)
    return out, MambaCache(ssm=final_state, conv_x=conv_x_state,
                           conv_bc=conv_bc_state)


def _conv_step(window, new, w, b):
    """window: (B, K-1, C); new: (B, 1, C) -> (out (B, C), new window)."""
    win = jnp.concatenate([window, new.astype(window.dtype)], axis=1)
    out = jnp.sum(win.astype(jnp.float32) * w.astype(jnp.float32)[None],
                  axis=1) + b.astype(jnp.float32)
    return jax.nn.silu(out).astype(compute_dtype()), win[:, 1:]


def mamba_decode(params, cfg: ModelConfig, u,
                 cache: MambaCache, pos) -> Tuple[jax.Array, MambaCache]:
    """u: (B, 1, d)."""
    s = cfg.ssm
    d_inner, n_heads, bc_dim = dims(cfg)
    B_ = u.shape[0]
    z, x_raw, bc_raw, dt = _project(params, cfg, u)    # (B,1,·)
    xx, new_conv_x = _conv_step(cache.conv_x, x_raw,
                                params["conv_x_w"], params["conv_x_b"])
    bc, new_conv_bc = _conv_step(cache.conv_bc, bc_raw,
                                 params["conv_bc_w"], params["conv_bc_b"])

    x = xx.reshape(B_, n_heads, s.head_dim)
    gn = s.n_groups * s.d_state
    Bm = bc[:, :gn].reshape(B_, s.n_groups, s.d_state)
    Cm = bc[:, gn:].reshape(B_, s.n_groups, s.d_state)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dt1 = dt[:, 0]                                     # (B,H)
    dA = jnp.exp(dt1 * A[None])                        # (B,H)
    hg = n_heads // s.n_groups
    Bh = jnp.repeat(Bm, hg, axis=1)                    # (B,H,N)
    Ch = jnp.repeat(Cm, hg, axis=1)
    xdt = x.astype(jnp.float32) * dt1[..., None]       # (B,H,P)
    new_state = cache.ssm * dA[..., None, None] \
        + xdt[..., :, None] * Bh.astype(jnp.float32)[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch.astype(jnp.float32))
    y = y + params["D"].astype(jnp.float32)[None, :, None] * x.astype(jnp.float32)
    y = y.reshape(B_, 1, d_inner).astype(compute_dtype())
    out = _gated_out(params, cfg, y, z)
    return out, MambaCache(ssm=new_state, conv_x=new_conv_x,
                           conv_bc=new_conv_bc)
