"""Causal LM assembly: stages of scanned blocks + embeddings + chunked loss.

Depth is folded into ``jax.lax.scan`` over stacked layer parameters, so the
HLO is O(1) in the number of layers (critical for 88-layer configs). A model
is a list of *stages*; each stage is a homogeneous stack of blocks:

  dense family     -> [("dense", L)]
  moe family       -> [("dense", first_dense)] + [("moe", rest)]
  ssm family       -> [("mamba", L)]
  hybrid (zamba2)  -> [("hybrid", L)]  groups of `hybrid_attn_every` mamba
                       layers followed by the shared attention block

Entry points:
  init_params(cfg, key)
  train_loss(params, cfg, batch)                  -> (loss, metrics)
  prefill(params, cfg, batch, cache_size)         -> (last_logits, caches)
  decode_step(params, cfg, tokens, caches, pos)   -> (logits, caches)
  init_cache(cfg, batch, cache_size)              -> caches (zeros)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks as B
from repro.models.attention import KVCache
from repro.models.layers import (compute_dtype, cross_entropy, embed,
                                 init_embedding, init_rms_norm, normal_init,
                                 rms_norm, unembed)
from repro.models.mamba2 import MambaCache, dims as mamba_dims
from repro.launch.actctx import shard_act


# ---------------------------------------------------------------------------
# Stage planning
# ---------------------------------------------------------------------------

def stage_plan(cfg: ModelConfig) -> List[Tuple[str, int]]:
    if cfg.family in ("dense", "vlm", "audio"):
        return [("dense", cfg.num_layers)]
    if cfg.family == "moe":
        plan = []
        fd = cfg.moe.first_dense_layers
        if fd:
            plan.append(("dense_first", fd))
        plan.append(("moe", cfg.num_layers - fd))
        return plan
    if cfg.family == "ssm":
        return [("mamba", cfg.num_layers)]
    if cfg.family == "hybrid":
        return [("hybrid", cfg.num_layers)]
    raise ValueError(cfg.family)


def _block_fns(kind: str):
    base = "dense" if kind == "dense_first" else kind
    return B.BLOCK_FNS[base]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_stage(key, cfg: ModelConfig, kind: str, n: int):
    if kind == "hybrid":
        k = cfg.hybrid_attn_every
        assert n % k == 0, f"layers {n} % hybrid_attn_every {k} != 0"
        g = n // k
        keys = jax.random.split(key, n)
        stacked = jax.vmap(lambda kk: B.init_mamba_block(kk, cfg))(keys)
        return jax.tree.map(lambda x: x.reshape((g, k) + x.shape[1:]), stacked)
    init_fn = _block_fns(kind)[0]
    if kind == "dense_first":
        init_fn = functools.partial(B.init_dense_block,
                                    d_ff=cfg.moe.first_dense_d_ff)
    keys = jax.random.split(key, n)
    return jax.vmap(lambda kk: init_fn(kk, cfg))(keys)


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    plan = stage_plan(cfg)
    keys = jax.random.split(key, len(plan) + 3)
    params: Dict[str, Any] = {
        "embed": init_embedding(keys[0], cfg.padded_vocab, cfg.d_model),
        "final_norm": init_rms_norm(cfg.d_model),
        "stages": [_init_stage(keys[2 + i], cfg, kind, n)
                   for i, (kind, n) in enumerate(plan)],
    }
    if not cfg.tie_embeddings:
        params["unembed"] = {
            "lm_head": normal_init(keys[1], (cfg.d_model, cfg.padded_vocab))}
    if cfg.family == "hybrid":
        params["shared_attn"] = B.init_shared_attn(keys[-1], cfg)
    return params


# ---------------------------------------------------------------------------
# Remat policy
# ---------------------------------------------------------------------------

def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Embedding (+ modality stubs)
# ---------------------------------------------------------------------------

def embed_inputs(params, cfg: ModelConfig, batch) -> Tuple[jax.Array, int]:
    """Returns (h, prefix_len). VLM prepends precomputed patch embeddings;
    audio consumes precomputed frame embeddings directly."""
    if cfg.modality == "audio":
        return batch["frame_embeds"].astype(compute_dtype()), 0
    h = embed(params["embed"], batch["tokens"])
    if cfg.modality == "vision":
        patches = batch["patch_embeds"].astype(compute_dtype())
        h = jnp.concatenate([patches, h], axis=1)
        return h, patches.shape[1]
    return h, 0


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------

def _stage_train(stage_params, kind, cfg, h, aux, prefix_len, shared=None):
    if kind == "hybrid":
        emb = h  # hybrid shared block sees the original embedding stream
        k = cfg.hybrid_attn_every

        def group_body(carry, gp):
            hh, ax = carry
            hh = shard_act(hh)

            def inner(h2, lp):
                h2, _ = B.mamba_block_train(lp, cfg, h2)
                return shard_act(h2), None

            hh, _ = jax.lax.scan(inner, hh, gp)
            hh = B.shared_attn_train(shared, cfg, hh, emb)
            return (hh, ax), None

        body = _maybe_remat(group_body, cfg)
        (h, aux), _ = jax.lax.scan(body, (h, aux), stage_params)
        return h, aux

    train_fn = _block_fns(kind)[1]

    def body(carry, lp):
        hh, ax = carry
        hh = shard_act(hh)
        hh, ax = train_fn(lp, cfg, hh, prefix_len=prefix_len, aux=ax)
        return (hh, ax), None

    body = _maybe_remat(body, cfg)
    (h, aux), _ = jax.lax.scan(body, (h, aux), stage_params)
    return h, aux


def _pick_chunk(total: int, target: int = 32_768) -> int:
    c = min(total, target)
    while total % c:
        c -= 1
    return c


def chunked_loss(params, cfg: ModelConfig, h, labels,
                 loss_mask=None) -> jax.Array:
    """Never materialises the full (T, vocab) logits tensor."""
    Bq, S, d = h.shape
    T = Bq * S
    hf = h.reshape(T, d)
    lf = labels.reshape(T)
    mf = (jnp.ones((T,), jnp.float32) if loss_mask is None
          else loss_mask.reshape(T).astype(jnp.float32))
    tie = params["embed"]["emb"] if cfg.tie_embeddings else None
    un = params.get("unembed")
    c = _pick_chunk(T)
    n = T // c

    def body(carry, xs):
        hc, lc, mc = xs
        from repro.launch.actctx import shard_as
        # gather the bf16 hidden chunk over `model` once instead of letting
        # XLA psum f32 logits (6x less collective traffic, see §Perf)
        hc = shard_as(hc, "loss_act")
        logits = unembed(un, hc, tie_to=tie, softcap=cfg.logit_softcap,
                         logical_vocab=cfg.vocab_size)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[:, None], axis=-1)[:, 0]
        nll = (logz - gold) * mc
        return carry + jnp.sum(nll), None

    # remat: recompute the (Tc, V) logits in backward instead of saving all
    # n chunks of them (226 GB/device at mamba2 train_4k scale — see §Perf).
    body = jax.checkpoint(body)
    total, _ = jax.lax.scan(
        body, jnp.zeros((), jnp.float32),
        (hf.reshape(n, c, d), lf.reshape(n, c), mf.reshape(n, c)))
    return total / jnp.maximum(jnp.sum(mf), 1.0)


def train_loss(params, cfg: ModelConfig, batch) -> Tuple[jax.Array, Dict]:
    h, prefix_len = embed_inputs(params, cfg, batch)
    aux = jnp.zeros((), jnp.float32)
    for sp, (kind, _) in zip(params["stages"], stage_plan(cfg)):
        h, aux = _stage_train(sp, kind, cfg, h, aux, prefix_len,
                              shared=params.get("shared_attn"))
    h = rms_norm(h, params["final_norm"]["scale"], cfg.norm_eps)
    labels = batch["labels"]
    if cfg.modality == "vision":  # loss only over the text suffix
        h = h[:, prefix_len:]
    loss = chunked_loss(params, cfg, h, labels, batch.get("loss_mask"))
    metrics = {"ce": loss, "aux": aux}
    if cfg.family == "moe" or (cfg.moe and cfg.moe.num_experts):
        loss = loss + 0.01 * aux
    return loss, metrics


def encode(params, cfg: ModelConfig, batch):
    """Encoder-only serving (hubert): full-sequence frame logits, no cache."""
    h, prefix_len = embed_inputs(params, cfg, batch)
    aux = jnp.zeros((), jnp.float32)
    for sp, (kind, _) in zip(params["stages"], stage_plan(cfg)):
        h, aux = _stage_train(sp, kind, cfg, h, aux, prefix_len,
                              shared=params.get("shared_attn"))
    h = rms_norm(h, params["final_norm"]["scale"], cfg.norm_eps)
    tie = params["embed"]["emb"] if cfg.tie_embeddings else None
    return unembed(params.get("unembed"), h, tie_to=tie,
                   softcap=cfg.logit_softcap, logical_vocab=cfg.vocab_size)


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------

def _stage_prefill(stage_params, kind, cfg, h, cache_size, prefix_len,
                   shared=None):
    if kind == "hybrid":
        emb = h
        k = cfg.hybrid_attn_every

        def group_body(hh, gp):
            def inner(h2, lp):
                return B.mamba_block_prefill(lp, cfg, h2, cache_size)

            hh, mcaches = jax.lax.scan(inner, hh, gp)
            hh, acache = B.shared_attn_prefill(shared, cfg, hh, emb, cache_size)
            return hh, {"mamba": mcaches, "attn": acache}

        h, caches = jax.lax.scan(group_body, h, stage_params)
        return h, caches

    prefill_fn = _block_fns(kind)[2]

    def body(hh, lp):
        return prefill_fn(lp, cfg, shard_act(hh), cache_size,
                          prefix_len=prefix_len)

    h, caches = jax.lax.scan(body, h, stage_params)
    return h, caches


def prefill(params, cfg: ModelConfig, batch, cache_size: int):
    h, prefix_len = embed_inputs(params, cfg, batch)
    caches = []
    for sp, (kind, _) in zip(params["stages"], stage_plan(cfg)):
        h, cache = _stage_prefill(sp, kind, cfg, h, cache_size, prefix_len,
                                  shared=params.get("shared_attn"))
        caches.append(cache)
    h = rms_norm(h, params["final_norm"]["scale"], cfg.norm_eps)
    last = h[:, -1]
    tie = params["embed"]["emb"] if cfg.tie_embeddings else None
    logits = unembed(params.get("unembed"), last, tie_to=tie,
                     softcap=cfg.logit_softcap, logical_vocab=cfg.vocab_size)
    return logits, caches


def _stage_decode(stage_params, kind, cfg, h, caches, pos, shared=None,
                  block_tables=None):
    if kind == "hybrid":
        emb = h

        def group_body(hh, xs):
            gp, gc = xs

            def inner(h2, xs2):
                lp, c = xs2
                return B.mamba_block_decode(lp, cfg, h2, c, pos)

            hh, mcaches = jax.lax.scan(inner, hh, (gp, gc["mamba"]))
            hh, acache = B.shared_attn_decode(shared, cfg, hh, emb,
                                              gc["attn"], pos)
            return hh, {"mamba": mcaches, "attn": acache}

        h, new = jax.lax.scan(group_body, h, (stage_params, caches))
        return h, new

    decode_fn = _block_fns(kind)[3]

    def body(hh, xs):
        lp, c = xs
        return decode_fn(lp, cfg, hh, c, pos, block_tables=block_tables)

    h, new = jax.lax.scan(body, h, (stage_params, caches))
    return h, new


def decode_step(params, cfg: ModelConfig, tokens, caches, pos,
                block_tables=None):
    """tokens: (B, 1) int32; pos: scalar cache index shared by the batch, or
    a (B,) int32 vector of per-request indices (continuous batching — every
    slot decodes at its own depth). With ``block_tables`` ((B, nblk) int32)
    the caches are page pools (see ``init_paged_cache``) and ``pos`` must be
    the (B,) per-request write index. Returns (logits (B, V), new caches)."""
    if block_tables is not None and cfg.family == "hybrid":
        raise NotImplementedError("paged decode covers attention caches only")
    h = embed(params["embed"], tokens)
    new_caches = []
    for sp, cache, (kind, _) in zip(params["stages"], caches, stage_plan(cfg)):
        h, nc = _stage_decode(sp, kind, cfg, h, cache, pos,
                              shared=params.get("shared_attn"),
                              block_tables=block_tables)
        new_caches.append(nc)
    h = rms_norm(h, params["final_norm"]["scale"], cfg.norm_eps)
    tie = params["embed"]["emb"] if cfg.tie_embeddings else None
    logits = unembed(params.get("unembed"), h[:, 0], tie_to=tie,
                     softcap=cfg.logit_softcap, logical_vocab=cfg.vocab_size)
    return logits, new_caches


def _stage_prefill_chunk(stage_params, kind, cfg, h, caches, block_tables,
                         start, kv_len):
    fn = (B.moe_block_prefill_chunk if kind == "moe"
          else B.dense_block_prefill_chunk)

    def body(hh, xs):
        lp, c = xs
        return fn(lp, cfg, shard_act(hh), c, block_tables, start, kv_len)

    h, new = jax.lax.scan(body, h, (stage_params, caches))
    return h, new


def prefill_chunk(params, cfg: ModelConfig, tokens, caches, block_tables,
                  start, valid):
    """One padded chunk of a paged prefill.

    tokens: (B, C) int32, columns at absolute positions ``start + i``;
    ``valid`` (traced scalar) counts the real tokens — padding columns
    write to the scratch page and are masked out of attention. Returns
    (logits of the last real token (B, V), updated pool caches)."""
    if cfg.family not in ("dense", "moe") or cfg.modality != "text":
        raise NotImplementedError(
            "chunked paged prefill covers dense/moe text models")
    h = embed(params["embed"], tokens)
    kv_len = start + valid
    new_caches = []
    for sp, cache, (kind, _) in zip(params["stages"], caches, stage_plan(cfg)):
        h, nc = _stage_prefill_chunk(sp, kind, cfg, h, cache, block_tables,
                                     start, kv_len)
        new_caches.append(nc)
    h = rms_norm(h, params["final_norm"]["scale"], cfg.norm_eps)
    last = jax.lax.dynamic_slice_in_dim(h, valid - 1, 1, axis=1)[:, 0]
    tie = params["embed"]["emb"] if cfg.tie_embeddings else None
    logits = unembed(params.get("unembed"), last, tie_to=tie,
                     softcap=cfg.logit_softcap, logical_vocab=cfg.vocab_size)
    return logits, new_caches


# ---------------------------------------------------------------------------
# Cache construction (zeros — used by serving and by the dry-run specs)
# ---------------------------------------------------------------------------

def _kv_cache_zeros(cfg: ModelConfig, bsz: int, cache_size: int):
    if cfg.attn_type == "mla":
        m = cfg.mla
        return KVCache(
            jnp.zeros((bsz, cache_size, m.kv_lora_rank), compute_dtype()),
            jnp.zeros((bsz, cache_size, m.qk_rope_head_dim), compute_dtype()))
    from repro.models.attention import padded_heads
    hd = cfg.resolved_head_dim
    kv = padded_heads(cfg)[1]
    return KVCache(
        jnp.zeros((bsz, cache_size, kv, hd), compute_dtype()),
        jnp.zeros((bsz, cache_size, kv, hd), compute_dtype()))


def _mamba_cache_zeros(cfg: ModelConfig, bsz: int):
    d_inner, n_heads, bc_dim = mamba_dims(cfg)
    s = cfg.ssm
    return MambaCache(
        ssm=jnp.zeros((bsz, n_heads, s.head_dim, s.d_state), jnp.float32),
        conv_x=jnp.zeros((bsz, s.d_conv - 1, d_inner), compute_dtype()),
        conv_bc=jnp.zeros((bsz, s.d_conv - 1, bc_dim), compute_dtype()))


def _stack(tree, n: int):
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), tree)


def init_cache(cfg: ModelConfig, bsz: int, cache_size: int):
    caches = []
    for kind, n in stage_plan(cfg):
        if kind == "mamba":
            caches.append(_stack(_mamba_cache_zeros(cfg, bsz), n))
        elif kind == "hybrid":
            k = cfg.hybrid_attn_every
            g = n // k
            caches.append({
                "mamba": _stack(_stack(_mamba_cache_zeros(cfg, bsz), k), g),
                "attn": _stack(_kv_cache_zeros(cfg, bsz, cache_size), g),
            })
        else:
            caches.append(_stack(_kv_cache_zeros(cfg, bsz, cache_size), n))
    return caches


def cache_batch_axes(cfg: ModelConfig):
    """Per-stage pytrees mirroring ``init_cache``'s structure whose leaves
    are the index of that leaf's *batch* axis. Scan-stack dims sit in front
    of batch (a dense-stage KV leaf is (L, B, S, KV, D) -> axis 1; hybrid
    mamba leaves are (g, k, B, ...) -> axis 2), so lane splicing must use
    this metadata rather than inferring the axis from shapes."""
    axes = []
    for kind, _ in stage_plan(cfg):
        if kind == "mamba":
            axes.append(jax.tree.map(lambda _: 1, _mamba_cache_zeros(cfg, 1)))
        elif kind == "hybrid":
            axes.append({
                "mamba": jax.tree.map(lambda _: 2,
                                      _mamba_cache_zeros(cfg, 1)),
                "attn": jax.tree.map(lambda _: 1,
                                     _kv_cache_zeros(cfg, 1, 1)),
            })
        else:
            axes.append(jax.tree.map(lambda _: 1, _kv_cache_zeros(cfg, 1, 1)))
    return axes


# ---------------------------------------------------------------------------
# Paged cache construction: one page pool per stage, stacked over layers.
# Leaves are (L, num_pages, page_size, *tail); block tables are shared by
# every layer, so one (B, nblk) table drives the whole stack.
# ---------------------------------------------------------------------------

def _kv_pool_zeros(cfg: ModelConfig, num_pages: int, page_size: int,
                   quant: bool):
    from repro.serving.kvcache import pool_zeros
    if cfg.attn_type == "mla":
        m = cfg.mla
        return KVCache(
            pool_zeros(num_pages, page_size, (m.kv_lora_rank,),
                       compute_dtype(), quant),
            pool_zeros(num_pages, page_size, (m.qk_rope_head_dim,),
                       compute_dtype(), quant))
    from repro.models.attention import padded_heads
    hd = cfg.resolved_head_dim
    kv = padded_heads(cfg)[1]
    return KVCache(
        pool_zeros(num_pages, page_size, (kv, hd), compute_dtype(), quant),
        pool_zeros(num_pages, page_size, (kv, hd), compute_dtype(), quant))


def init_paged_cache(cfg: ModelConfig, num_pages: int, page_size: int,
                     quant: bool = False):
    if cfg.family not in ("dense", "moe") or cfg.modality != "text":
        raise NotImplementedError(
            "paged KV covers dense/moe text models; ssm/hybrid state is O(1) "
            "per request and vlm prefixes are not token-addressed")
    return [_stack(_kv_pool_zeros(cfg, num_pages, page_size, quant), n)
            for _, n in stage_plan(cfg)]
