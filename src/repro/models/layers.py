"""Basic building blocks: norms, embeddings, RoPE, MLPs, init helpers.

All modules are plain functions over pytrees of arrays (no framework). Matmuls
run in ``compute_dtype`` (bf16) with f32 accumulation; norms run in f32.
Parameter leaves use a *naming convention* that the sharding rules and the
adapter machinery key off (see repro/launch/sharding.py and repro/core/masks.py):

  wq wk wv wo        attention projections
  w_up w_gate w_down MLP projections
  in_proj out_proj   mamba mixer projections
  w_dkv w_uk w_uv wq_a wq_b  MLA projections
  emb lm_head        embeddings / unembedding
  scale bias         norm scale / linear bias (never adapted, never TP-sharded)
"""
from __future__ import annotations

import contextlib
import math
from typing import Optional

import jax
import jax.numpy as jnp

COMPUTE_DTYPE = jnp.bfloat16  # default; see compute_precision()


def compute_dtype():
    """The current matmul/activation dtype. Module code reads this at trace
    time so ``compute_precision`` can override it per scope."""
    return COMPUTE_DTYPE


@contextlib.contextmanager
def compute_precision(dtype):
    """Temporarily override the compute dtype (default bf16).

    Used by the multi-tenant serving parity tests/benchmarks, which compare
    two numerically different evaluation orders and need f32 matmuls for a
    meaningful tolerance. Jitted closures must be *traced* inside the scope:
    the dtype is read at trace time, and a closure traced outside the scope
    keeps whatever dtype was active then.
    """
    global COMPUTE_DTYPE
    prev = COMPUTE_DTYPE
    COMPUTE_DTYPE = dtype
    try:
        yield
    finally:
        COMPUTE_DTYPE = prev


def cast_compute(tree):
    """Cast >=2D float params to bf16 once per step (mixed precision: f32
    master weights live in the optimizer; all FSDP gathers / TP collectives
    then move bf16, halving parameter traffic)."""
    return jax.tree.map(
        lambda x: x.astype(compute_dtype())
        if (hasattr(x, "ndim") and x.ndim >= 2
            and jnp.issubdtype(x.dtype, jnp.floating)) else x,
        tree)


# ---------------------------------------------------------------------------
# Side-delta weights (multi-tenant serving)
# ---------------------------------------------------------------------------
# A weight leaf may be replaced by a dict bundling the shared base matrix
# with a per-adapter sparse-delta table and the batch's per-request adapter
# ids (see repro/serving/multitenant.py). ``pdot`` then computes the base
# matmul once for the whole batch plus each request's sparse correction via
# the Pallas sidedelta kernel. The bundle is a plain dict so it survives
# jax.lax.scan slicing over stacked layer weights. Tables may be quantized:
# ``sd.vals`` int8 with a per-adapter ``sd.scale`` (dequantized inside the
# kernel's VMEM, so the resident adapter tables stay ~4x smaller).

SIDEDELTA_KEY = "sd.base"

# Which execution mode the sidedelta kernel uses, read at TRACE time (same
# discipline as compute_precision): None = auto (Pallas interpret emulation
# off-TPU, compiled Mosaic on TPU); True/False force it. interpret=False
# off-TPU compiles the kernel's tile plan through XLA — what CPU CI uses to
# guard the tiling/masking logic against TPU-only lowering bugs. "xla"
# forces the pure-jnp XLA twin on every backend; the twin is differentiable
# w.r.t. the value tables, which the multi-adapter trainer's forward needs.
SIDEDELTA_INTERPRET = None


def sidedelta_interpret():
    if SIDEDELTA_INTERPRET is None:
        return jax.default_backend() != "tpu"
    return SIDEDELTA_INTERPRET


@contextlib.contextmanager
def sidedelta_backend(interpret):
    """Temporarily force the sidedelta kernel mode (True, False, or "xla").
    Jitted closures must be *traced* inside the scope — the flag is read at
    trace time."""
    global SIDEDELTA_INTERPRET
    prev = SIDEDELTA_INTERPRET
    SIDEDELTA_INTERPRET = interpret
    try:
        yield
    finally:
        SIDEDELTA_INTERPRET = prev


def sidedelta_weight(base: jax.Array, rows: jax.Array, cols: jax.Array,
                     vals: jax.Array, ids: jax.Array,
                     scale: Optional[jax.Array] = None) -> dict:
    """base: (n, m); rows/cols/vals: (A, K) packed per-adapter deltas
    (vals f32, or int8 with per-adapter ``scale`` (A,) f32);
    ids: (B,) int32 per-request adapter slot (-1 = base only)."""
    w = {SIDEDELTA_KEY: base, "sd.rows": rows, "sd.cols": cols,
         "sd.vals": vals, "sd.ids": ids}
    if scale is not None:
        w["sd.scale"] = scale
    return w


def is_sidedelta(w) -> bool:
    return isinstance(w, dict) and SIDEDELTA_KEY in w


def pdot(x: jax.Array, w: jax.Array) -> jax.Array:
    """Matmul in bf16 (MXU accumulates f32 internally on TPU; bf16 output
    keeps backward cotangents AND row-parallel psums in bf16 — found via the
    dry-run: f32 outputs made every backward collective 2x, see §Perf).

    ``w`` may also be a side-delta bundle (multi-tenant serving): then the
    result is x @ base + per-request sparse deltas routed by the bundled ids.
    """
    if is_sidedelta(w):
        return _pdot_sidedelta(x, w)
    return jax.lax.dot_general(
        x.astype(compute_dtype()),
        w.astype(compute_dtype()),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=compute_dtype(),
    )


def _pdot_sidedelta(x: jax.Array, w: dict) -> jax.Array:
    from repro.kernels.ops import sidedelta  # deferred: kernels are leaf deps
    base = w[SIDEDELTA_KEY]
    if x.ndim == 2:
        # Flattened-token call sites (MoE shared experts): the model only
        # ever flattens row-major from (B, S, d), so the request axis is
        # recoverable from the bundled per-request ids. Single-program
        # serving only — an EP shard's local batch would divide B wrongly.
        B = w["sd.ids"].shape[0]
        T = x.shape[0]
        assert T % B == 0, (f"flattened tokens {T} not divisible by batch "
                            f"{B} at a side-delta weight")
        y2 = _pdot_sidedelta(x.reshape(B, T // B, x.shape[-1]), w)
        return y2.reshape(T, y2.shape[-1])
    assert x.ndim == 3, ("side-delta weights serve batched (B, S, d) "
                         f"activations, got {x.shape}")
    y = pdot(x, base)
    delta = sidedelta(x, w["sd.rows"], w["sd.cols"], w["sd.vals"],
                      w["sd.ids"], m=base.shape[-1],
                      scale=w.get("sd.scale"),
                      interpret=sidedelta_interpret())
    return (y.astype(jnp.float32) + delta).astype(y.dtype)


def dense(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None) -> jax.Array:
    y = pdot(x, w)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(compute_dtype())


def init_rms_norm(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def glorot(key, shape, in_axis=-2, out_axis=-1, dtype=jnp.float32):
    fan_in = shape[in_axis]
    fan_out = shape[out_axis]
    std = math.sqrt(2.0 / (fan_in + fan_out))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def normal_init(key, shape, std=0.02, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape (head_dim // 2,)."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: (S,) or (..., S). Split-half convention."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                       # (d/2,)
    angles = positions[..., :, None].astype(jnp.float32) * inv  # (..., S, d/2)
    cos = jnp.cos(angles)[..., :, None, :]           # (..., S, 1, d/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, act: str = "silu") -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": glorot(k1, (d_model, d_ff)),
        "w_down": glorot(k2, (d_ff, d_model)),
    }
    if act == "silu":  # SwiGLU
        p["w_gate"] = glorot(k3, (d_model, d_ff))
    return p


def mlp(params: dict, x: jax.Array, act: str = "silu") -> jax.Array:
    up = dense(x, params["w_up"])
    if act == "silu":
        gate = dense(x, params["w_gate"])
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(compute_dtype()) * up
    else:
        h = jax.nn.gelu(up.astype(jnp.float32)).astype(compute_dtype())
    return dense(h, params["w_down"])


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d_model: int) -> dict:
    return {"emb": normal_init(key, (vocab, d_model), std=0.02)}


def embed(params: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["emb"], tokens, axis=0).astype(compute_dtype())


def unembed(params: dict, h: jax.Array, tie_to: Optional[jax.Array] = None,
            softcap: float = 0.0, logical_vocab: int = 0) -> jax.Array:
    w = tie_to.T if tie_to is not None else params["lm_head"]
    logits = jax.lax.dot_general(
        h.astype(compute_dtype()), w.astype(compute_dtype()),
        (((h.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    if softcap > 0.0:
        logits = jnp.tanh(logits / softcap) * softcap
    if logical_vocab and logical_vocab < w.shape[-1]:
        pad_mask = jnp.arange(w.shape[-1]) >= logical_vocab
        logits = jnp.where(pad_mask, -1e30, logits)
    return logits  # f32


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token CE. logits f32 (..., V), labels int (...)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
