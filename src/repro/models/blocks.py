"""Residual blocks per family + the zamba2 shared-attention block.

A "block" bundles its mixer (attention / MLA / Mamba2) with its FFN
(dense / MoE / none) and pre-norms. Each kind exposes init / train /
prefill / decode with a uniform signature so the LM can scan over stacked
layer parameters regardless of family.

Cache conventions (per layer):
  gqa/mla block : attention.KVCache
  mamba block   : mamba2.MambaCache
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mamba2
from repro.models.layers import dense, init_mlp, init_rms_norm, mlp, rms_norm
from repro.models.moe import init_moe, moe_ffn


# ---------------------------------------------------------------------------
# Dense transformer block (attn + MLP) — also used for vlm/audio backbones.
# ---------------------------------------------------------------------------

def init_dense_block(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    k1, k2 = jax.random.split(key)
    a = attn.init_mla(k1, cfg) if cfg.attn_type == "mla" else attn.init_gqa(k1, cfg)
    return {
        "attn_norm": init_rms_norm(cfg.d_model),
        "attn": a,
        "mlp_norm": init_rms_norm(cfg.d_model),
        "mlp": init_mlp(k2, cfg.d_model, d_ff or cfg.d_ff, cfg.act),
    }


def _attn_train(params, cfg, x, prefix_len):
    if cfg.attn_type == "mla":
        return attn.mla_train(params, cfg, x, prefix_len=prefix_len)
    return attn.gqa_train(params, cfg, x, prefix_len=prefix_len)


def dense_block_train(params, cfg: ModelConfig, h, *, prefix_len=0, aux=None):
    x = rms_norm(h, params["attn_norm"]["scale"], cfg.norm_eps)
    h = h + _attn_train(params["attn"], cfg, x, prefix_len)
    x = rms_norm(h, params["mlp_norm"]["scale"], cfg.norm_eps)
    h = h + mlp(params["mlp"], x, cfg.act)
    return h, aux


def dense_block_prefill(params, cfg: ModelConfig, h, cache_size, *, prefix_len=0):
    x = rms_norm(h, params["attn_norm"]["scale"], cfg.norm_eps)
    if cfg.attn_type == "mla":
        a, cache = attn.mla_prefill(params["attn"], cfg, x, cache_size)
    else:
        a, cache = attn.gqa_prefill(params["attn"], cfg, x, cache_size,
                                    prefix_len=prefix_len)
    h = h + a
    x = rms_norm(h, params["mlp_norm"]["scale"], cfg.norm_eps)
    h = h + mlp(params["mlp"], x, cfg.act)
    return h, cache


def dense_block_decode(params, cfg: ModelConfig, h, cache, pos,
                       block_tables=None):
    x = rms_norm(h, params["attn_norm"]["scale"], cfg.norm_eps)
    if block_tables is not None:
        if cfg.attn_type == "mla":
            a, cache = attn.mla_decode_paged(params["attn"], cfg, x, cache,
                                             block_tables, pos)
        else:
            a, cache = attn.gqa_decode_paged(params["attn"], cfg, x, cache,
                                             block_tables, pos)
    elif cfg.attn_type == "mla":
        a, cache = attn.mla_decode(params["attn"], cfg, x, cache, pos)
    else:
        a, cache = attn.gqa_decode(params["attn"], cfg, x, cache, pos)
    h = h + a
    x = rms_norm(h, params["mlp_norm"]["scale"], cfg.norm_eps)
    h = h + mlp(params["mlp"], x, cfg.act)
    return h, cache


def dense_block_prefill_chunk(params, cfg: ModelConfig, h, cache,
                              block_tables, start, kv_len):
    """Paged chunk prefill: like dense_block_prefill but writing one padded
    chunk of positions [start, kv_len) through a block table."""
    x = rms_norm(h, params["attn_norm"]["scale"], cfg.norm_eps)
    if cfg.attn_type == "mla":
        a, cache = attn.mla_prefill_chunk(params["attn"], cfg, x, cache,
                                          block_tables, start, kv_len)
    else:
        a, cache = attn.gqa_prefill_chunk(params["attn"], cfg, x, cache,
                                          block_tables, start, kv_len)
    h = h + a
    x = rms_norm(h, params["mlp_norm"]["scale"], cfg.norm_eps)
    h = h + mlp(params["mlp"], x, cfg.act)
    return h, cache


# ---------------------------------------------------------------------------
# MoE transformer block (attn + MoE FFN).
# ---------------------------------------------------------------------------

def init_moe_block(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    a = attn.init_mla(k1, cfg) if cfg.attn_type == "mla" else attn.init_gqa(k1, cfg)
    return {
        "attn_norm": init_rms_norm(cfg.d_model),
        "attn": a,
        "mlp_norm": init_rms_norm(cfg.d_model),
        "moe": init_moe(k2, cfg),
    }


def moe_block_train(params, cfg: ModelConfig, h, *, prefix_len=0, aux=None):
    x = rms_norm(h, params["attn_norm"]["scale"], cfg.norm_eps)
    h = h + _attn_train(params["attn"], cfg, x, prefix_len)
    x = rms_norm(h, params["mlp_norm"]["scale"], cfg.norm_eps)
    y, lb = moe_ffn(params["moe"], cfg, x)
    h = h + y
    aux = lb if aux is None else aux + lb
    return h, aux


def moe_block_prefill(params, cfg: ModelConfig, h, cache_size, *, prefix_len=0):
    x = rms_norm(h, params["attn_norm"]["scale"], cfg.norm_eps)
    if cfg.attn_type == "mla":
        a, cache = attn.mla_prefill(params["attn"], cfg, x, cache_size)
    else:
        a, cache = attn.gqa_prefill(params["attn"], cfg, x, cache_size,
                                    prefix_len=prefix_len)
    h = h + a
    x = rms_norm(h, params["mlp_norm"]["scale"], cfg.norm_eps)
    y, _ = moe_ffn(params["moe"], cfg, x)
    h = h + y
    return h, cache


def moe_block_decode(params, cfg: ModelConfig, h, cache, pos,
                     block_tables=None):
    x = rms_norm(h, params["attn_norm"]["scale"], cfg.norm_eps)
    if block_tables is not None:
        if cfg.attn_type == "mla":
            a, cache = attn.mla_decode_paged(params["attn"], cfg, x, cache,
                                             block_tables, pos)
        else:
            a, cache = attn.gqa_decode_paged(params["attn"], cfg, x, cache,
                                             block_tables, pos)
    elif cfg.attn_type == "mla":
        a, cache = attn.mla_decode(params["attn"], cfg, x, cache, pos)
    else:
        a, cache = attn.gqa_decode(params["attn"], cfg, x, cache, pos)
    h = h + a
    x = rms_norm(h, params["mlp_norm"]["scale"], cfg.norm_eps)
    y, _ = moe_ffn(params["moe"], cfg, x)
    h = h + y
    return h, cache


def moe_block_prefill_chunk(params, cfg: ModelConfig, h, cache,
                            block_tables, start, kv_len):
    x = rms_norm(h, params["attn_norm"]["scale"], cfg.norm_eps)
    if cfg.attn_type == "mla":
        a, cache = attn.mla_prefill_chunk(params["attn"], cfg, x, cache,
                                          block_tables, start, kv_len)
    else:
        a, cache = attn.gqa_prefill_chunk(params["attn"], cfg, x, cache,
                                          block_tables, start, kv_len)
    h = h + a
    x = rms_norm(h, params["mlp_norm"]["scale"], cfg.norm_eps)
    y, _ = moe_ffn(params["moe"], cfg, x)
    h = h + y
    return h, cache


# ---------------------------------------------------------------------------
# Mamba2 block (norm + SSD mixer, no FFN — mamba2-780m style).
# ---------------------------------------------------------------------------

def init_mamba_block(key, cfg: ModelConfig) -> dict:
    return {"norm": init_rms_norm(cfg.d_model), "mixer": mamba2.init_mamba(key, cfg)}


def mamba_block_train(params, cfg: ModelConfig, h, *, prefix_len=0, aux=None):
    x = rms_norm(h, params["norm"]["scale"], cfg.norm_eps)
    h = h + mamba2.mamba_train(params["mixer"], cfg, x)
    return h, aux


def mamba_block_prefill(params, cfg: ModelConfig, h, cache_size, *, prefix_len=0):
    x = rms_norm(h, params["norm"]["scale"], cfg.norm_eps)
    y, cache = mamba2.mamba_prefill(params["mixer"], cfg, x)
    return h + y, cache


def mamba_block_decode(params, cfg: ModelConfig, h, cache, pos,
                       block_tables=None):
    # SSM state is O(1) per request — paging does not apply; the kwarg only
    # keeps the scan-body signature uniform.
    del block_tables
    x = rms_norm(h, params["norm"]["scale"], cfg.norm_eps)
    y, cache = mamba2.mamba_decode(params["mixer"], cfg, x, cache, pos)
    return h + y, cache


# ---------------------------------------------------------------------------
# Zamba2 shared attention block: ONE set of weights applied at several depth
# sites. Input is concat(hidden, initial_embedding) fused down to d_model.
# ---------------------------------------------------------------------------

def init_shared_attn(key, cfg: ModelConfig) -> dict:
    k0, k1 = jax.random.split(key)
    from repro.models.layers import glorot
    p = init_dense_block(k1, cfg)
    p["w_fuse"] = glorot(k0, (2 * cfg.d_model, cfg.d_model))
    return p


def shared_attn_train(params, cfg: ModelConfig, h, emb):
    u = dense(jnp.concatenate([h, emb], axis=-1), params["w_fuse"])
    out, _ = dense_block_train(params, cfg, u)
    return h + (out - u)  # residual of the block body only


def shared_attn_prefill(params, cfg: ModelConfig, h, emb, cache_size):
    u = dense(jnp.concatenate([h, emb], axis=-1), params["w_fuse"])
    out, cache = dense_block_prefill(params, cfg, u, cache_size)
    return h + (out - u), cache


def shared_attn_decode(params, cfg: ModelConfig, h, emb, cache, pos):
    u = dense(jnp.concatenate([h, emb], axis=-1), params["w_fuse"])
    out, cache = dense_block_decode(params, cfg, u, cache, pos)
    return h + (out - u), cache


BLOCK_FNS = {
    "dense": (init_dense_block, dense_block_train, dense_block_prefill,
              dense_block_decode),
    "moe": (init_moe_block, moe_block_train, moe_block_prefill,
            moe_block_decode),
    "mamba": (init_mamba_block, mamba_block_train, mamba_block_prefill,
              mamba_block_decode),
}
