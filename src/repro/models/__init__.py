from repro.models import attention, blocks, layers, lm, mamba2, moe  # noqa: F401
from repro.models.lm import (decode_step, init_cache, init_params, prefill,  # noqa: F401
                             train_loss)
