"""Attention: GQA/MQA/MHA (chunked, flash-style) and MLA (DeepSeek-V2).

Three entry points per variant:
  *_train(params, cfg, x, ...)            full-sequence, no cache
  *_prefill(params, cfg, x, cache_len)    full-sequence, returns KV cache
  *_decode(params, cfg, x, cache, pos)    one new token against the cache

The sequence dimension of the score matrix is never materialised in full for
long sequences: queries are processed in chunks of ``q_chunk`` via lax.scan
(online peak memory = one chunk row of scores). MLA decode uses the matrix-
absorption trick: attention runs directly in the kv_lora latent space so the
cache stores only (c_kv, k_rope) = (rank + rope_dim) per token.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (apply_rope, compute_dtype, dense, glorot,
                                 rms_norm)

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jax.Array  # (B, S_max, KV, D)  [MLA: c_kv (B, S_max, rank)]
    v: jax.Array  # (B, S_max, KV, D)  [MLA: k_rope (B, S_max, rope_dim)]


# ---------------------------------------------------------------------------
# Core score/softmax/AV with GQA grouping — one q-chunk against full K.
# ---------------------------------------------------------------------------

def _attend_block(q, k, v, q_pos, k_pos, causal, prefix_len, kv_len=None):
    """q: (B, qc, H, D); k,v: (B, Sk, KV, Dk|Dv); positions are (qc,) or
    (B, qc) for per-request decode offsets, and (Sk,). ``kv_len`` may be a
    scalar or (B,) per-request filled-cache length.

    Returns (B, qc, H, Dv). GQA grouping happens here without repeating KV.
    """
    B, qc, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, qc, KV, G, D)
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    scores = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg.astype(compute_dtype()), k.astype(compute_dtype()),
        preferred_element_type=jnp.float32) * scale
    qp = q_pos if q_pos.ndim == 2 else q_pos[None]    # (B|1, qc)
    mask = jnp.ones((qp.shape[0], qc, Sk), bool)
    if causal:
        cm = qp[:, :, None] >= k_pos[None, None, :]
        if prefix_len > 0:  # prefix-LM: prefix tokens are globally visible
            cm = cm | (k_pos[None, None, :] < prefix_len)
        mask = mask & cm
    if kv_len is not None:  # only the filled part of the cache is valid
        kl = jnp.asarray(kv_len)
        kl = kl[:, None, None] if kl.ndim == 1 else kl
        mask = mask & (k_pos[None, None, :] < kl)
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(compute_dtype())
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(compute_dtype()),
                     preferred_element_type=compute_dtype())
    return out.reshape(B, qc, H, v.shape[-1])


def chunked_attention(q, k, v, *, causal=True, q_offset=0, prefix_len=0,
                      q_chunk=512, kv_len=None):
    """Flash-style attention over q-chunks. q: (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    k_pos = jnp.arange(Sk)
    if Sq <= q_chunk:
        q_pos = q_offset + jnp.arange(Sq)
        return _attend_block(q, k, v, q_pos, k_pos, causal, prefix_len, kv_len)
    while Sq % q_chunk:  # shrink to the nearest divisor of Sq
        q_chunk -= 1
    n = Sq // q_chunk
    qr = jnp.moveaxis(q.reshape(B, n, q_chunk, H, D), 1, 0)

    def body(_, inp):
        qi, i = inp
        q_pos = q_offset + i * q_chunk + jnp.arange(q_chunk)
        return None, _attend_block(qi, k, v, q_pos, k_pos, causal, prefix_len,
                                   kv_len)

    # nested remat: recompute each chunk's scores/probs in backward instead
    # of stacking (n, B, H, qc, S) probs to HBM (flash-attention-style
    # backward; -7 TB/step on deepseek-coder train_4k, see §Perf)
    body = jax.checkpoint(body)
    _, out = jax.lax.scan(body, None, (qr, jnp.arange(n)))
    return jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, v.shape[-1])


# ---------------------------------------------------------------------------
# GQA attention module
# ---------------------------------------------------------------------------

def padded_heads(cfg: ModelConfig) -> Tuple[int, int]:
    """(H', KV') after optional head-group padding (§Perf optimized variants).

    Padding keeps the GQA grouping: each kv group's q-head slots grow from
    G = H/KV to G' = H'/KV'; the extra slots (and extra kv heads) are
    zero-initialised so they contribute exactly nothing — the model function
    is unchanged, but the flat head dims now divide 16-way TP."""
    H = cfg.pad_heads_to or cfg.num_heads
    KV = cfg.pad_kv_to or cfg.num_kv_heads
    assert H % KV == 0, (H, KV)
    return H, KV


def _pad_masks(cfg: ModelConfig):
    """(q_head_real (H',), kv_head_real (KV',)) boolean masks."""
    Hp, KVp = padded_heads(cfg)
    H, KV = cfg.num_heads, cfg.num_kv_heads
    G, Gp = H // KV, Hp // KVp
    kv_real = jnp.arange(KVp) < KV
    grp = jnp.arange(Hp) // Gp
    slot = jnp.arange(Hp) % Gp
    q_real = (grp < KV) & (slot < G)
    return q_real, kv_real


def init_gqa(key, cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    Hp, KVp = padded_heads(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "wq": glorot(ks[0], (d, Hp * hd)),
        "wk": glorot(ks[1], (d, KVp * hd)),
        "wv": glorot(ks[2], (d, KVp * hd)),
        "wo": glorot(ks[3], (Hp * hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((Hp * hd,), jnp.float32)
        p["bk"] = jnp.zeros((KVp * hd,), jnp.float32)
        p["bv"] = jnp.zeros((KVp * hd,), jnp.float32)
    if Hp != cfg.num_heads or KVp != cfg.num_kv_heads:
        q_real, kv_real = _pad_masks(cfg)
        qm = jnp.repeat(q_real, hd).astype(jnp.float32)
        km = jnp.repeat(kv_real, hd).astype(jnp.float32)
        p["wq"] = p["wq"] * qm
        p["wk"] = p["wk"] * km
        p["wv"] = p["wv"] * km
        p["wo"] = p["wo"] * qm[:, None]
        if cfg.qkv_bias:
            p["bq"] = p["bq"] * qm
            p["bk"] = p["bk"] * km
            p["bv"] = p["bv"] * km
    return p


def _gqa_qkv(params, cfg: ModelConfig, x, positions):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    Hp, KVp = padded_heads(cfg)
    q = dense(x, params["wq"], params.get("bq")).reshape(B, S, Hp, hd)
    k = dense(x, params["wk"], params.get("bk")).reshape(B, S, KVp, hd)
    v = dense(x, params["wv"], params.get("bv")).reshape(B, S, KVp, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _maybe_repeat_kv(cfg: ModelConfig, t):
    """(B, S, KV', D) -> (B, S, H', D) when attn_repeat_kv (see configs)."""
    if not cfg.attn_repeat_kv:
        return t
    Hp, KVp = padded_heads(cfg)
    return jnp.repeat(t, Hp // KVp, axis=2)


def gqa_train(params, cfg: ModelConfig, x, *, prefix_len=0, q_chunk=512):
    B, S, _ = x.shape
    positions = jnp.arange(S)
    q, k, v = _gqa_qkv(params, cfg, x, positions)
    out = chunked_attention(q, _maybe_repeat_kv(cfg, k),
                            _maybe_repeat_kv(cfg, v), causal=cfg.causal,
                            prefix_len=prefix_len, q_chunk=q_chunk)
    return dense(out.reshape(B, S, -1), params["wo"])


def gqa_prefill(params, cfg: ModelConfig, x, cache_size: int, *,
                prefix_len=0, q_chunk=512) -> Tuple[jax.Array, KVCache]:
    B, S, _ = x.shape
    positions = jnp.arange(S)
    q, k, v = _gqa_qkv(params, cfg, x, positions)
    out = chunked_attention(q, _maybe_repeat_kv(cfg, k),
                            _maybe_repeat_kv(cfg, v), causal=cfg.causal,
                            prefix_len=prefix_len, q_chunk=q_chunk)
    hd = cfg.resolved_head_dim
    KV = padded_heads(cfg)[1]
    ck = jnp.zeros((B, cache_size, KV, hd), compute_dtype())
    cv = jnp.zeros((B, cache_size, KV, hd), compute_dtype())
    cache = KVCache(
        jax.lax.dynamic_update_slice(ck, k.astype(compute_dtype()), (0, 0, 0, 0)),
        jax.lax.dynamic_update_slice(cv, v.astype(compute_dtype()), (0, 0, 0, 0)),
    )
    return dense(out.reshape(B, S, -1), params["wo"]), cache


def _decode_positions(pos) -> Tuple[jax.Array, bool]:
    """Rope positions for one decode step: pos may be a scalar (whole batch
    at one index — the fixed-batch serving path) or a (B,) vector of
    per-request indices (continuous batching: every slot is mid-stream at
    its own depth). Returns (positions (1,)|(B, 1), is_vector)."""
    p = jnp.asarray(pos)
    if p.ndim == 0:
        return jnp.full((1,), p), False
    return p[:, None], True


def gqa_decode(params, cfg: ModelConfig, x, cache: KVCache, pos) -> Tuple[jax.Array, KVCache]:
    """x: (B, 1, d); pos: scalar index where the new token lands, or (B,)
    per-request indices."""
    B = x.shape[0]
    positions, vector = _decode_positions(pos)
    q, k, v = _gqa_qkv(params, cfg, x, positions)
    if vector:
        b = jnp.arange(B)
        ck = cache.k.at[b, pos].set(k.astype(compute_dtype())[:, 0])
        cv = cache.v.at[b, pos].set(v.astype(compute_dtype())[:, 0])
    else:
        ck = jax.lax.dynamic_update_slice(cache.k, k.astype(compute_dtype()), (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache.v, v.astype(compute_dtype()), (0, pos, 0, 0))
    out = _attend_block(q, _maybe_repeat_kv(cfg, ck), _maybe_repeat_kv(cfg, cv),
                        positions, jnp.arange(ck.shape[1]),
                        causal=True, prefix_len=0, kv_len=jnp.asarray(pos) + 1)
    return dense(out.reshape(B, 1, -1), params["wo"]), KVCache(ck, cv)


# ---------------------------------------------------------------------------
# Paged GQA: the cache is a global page pool (P, page, KV, D) per layer plus
# per-request block tables (B, nblk) — see repro.serving.kvcache. Decode and
# chunk-prefill write through the table and attend against the gathered
# contiguous view, so the math (and, in f32, the bits) matches the
# contiguous cache path token-for-token.
# ---------------------------------------------------------------------------

def _paged_kv_mod():
    from repro.serving import kvcache  # deferred: serving imports models
    return kvcache


def gqa_decode_paged(params, cfg: ModelConfig, x, cache: KVCache,
                     block_tables, pos) -> Tuple[jax.Array, KVCache]:
    """x: (B, 1, d); cache: page pools (P, page, KV, D) [or QuantKV];
    block_tables: (B, nblk) int32; pos: (B,) per-request write index."""
    KVC = _paged_kv_mod()
    B = x.shape[0]
    pos = jnp.asarray(pos)
    positions = pos[:, None]                               # (B, 1)
    q, k, v = _gqa_qkv(params, cfg, x, positions)
    valid = jnp.ones((B, 1), bool)
    ck = KVC.paged_write(cache.k, k, block_tables, positions, valid)
    cv = KVC.paged_write(cache.v, v, block_tables, positions, valid)
    kk = KVC.paged_gather(ck, block_tables)                # (B, S_max, KV, D)
    vv = KVC.paged_gather(cv, block_tables)
    out = _attend_block(q, _maybe_repeat_kv(cfg, kk), _maybe_repeat_kv(cfg, vv),
                        positions, jnp.arange(kk.shape[1]),
                        causal=True, prefix_len=0, kv_len=pos + 1)
    return dense(out.reshape(B, 1, -1), params["wo"]), KVCache(ck, cv)


def gqa_prefill_chunk(params, cfg: ModelConfig, x, cache: KVCache,
                      block_tables, start, kv_len) -> Tuple[jax.Array, KVCache]:
    """One chunk of a paged prefill. x: (B, C, d) — rows at absolute
    positions ``start + i``; rows with position >= ``kv_len`` are padding
    (their K/V land in the scratch page, their outputs are garbage the
    caller discards). ``kv_len`` is the total valid length including this
    chunk."""
    KVC = _paged_kv_mod()
    B, C, _ = x.shape
    positions = start + jnp.arange(C)                      # (C,)
    q, k, v = _gqa_qkv(params, cfg, x, positions)
    posg = jnp.broadcast_to(positions[None], (B, C))
    valid = posg < kv_len
    ck = KVC.paged_write(cache.k, k, block_tables, posg, valid)
    cv = KVC.paged_write(cache.v, v, block_tables, posg, valid)
    kk = KVC.paged_gather(ck, block_tables)
    vv = KVC.paged_gather(cv, block_tables)
    out = _attend_block(q, _maybe_repeat_kv(cfg, kk), _maybe_repeat_kv(cfg, vv),
                        positions, jnp.arange(kk.shape[1]),
                        causal=cfg.causal, prefix_len=0, kv_len=kv_len)
    return dense(out.reshape(B, C, -1), params["wo"]), KVCache(ck, cv)


def mla_decode_paged(params, cfg: ModelConfig, x, cache: KVCache,
                     block_tables, pos) -> Tuple[jax.Array, KVCache]:
    """Matrix-absorbed paged decode: cache.k pools c_kv (P, page, rank),
    cache.v pools k_rope (P, page, rope_dim)."""
    KVC = _paged_kv_mod()
    m = cfg.mla
    B = x.shape[0]
    H = cfg.num_heads
    pos = jnp.asarray(pos)
    positions = pos[:, None]
    q_nope, q_rope = _mla_q(params, cfg, x, positions)
    c_kv_new, k_rope_new = _mla_ckv(params, cfg, x, positions)
    valid = jnp.ones((B, 1), bool)
    ck = KVC.paged_write(cache.k, c_kv_new, block_tables, positions, valid)
    cv = KVC.paged_write(cache.v, k_rope_new, block_tables, positions, valid)
    cc = KVC.paged_gather(ck, block_tables)                # (B, S_max, rank)
    cr = KVC.paged_gather(cv, block_tables)
    w_uk = params["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_eff = jnp.einsum("bqhn,rhn->bqhr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    scale = 1.0 / jnp.sqrt(jnp.asarray(m.qk_nope_head_dim + m.qk_rope_head_dim,
                                       jnp.float32))
    scores = (jnp.einsum("bqhr,bsr->bhqs", q_eff, cc.astype(jnp.float32))
              + jnp.einsum("bqhd,bsd->bhqs", q_rope.astype(jnp.float32),
                           cr.astype(jnp.float32))) * scale
    valid_k = jnp.arange(cc.shape[1])[None, None, None, :] <= \
        pos[:, None, None, None]
    scores = jnp.where(valid_k, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out_lat = jnp.einsum("bhqs,bsr->bqhr", probs, cc.astype(jnp.float32))
    w_uv = params["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bqhr,rhv->bqhv", out_lat,
                     w_uv.astype(jnp.float32)).astype(compute_dtype())
    return dense(out.reshape(B, 1, -1), params["wo"]), KVCache(ck, cv)


def mla_prefill_chunk(params, cfg: ModelConfig, x, cache: KVCache,
                      block_tables, start, kv_len) -> Tuple[jax.Array, KVCache]:
    """One chunk of a paged MLA prefill: write the chunk's latents, then
    attend with per-head K/V expanded from the gathered latent view."""
    KVC = _paged_kv_mod()
    B, C, _ = x.shape
    positions = start + jnp.arange(C)
    q_nope, q_rope = _mla_q(params, cfg, x, positions)
    c_kv, k_rope = _mla_ckv(params, cfg, x, positions)
    posg = jnp.broadcast_to(positions[None], (B, C))
    valid = posg < kv_len
    ck = KVC.paged_write(cache.k, c_kv, block_tables, posg, valid)
    cv = KVC.paged_write(cache.v, k_rope, block_tables, posg, valid)
    cc = KVC.paged_gather(ck, block_tables)
    cr = KVC.paged_gather(cv, block_tables)
    k, v = _mla_expand_kv(params, cfg, cc, cr)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = _attend_block(q, k, v, positions, jnp.arange(k.shape[1]),
                        causal=True, prefix_len=0, kv_len=kv_len)
    return dense(out.reshape(B, C, -1), params["wo"]), KVCache(ck, cv)


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V2)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig) -> dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    p = {
        "w_dkv": glorot(ks[1], (d, m.kv_lora_rank + m.qk_rope_head_dim)),
        "kv_norm": {"scale": jnp.ones((m.kv_lora_rank,), jnp.float32)},
        "w_uk": glorot(ks[2], (m.kv_lora_rank, H * m.qk_nope_head_dim)),
        "w_uv": glorot(ks[3], (m.kv_lora_rank, H * m.v_head_dim)),
        "wo": glorot(ks[4], (H * m.v_head_dim, d)),
    }
    if m.q_lora_rank:
        p["wq_a"] = glorot(ks[0], (d, m.q_lora_rank))
        p["q_norm"] = {"scale": jnp.ones((m.q_lora_rank,), jnp.float32)}
        p["wq_b"] = glorot(ks[5], (m.q_lora_rank, H * qk_dim))
    else:
        p["wq"] = glorot(ks[0], (d, H * qk_dim))
    return p


def _mla_q(params, cfg, x, positions):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    if m.q_lora_rank:
        qa = rms_norm(dense(x, params["wq_a"]), params["q_norm"]["scale"], cfg.norm_eps)
        q = dense(qa, params["wq_b"])
    else:
        q = dense(x, params["wq"])
    q = q.reshape(B, S, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_ckv(params, cfg, x, positions):
    m = cfg.mla
    ckv_full = dense(x, params["w_dkv"])
    c_kv, k_rope = jnp.split(ckv_full, [m.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, params["kv_norm"]["scale"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def _mla_expand_kv(params, cfg, c_kv, k_rope):
    """Materialise per-head K/V from the latent cache (train/prefill path)."""
    m = cfg.mla
    B, S = c_kv.shape[:2]
    H = cfg.num_heads
    k_nope = dense(c_kv, params["w_uk"]).reshape(B, S, H, m.qk_nope_head_dim)
    v = dense(c_kv, params["w_uv"]).reshape(B, S, H, m.v_head_dim)
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :],
                                (B, S, H, m.qk_rope_head_dim))
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    return k, v


def mla_train(params, cfg: ModelConfig, x, *, q_chunk=512, prefix_len=0):
    B, S, _ = x.shape
    positions = jnp.arange(S)
    q_nope, q_rope = _mla_q(params, cfg, x, positions)
    c_kv, k_rope = _mla_ckv(params, cfg, x, positions)
    k, v = _mla_expand_kv(params, cfg, c_kv, k_rope)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = chunked_attention(q, k, v, causal=cfg.causal, q_chunk=q_chunk,
                            prefix_len=prefix_len)
    return dense(out.reshape(B, S, -1), params["wo"])


def mla_prefill(params, cfg: ModelConfig, x, cache_size: int, *,
                q_chunk=512) -> Tuple[jax.Array, KVCache]:
    m = cfg.mla
    B, S, _ = x.shape
    positions = jnp.arange(S)
    q_nope, q_rope = _mla_q(params, cfg, x, positions)
    c_kv, k_rope = _mla_ckv(params, cfg, x, positions)
    k, v = _mla_expand_kv(params, cfg, c_kv, k_rope)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = chunked_attention(q, k, v, causal=True, q_chunk=q_chunk)
    cc = jnp.zeros((B, cache_size, m.kv_lora_rank), compute_dtype())
    cr = jnp.zeros((B, cache_size, m.qk_rope_head_dim), compute_dtype())
    cache = KVCache(
        jax.lax.dynamic_update_slice(cc, c_kv.astype(compute_dtype()), (0, 0, 0)),
        jax.lax.dynamic_update_slice(cr, k_rope.astype(compute_dtype()), (0, 0, 0)),
    )
    return dense(out.reshape(B, S, -1), params["wo"]), cache


def mla_decode(params, cfg: ModelConfig, x, cache: KVCache, pos) -> Tuple[jax.Array, KVCache]:
    """Matrix-absorbed decode: attention runs in the kv_lora latent space.

    cache.k = c_kv (B, S, r); cache.v = k_rope (B, S, rope_dim).
    """
    m = cfg.mla
    B = x.shape[0]
    H = cfg.num_heads
    positions, vector = _decode_positions(pos)
    q_nope, q_rope = _mla_q(params, cfg, x, positions)     # (B,1,H,·)
    c_kv_new, k_rope_new = _mla_ckv(params, cfg, x, positions)
    if vector:
        b = jnp.arange(B)
        cc = cache.k.at[b, pos].set(c_kv_new.astype(compute_dtype())[:, 0])
        cr = cache.v.at[b, pos].set(k_rope_new.astype(compute_dtype())[:, 0])
    else:
        cc = jax.lax.dynamic_update_slice(cache.k, c_kv_new.astype(compute_dtype()),
                                          (0, pos, 0))
        cr = jax.lax.dynamic_update_slice(cache.v, k_rope_new.astype(compute_dtype()),
                                          (0, pos, 0))
    # Absorb W_uk into q: q_eff[b,h,r] = sum_n q_nope[b,1,h,n] * W_uk[r, h*n]
    # (f32 einsums: decode-step FLOPs are negligible; avoids CPU bf16-dot gaps)
    w_uk = params["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_eff = jnp.einsum("bqhn,rhn->bqhr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    scale = 1.0 / jnp.sqrt(jnp.asarray(m.qk_nope_head_dim + m.qk_rope_head_dim,
                                       jnp.float32))
    scores = (jnp.einsum("bqhr,bsr->bhqs", q_eff, cc.astype(jnp.float32))
              + jnp.einsum("bqhd,bsd->bhqs", q_rope.astype(jnp.float32),
                           cr.astype(jnp.float32))) * scale
    p = jnp.asarray(pos)
    valid = jnp.arange(cc.shape[1])[None, None, None, :] <= (
        p[:, None, None, None] if p.ndim == 1 else p)
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out_lat = jnp.einsum("bhqs,bsr->bqhr", probs, cc.astype(jnp.float32))
    w_uv = params["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bqhr,rhv->bqhv", out_lat,
                     w_uv.astype(jnp.float32)).astype(compute_dtype())
    return dense(out.reshape(B, 1, -1), params["wo"]), KVCache(cc, cr)
