"""Mixture-of-Experts FFN with capacity-based token dispatch.

Dispatch uses the gather/scatter ("dropping") formulation: tokens are routed
top-k, assigned a position inside their expert's capacity buffer via a cumsum
over the flattened routing order, scattered into an (E, C, d) buffer, processed
by a batched expert SwiGLU, and combined back weighted by the (renormalised)
router probabilities. Experts are sharded over the ``model`` mesh axis
(expert parallelism); the scatter/gather turn into all-to-alls under SPMD.

Shared experts (DeepSeek-V2 style) are fused into a single dense SwiGLU with
hidden dim ``num_shared * d_ff`` applied to every token.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import compute_dtype, dense, glorot, init_mlp, mlp


def init_moe(key, cfg: ModelConfig) -> dict:
    m = cfg.moe
    d, E, ff = cfg.d_model, m.num_experts, m.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "w_router": glorot(ks[0], (d, E)),
        "experts_w_up": glorot(ks[1], (E, d, ff), in_axis=-2, out_axis=-1),
        "experts_w_gate": glorot(ks[2], (E, d, ff), in_axis=-2, out_axis=-1),
        "experts_w_down": glorot(ks[3], (E, ff, d), in_axis=-2, out_axis=-1),
    }
    if m.num_shared:
        p["shared"] = init_mlp(ks[4], d, m.num_shared * ff, act="silu")
    return p


def _expert_ffn(p, buf):
    """buf: (E, C, d) -> (E, C, d), batched SwiGLU over experts."""
    up = jnp.einsum("ecd,edf->ecf", buf.astype(compute_dtype()),
                    p["experts_w_up"].astype(compute_dtype()),
                    preferred_element_type=jnp.float32)
    gate = jnp.einsum("ecd,edf->ecf", buf.astype(compute_dtype()),
                      p["experts_w_gate"].astype(compute_dtype()),
                      preferred_element_type=jnp.float32)
    h = (jax.nn.silu(gate) * up).astype(compute_dtype())
    out = jnp.einsum("ecf,efd->ecd", h,
                     p["experts_w_down"].astype(compute_dtype()),
                     preferred_element_type=compute_dtype())
    return out


def moe_ffn(params: dict, cfg: ModelConfig, x: jax.Array,
            deterministic: bool = True) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d). Returns (y, aux_load_balance_loss).

    Under a mesh (launch layer installs the "moe_ep_mesh" hint) dispatch runs
    as explicit expert parallelism via shard_map: activations are already
    replicated across ``model`` for the TP matmuls, so every model rank
    routes its data-shard's tokens to its LOCAL experts and one psum merges
    the partial outputs — no token all-to-all, no (E, C, d) resharding. This
    replaced two GSPMD-chosen formulations that cost 2.5-3.5 TB/step of
    collectives on deepseek-v2-lite train_4k (see EXPERIMENTS §Perf)."""
    from repro.launch.actctx import _SPECS, shard_as

    ep = _SPECS.get("moe_ep_mesh")
    if ep is not None and cfg.moe.num_experts % ep[1] == 0:
        return _moe_ffn_ep(params, cfg, x, ep[0])
    return _moe_ffn_dense(params, cfg, x)


def _moe_ffn_dense(params: dict, cfg: ModelConfig,
                   x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single-program dispatch (CPU tests / decode / meshless runs)."""
    from repro.launch.actctx import shard_as

    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    k, E = m.top_k, m.num_experts
    xf = x.reshape(T, d)

    logits = dense(xf, params["w_router"]).astype(jnp.float32)      # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                          # (T, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # Load-balancing aux loss (Switch-style): E * sum_e f_e * P_e.
    me = jnp.mean(probs, axis=0)                                    # (E,)
    onehot_k = jax.nn.one_hot(top_i, E, dtype=jnp.float32)          # (T,k,E)
    ce = jnp.mean(jnp.sum(onehot_k, axis=1), axis=0) / k            # (E,)
    aux = E * jnp.sum(me * ce)

    # Capacity floor: for small token counts (decode steps, smoke tests) give
    # every expert room for all tokens so routing is drop-free and decode is
    # consistent with prefill; for large T the capacity factor governs.
    capacity = int(max(round(m.capacity_factor * T * k / E), min(T, 512)))

    # --- dispatch: one scatter of (T, d) per routing choice ---
    buf = jnp.zeros((E, capacity + 1, d), compute_dtype())            # +trash lane
    buf = shard_as(buf, "moe_buf")
    counts = jnp.zeros((E,), jnp.int32)
    slots = []
    xc = xf.astype(compute_dtype())
    for j in range(k):
        e_j = top_i[:, j]                                           # (T,)
        oh = jax.nn.one_hot(e_j, E, dtype=jnp.int32)                # (T, E)
        pos_in = jnp.cumsum(oh, axis=0) - oh                        # before me
        pos = jnp.take_along_axis(pos_in, e_j[:, None], axis=1)[:, 0] \
            + counts[e_j]
        counts = counts + jnp.sum(oh, axis=0)
        slot = jnp.where(pos < capacity, pos, capacity)
        slots.append(slot)
        buf = buf.at[e_j, slot].add(xc, mode="drop")
        buf = shard_as(buf, "moe_buf")

    out_buf = _expert_ffn(params, buf[:, :capacity])                # (E,C,d)
    out_buf = jnp.concatenate(
        [out_buf, jnp.zeros((E, 1, d), compute_dtype())], axis=1)
    out_buf = shard_as(out_buf, "moe_buf")

    # --- combine: gather each choice's slot, weight by router prob ---
    y = jnp.zeros((T, d), compute_dtype())
    for j in range(k):
        got = out_buf[top_i[:, j], slots[j]]                        # (T, d)
        w_j = (top_p[:, j] * (slots[j] < capacity)).astype(compute_dtype())
        y = y + got * w_j[:, None]

    if m.num_shared:
        y = y + mlp(params["shared"], xf, act="silu")
    return y.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# Expert-parallel dispatch (shard_map)
# ---------------------------------------------------------------------------

def _moe_ffn_ep(params: dict, cfg: ModelConfig, x: jax.Array,
                mesh) -> Tuple[jax.Array, jax.Array]:
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import axis_size, dp_axes

    m = cfg.moe
    B, S, d = x.shape
    k, E = m.top_k, m.num_experts
    ep_size = axis_size(mesh, "model")
    e_l = E // ep_size
    dp = dp_axes(mesh)
    import numpy as np
    dp_size = int(np.prod([axis_size(mesh, a) for a in dp]))
    b_spec = dp if (B % dp_size == 0 and B >= dp_size) else None
    t_l = (B // dp_size if b_spec else B) * S
    capacity = int(max(round(m.capacity_factor * t_l * k / E), min(t_l, 512)))

    def local_fn(w_router, w_up, w_gate, w_down, xl):
        # xl: (B_l, S, d) — replicated over `model`; w_*: (e_l, ...) local.
        bl = xl.shape[0]
        xf = xl.reshape(bl * S, d)
        logits = dense(xf, w_router).astype(jnp.float32)        # (T_l, E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_i = jax.lax.top_k(probs, k)
        top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

        me = jnp.mean(probs, axis=0)
        oh_k = jax.nn.one_hot(top_i, E, dtype=jnp.float32)
        ce = jnp.mean(jnp.sum(oh_k, axis=1), axis=0) / k
        aux = jax.lax.pmean(E * jnp.sum(me * ce), dp)

        first = jax.lax.axis_index("model") * e_l
        xc = xf.astype(compute_dtype())
        buf = jnp.zeros((e_l, capacity + 1, d), compute_dtype())
        counts = jnp.zeros((e_l,), jnp.int32)
        slots, mines = [], []
        for j in range(k):
            le = top_i[:, j] - first                            # (T_l,)
            mine = (le >= 0) & (le < e_l)
            le = jnp.where(mine, le, 0)
            oh = jax.nn.one_hot(le, e_l, dtype=jnp.int32) \
                * mine[:, None].astype(jnp.int32)
            pos = jnp.take_along_axis(jnp.cumsum(oh, 0) - oh,
                                      le[:, None], axis=1)[:, 0] + counts[le]
            counts = counts + jnp.sum(oh, axis=0)
            slot = jnp.where(mine & (pos < capacity), pos, capacity)
            slots.append(slot)
            mines.append(mine)
            buf = buf.at[le, slot].add(
                xc * mine[:, None].astype(compute_dtype()), mode="drop")

        p_loc = {"experts_w_up": w_up, "experts_w_gate": w_gate,
                 "experts_w_down": w_down}
        out_buf = _expert_ffn(p_loc, buf[:, :capacity])
        out_buf = jnp.concatenate(
            [out_buf, jnp.zeros((e_l, 1, d), compute_dtype())], axis=1)

        y = jnp.zeros((bl * S, d), compute_dtype())
        for j in range(k):
            le = jnp.where(mines[j], top_i[:, j] - first, 0)
            got = out_buf[le, slots[j]]
            w_j = (top_p[:, j] * mines[j]
                   * (slots[j] < capacity)).astype(compute_dtype())
            y = y + got * w_j[:, None]
        y = jax.lax.psum(y, "model")          # merge expert-shard partials
        return y.reshape(bl, S, d), aux

    specs_in = (P(), P("model", None, None), P("model", None, None),
                P("model", None, None), P(b_spec, None, None))
    specs_out = (P(b_spec, None, None), P())
    from repro.compat import shard_map
    y, aux = shard_map(
        local_fn, mesh=mesh, in_specs=specs_in, out_specs=specs_out,
        check_vma=False,
    )(params["w_router"], params["experts_w_up"], params["experts_w_gate"],
      params["experts_w_down"], x)

    if m.num_shared:
        xf = x.reshape(B * S, d)
        y = y + mlp(params["shared"], xf, act="silu").reshape(B, S, d)
    return y, aux
