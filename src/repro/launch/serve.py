"""Serving driver: batched decode with per-request SHiRA adapter switching.

Demonstrates the paper's deployment story end to end on this host:
  * prefill a batch of prompts, then decode tokens step by step,
  * swap SHiRA adapters BETWEEN batches via the sparse scatter path
    (SwitchEngine) — no fuse/unfuse stage, base weights patched in place,
  * optionally fuse several adapters (multi-adapter serving).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m --smoke \
      --adapters 3 --tokens 16 --batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import core
from repro.configs import AdapterConfig, get_config, get_smoke_config
from repro.models import lm


def make_adapters(cfg, params, n: int, key) -> list:
    """n random SHiRA packs (stand-ins for independently trained adapters)."""
    packs = []
    acfg = AdapterConfig(kind="shira", mask="rand", sparsity=0.98)
    for i in range(n):
        sub = jax.random.fold_in(key, i)
        values, aux = core.init_adapter(sub, params, acfg)
        values = jax.tree.map(
            lambda v: None if v is None
            else 0.01 * jax.random.normal(sub, v.shape), values,
            is_leaf=lambda x: x is None)
        packs.append(core.pack_from_shira(f"adapter_{i}", values, aux))
    return packs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-780m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--adapters", type=int, default=2)
    ap.add_argument("--fuse", action="store_true",
                    help="serve with all adapters fused (multi-adapter)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.encoder_only:
        raise SystemExit("encoder-only archs have no decode serving path")
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    packs = make_adapters(cfg, params, args.adapters, jax.random.PRNGKey(7))
    engine = core.SwitchEngine(params)

    cache_size = args.prompt_len + args.tokens + 8
    B = args.batch

    prefill_fn = jax.jit(lambda p, b: lm.prefill(p, cfg, b, cache_size))
    decode_fn = jax.jit(lambda p, t, c, pos: lm.decode_step(p, cfg, t, c, pos))

    def serve_batch(params, label):
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, args.prompt_len),
                                  0, cfg.vocab_size)
        batch = {"tokens": toks}
        if cfg.modality == "vision":
            batch["patch_embeds"] = jnp.zeros(
                (B, cfg.num_prefix_embeds, cfg.d_model))
        t0 = time.perf_counter()
        logits, caches = prefill_fn(params, batch)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        outs = [nxt]
        pos = args.prompt_len + (cfg.num_prefix_embeds
                                 if cfg.modality == "vision" else 0)
        for i in range(args.tokens - 1):
            logits, caches = decode_fn(params, nxt, caches, pos + i)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            outs.append(nxt)
        jax.block_until_ready(outs[-1])
        dt = time.perf_counter() - t0
        tput = B * args.tokens / dt
        print(f"[serve] {label}: {B}x{args.tokens} tokens in {dt*1e3:.0f}ms "
              f"({tput:.1f} tok/s)")
        return jnp.concatenate(outs, axis=1)

    serve_batch(engine.params, "base model")
    if args.fuse:
        stats = engine.load_fused(packs)
        print(f"[serve] fused {len(packs)} adapters: "
              f"{sum(s.seconds for s in stats)*1e3:.1f}ms, "
              f"{sum(s.entries_written for s in stats)} entries")
        serve_batch(engine.params, "multi-adapter fused")
    else:
        for pack in packs:
            st = engine.switch(pack)
            print(f"[serve] switched to {pack.name}: {st.seconds*1e3:.1f}ms, "
                  f"{st.entries_written} entries "
                  f"({st.bytes_written/1e6:.2f}MB adapter vs "
                  f"{st.weight_bytes_total/1e6:.0f}MB weights)")
            serve_batch(engine.params, pack.name)


if __name__ == "__main__":
    main()
