"""Serving driver: batched decode with per-request SHiRA adapter switching.

Demonstrates the paper's deployment story end to end on this host:
  * prefill a batch of prompts, then decode tokens step by step,
  * swap SHiRA adapters BETWEEN batches via the sparse scatter path
    (SwitchEngine) — no fuse/unfuse stage, base weights patched in place,
  * optionally fuse several adapters (multi-adapter serving).

Multi-tenant serving (``--multi-tenant``): instead of serializing on the
active adapter, every request in the batch names its own adapter and all of
them decode together off ONE shared copy of the base weights
(``repro.serving.MultiTenantEngine``). Each request's SHiRA pack is applied
as a batched sparse side-delta in the forward pass via the Pallas
``sidedelta`` kernel, and a ``FusedLRU`` scheduler fuses the hot adapter
into the shared base (sparse scatter) while cold ones stay in side-delta
form. Flags:
  --multi-tenant        serve mixed-adapter batches in one forward pass
  --batches N           how many request batches to stream
  --skew F              tenant mix skew: fraction of requests routed to
                        adapter_0 (the rest spread uniformly); high skew
                        exercises the scheduler's promote path

Continuous batching (``--continuous``): the request-level path. Instead of
fixed-size batch streams, requests are submitted one by one to
``repro.hub.ServingEngine`` (``submit(prompt, adapter) -> future``): each
decode lane carries its own adapter id and cache position, finished
requests recycle their lane immediately, and all packs are resolved through
a ``repro.hub.AdapterStore`` (``--int8`` stores them quantized, ~3-4x
smaller resident bytes). Extra flags:
  --continuous          serve a request trace through the ServingEngine
  --requests N          how many requests to stream (continuous)
  --slots N             decode lanes (continuous; default --batch)
  --int8                keep adapters int8-quantized in the store

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-7b --smoke \
      --multi-tenant --adapters 3 --tokens 16 --batch 8 --batches 4
  PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-7b --smoke \
      --continuous --adapters 3 --tokens 16 --slots 4 --requests 12
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import core
from repro.configs import AdapterConfig, get_config, get_smoke_config
from repro.models import lm


def make_adapters(cfg, params, n: int, key, multi_tenant: bool = False) -> list:
    """n random SHiRA packs (stand-ins for independently trained adapters)."""
    packs = []
    targets = AdapterConfig().target_modules
    if multi_tenant:
        from repro.serving.multitenant import UNSUPPORTED_LEAVES
        targets = tuple(t for t in targets if t not in UNSUPPORTED_LEAVES)
    acfg = AdapterConfig(kind="shira", mask="rand", sparsity=0.98,
                         target_modules=targets)
    for i in range(n):
        sub = jax.random.fold_in(key, i)
        values, aux = core.init_adapter(sub, params, acfg)
        values = jax.tree.map(
            lambda v: None if v is None
            else 0.01 * jax.random.normal(sub, v.shape), values,
            is_leaf=lambda x: x is None)
        packs.append(core.pack_from_shira(f"adapter_{i}", values, aux))
    return packs


def tenant_mix(rng, packs, batch: int, skew: float) -> list:
    """Per-request adapter names: ``skew`` of the batch goes to the first
    adapter, the rest spread over the others + the base model (None)."""
    pool = [p.name for p in packs[1:]] + [None]
    return [packs[0].name if rng.random() < skew
            else pool[rng.integers(len(pool))] for _ in range(batch)]


def serve_multi_tenant(cfg, params, packs, args) -> None:
    from numpy.random import default_rng
    from repro.core.switching import FusedLRU
    from repro.serving.multitenant import MultiTenantEngine

    engine = MultiTenantEngine(cfg, params, scheduler=FusedLRU(),
                               table_dtype="int8" if args.int8 else "f32")
    for p in packs:
        engine.register(p)
    rng = default_rng(0)
    B = args.batch
    total, t_total = 0, 0.0
    for step in range(args.batches):
        names = tenant_mix(rng, packs, B, args.skew)
        toks = jax.random.randint(jax.random.fold_in(jax.random.PRNGKey(1),
                                                     step),
                                  (B, args.prompt_len), 0, cfg.vocab_size)
        batch = {"tokens": toks}
        if cfg.modality == "vision":
            batch["patch_embeds"] = jnp.zeros(
                (B, cfg.num_prefix_embeds, cfg.d_model))
        out, dt = engine.generate(batch, names, args.tokens)
        total += B * args.tokens
        t_total += dt
        mix = {n or "base": names.count(n) for n in dict.fromkeys(names)}
        print(f"[serve-mt] batch {step}: {mix} fused={engine.fused} "
              f"{B * args.tokens / dt:.1f} tok/s")
    print(f"[serve-mt] {total} tokens in {t_total*1e3:.0f}ms "
          f"({total / t_total:.1f} tok/s), "
          f"{engine.fuse_transitions} fused-state transitions")


def serve_continuous(cfg, params, packs, args) -> None:
    import tempfile

    from numpy.random import default_rng
    from repro.hub import AdapterStore, ServingEngine

    store = AdapterStore(tempfile.mkdtemp(prefix="adapter-store-"))
    for p in packs:
        store.add(p, values="int8" if args.int8 else "f32")
    slots = args.slots or args.batch
    engine = ServingEngine(
        cfg, params, slots=slots, store=store,
        table_dtype="int8" if args.int8 else "f32",
        cache_size=args.prompt_len + args.tokens + 8
        + (cfg.num_prefix_embeds if cfg.modality == "vision" else 0))
    rng = default_rng(0)
    futs = []
    for r in range(args.requests):
        name = tenant_mix(rng, packs, 1, args.skew)[0]
        toks = jax.random.randint(jax.random.fold_in(jax.random.PRNGKey(1), r),
                                  (args.prompt_len,), 0, cfg.vocab_size)
        futs.append(engine.submit(toks, name, max_tokens=args.tokens))
    dt = engine.run()
    done = sum(f.done() for f in futs)
    print(f"[serve-cc] {done}/{len(futs)} requests, {engine.tokens_out} "
          f"tokens in {dt*1e3:.0f}ms ({engine.tokens_out/dt:.1f} tok/s), "
          f"{engine.step_count} decode steps, idle-lane steps "
          f"{engine.decode_slot_waste}, store loads={store.loads} "
          f"resident={store.resident_bytes()/1e3:.1f}kB")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-780m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--adapters", type=int, default=2)
    ap.add_argument("--fuse", action="store_true",
                    help="serve with all adapters fused (multi-adapter)")
    ap.add_argument("--multi-tenant", action="store_true",
                    help="per-request adapters batched in one forward pass")
    ap.add_argument("--batches", type=int, default=4,
                    help="request batches to stream (multi-tenant)")
    ap.add_argument("--skew", type=float, default=0.5,
                    help="fraction of requests routed to adapter_0")
    ap.add_argument("--continuous", action="store_true",
                    help="request-level serving via repro.hub.ServingEngine")
    ap.add_argument("--requests", type=int, default=12,
                    help="requests to stream (continuous)")
    ap.add_argument("--slots", type=int, default=0,
                    help="decode lanes (continuous; 0 = --batch)")
    ap.add_argument("--int8", action="store_true",
                    help="int8 adapters: quantized store packs (continuous) "
                    "and int8 device-side delta tables (both paths)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.encoder_only:
        raise SystemExit("encoder-only archs have no decode serving path")
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    packs = make_adapters(cfg, params, args.adapters, jax.random.PRNGKey(7),
                          multi_tenant=args.multi_tenant or args.continuous)
    if args.continuous:
        serve_continuous(cfg, params, packs, args)
        return
    if args.multi_tenant:
        serve_multi_tenant(cfg, params, packs, args)
        return
    engine = core.SwitchEngine(params)

    from repro.serving.multitenant import serving_cache_size
    cache_size = serving_cache_size(cfg, args.prompt_len, args.tokens)
    B = args.batch

    prefill_fn = jax.jit(lambda p, b: lm.prefill(p, cfg, b, cache_size))
    decode_fn = jax.jit(lambda p, t, c, pos: lm.decode_step(p, cfg, t, c, pos))

    def serve_batch(params, label):
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, args.prompt_len),
                                  0, cfg.vocab_size)
        batch = {"tokens": toks}
        if cfg.modality == "vision":
            batch["patch_embeds"] = jnp.zeros(
                (B, cfg.num_prefix_embeds, cfg.d_model))
        t0 = time.perf_counter()
        logits, caches = prefill_fn(params, batch)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        outs = [nxt]
        pos = args.prompt_len + (cfg.num_prefix_embeds
                                 if cfg.modality == "vision" else 0)
        for i in range(args.tokens - 1):
            logits, caches = decode_fn(params, nxt, caches, pos + i)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            outs.append(nxt)
        jax.block_until_ready(outs[-1])
        dt = time.perf_counter() - t0
        tput = B * args.tokens / dt
        print(f"[serve] {label}: {B}x{args.tokens} tokens in {dt*1e3:.0f}ms "
              f"({tput:.1f} tok/s)")
        return jnp.concatenate(outs, axis=1)

    serve_batch(engine.params, "base model")
    if args.fuse:
        stats = engine.load_fused(packs)
        print(f"[serve] fused {len(packs)} adapters: "
              f"{sum(s.seconds for s in stats)*1e3:.1f}ms, "
              f"{sum(s.entries_written for s in stats)} entries")
        serve_batch(engine.params, "multi-adapter fused")
    else:
        for pack in packs:
            st = engine.switch(pack)
            print(f"[serve] switched to {pack.name}: {st.seconds*1e3:.1f}ms, "
                  f"{st.entries_written} entries "
                  f"({st.bytes_written/1e6:.2f}MB adapter vs "
                  f"{st.weight_bytes_total/1e6:.0f}MB weights)")
            serve_batch(engine.params, pack.name)


if __name__ == "__main__":
    main()
