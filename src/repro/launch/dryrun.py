import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.

__doc__ = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces, with zero device allocation:
  * compiled.memory_analysis()  — proves the program fits per device,
  * compiled.cost_analysis()    — HLO FLOPs / bytes for the roofline,
  * collective byte counts parsed from the post-SPMD optimized HLO,
and appends a JSON record to --out (default results/dryrun.json).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both [--adapter shira] [--out results/dryrun.json]
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.analysis.hlo import (collective_bytes, cost_summary,
                                memory_summary, program_cost)
from repro.configs import (SHAPES, AdapterConfig, TrainConfig, applicable_shapes,
                           get_config, registry)
from repro.launch import steps as S
from repro.launch.actctx import sharding_hints
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import cache_specs


# Optimized per-arch variants (§Perf): head-group padding + kv-repeat makes
# attention shard over 16-way TP instead of replicating (zero-init pads are
# function-preserving — see models/attention.padded_heads).
VARIANTS = {
    "padded": {
        "deepseek-coder-33b": dict(pad_heads_to=64, attn_repeat_kv=True),
        "starcoder2-7b": dict(pad_heads_to=48, attn_repeat_kv=True),
        "qwen1.5-32b": dict(pad_heads_to=48, pad_kv_to=48),
        "paligemma-3b": dict(pad_heads_to=16, attn_repeat_kv=True),
        "granite-34b": dict(attn_repeat_kv=True),
        "granite-moe-1b-a400m": dict(attn_repeat_kv=True),
    },
}


def lower_cell(arch: str, shape_name: str, mesh, *,
               adapter: str = "none", variant: str = "none",
               extra_tags: str = "") -> Dict[str, Any]:
    cfg = get_config(arch)
    if variant != "none":
        cfg = cfg.replace(**VARIANTS[variant].get(arch, {}))
    shape = SHAPES[shape_name]
    t0 = time.time()

    if shape.kind == "train":
        tcfg = TrainConfig()
        batch = S.abstract_batch(cfg, shape)
        state_sh, batch_sh = S.train_shardings(cfg, shape, mesh)
        hints = S.sharding_hints_for(cfg, shape, mesh)
        if adapter == "shira":
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.launch import sharding as shd
            acfg = AdapterConfig(kind="shira", mask="rand", sparsity=0.99)
            # shard-local packed adapter (see core.materialize_sharded)
            values, idx, pspecs, vsh = S.abstract_shira_sharded(
                cfg, acfg, mesh)
            step = S.make_shira_train_step(cfg, tcfg, acfg, mesh=mesh,
                                           pspecs=pspecs)
            state = {"trainable": values, "mu": values, "nu": values,
                     "step": jax.ShapeDtypeStruct((), jnp.int32)}
            repl = NamedSharding(mesh, P())
            st_sh = {"trainable": vsh, "mu": vsh, "nu": vsh, "step": repl}
            base = S.abstract_params(cfg)
            base_sh = S._ns(mesh, shd.param_specs(base, cfg, mesh))
            with sharding_hints(**hints):
                lowered = jax.jit(
                    step,
                    in_shardings=(st_sh, batch_sh, base_sh, vsh),
                ).lower(state, batch, base, idx)
        else:
            step = S.make_train_step(cfg, tcfg)
            state = S.abstract_train_state(cfg)
            with sharding_hints(**hints):
                lowered = jax.jit(
                    step, in_shardings=(state_sh, batch_sh),
                    donate_argnums=(0,),
                ).lower(state, batch)

    elif shape.kind == "prefill":
        params = S.abstract_params(cfg, dtype=jnp.bfloat16)
        psh = S.serve_param_shardings(cfg, mesh)
        batch = S.abstract_batch(cfg, shape, with_labels=False)
        _, batch_sh = S.train_shardings(cfg, shape, mesh)
        batch_sh = {k: v for k, v in batch_sh.items() if k in batch}
        hints = S.sharding_hints_for(cfg, shape, mesh)
        if cfg.encoder_only:
            step = S.make_encode_step(cfg)
        else:
            step = S.make_prefill_step(cfg, cache_size=shape.seq_len)
        with sharding_hints(**hints):
            lowered = jax.jit(step, in_shardings=(psh, batch_sh)).lower(
                params, batch)

    else:  # decode
        params = S.abstract_params(cfg, dtype=jnp.bfloat16)
        cache = S.abstract_cache(cfg, shape.global_batch, shape.seq_len)
        psh, csh, tsh = S.decode_shardings(cfg, shape, mesh)
        tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        step = S.make_decode_step(cfg)
        lowered = jax.jit(
            step, in_shardings=(psh, csh, tsh, None),
            donate_argnums=(1,),
        ).lower(params, cache, tokens, pos)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    hlo_text = compiled.as_text()
    # archive the optimized HLO (zstd) so analysis passes can be re-run
    # without recompiling 62 cells
    try:
        import zstandard
        os.makedirs("results/hlo", exist_ok=True)
        tag = (f"{arch}__{shape_name}__"
               f"{'x'.join(map(str, mesh.devices.shape))}__{adapter}"
               + ("" if variant == "none" else f"__{variant}"))
        with open(f"results/hlo/{tag}.hlo.zst", "wb") as f:
            f.write(zstandard.ZstdCompressor(level=6).compress(
                hlo_text.encode()))
    except Exception:
        pass
    mem = memory_summary(compiled)
    cost_raw = cost_summary(compiled)          # XLA aggregate (loops once)
    cost = program_cost(hlo_text)              # loop-weighted (ours)
    coll = collective_bytes(hlo_text)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": list(mesh.devices.shape), "axes": list(mesh.axis_names),
        "kind": shape.kind, "adapter": adapter, "variant": variant,
        "tags": extra_tags,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem, "cost": cost, "cost_xla_raw": cost_raw,
        "collectives": coll,
        "ok": True,
    }
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--adapter", default="none", choices=["none", "shira"])
    ap.add_argument("--variant", default="none", choices=["none", "padded"])
    ap.add_argument("--out", default="results/dryrun.json")
    args = ap.parse_args()

    archs = registry.ARCH_IDS if args.arch == "all" else args.arch.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], tuple(r["mesh"]), r.get("adapter", "none"),
             r.get("variant", "none"))
            for r in results if r.get("ok")}

    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        for arch in archs:
            shapes = ([s.name for s in applicable_shapes(arch)]
                      if args.shape == "all" else args.shape.split(","))
            app = {s.name for s in applicable_shapes(arch)}
            for shape_name in shapes:
                if shape_name not in app:
                    print(f"[dryrun] SKIP {arch} x {shape_name} (inapplicable)")
                    continue
                key = (arch, shape_name, tuple(mesh.devices.shape),
                       args.adapter, args.variant)
                if key in done:
                    print(f"[dryrun] cached {key}")
                    continue
                print(f"[dryrun] {arch} x {shape_name} x mesh{mesh.devices.shape} "
                      f"adapter={args.adapter} ...", flush=True)
                try:
                    rec = lower_cell(arch, shape_name, mesh,
                                     adapter=args.adapter,
                                     variant=args.variant)
                    print(f"[dryrun]   ok: compile={rec['compile_s']}s "
                          f"flops={rec['cost'].get('flops', 0):.3e} "
                          f"dev_mem={rec['memory'].get('temp_mb', '?')}MB "
                          f"coll={rec['collectives'].get('total_gb', 0):.2f}GB",
                          flush=True)
                except Exception as e:  # noqa: BLE001 — record and continue
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": list(mesh.devices.shape),
                           "adapter": args.adapter, "ok": False,
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                    print(f"[dryrun]   FAIL {type(e).__name__}: {e}",
                          flush=True)
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results if r.get("ok"))
    print(f"[dryrun] {n_ok}/{len(results)} cells OK -> {args.out}")


if __name__ == "__main__":
    main()
