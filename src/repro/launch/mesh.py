"""Mesh construction for the production topologies.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state. The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else (tests, benches) sees the 1 real CPU device.

Logical axes:
  pod   : inter-pod data parallelism (gradient all-reduce over DCN)
  data  : intra-pod data parallelism + FSDP parameter sharding + sequence
          sharding for batch-1 long-context decode
  model : tensor/expert parallelism (heads, ffn hidden, experts, vocab)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def _mk(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """jax.make_mesh across jax versions: ``axis_types`` (and
    jax.sharding.AxisType) only exist on newer releases; older ones default
    to Auto axes anyway, which is what we want."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (tests, elastic re-scale)."""
    return _mk(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (CPU tests)."""
    return _mk((data, model), ("data", "model"))


def dp_axes(mesh) -> Tuple[str, ...]:
    """The axes batch is sharded over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.shape[name]
