"""Activation-sharding hints.

The model code is mesh-agnostic; the launch layer installs NamedShardings
here before tracing. ``shard_act`` constrains the residual stream at scan
boundaries (keeps remat-saved activations model-sharded); ``shard_as`` is the
generic hook used for the MoE dispatch buffer ("moe_buf": expert-sharded so
expert compute is local and token exchange becomes all-to-alls) and the loss
chunks ("loss_act": gather the bf16 hidden once instead of psumming f32
logits — found via the dry-run, EXPERIMENTS §Perf).
"""
from __future__ import annotations

import contextlib
from typing import Dict, Optional

import jax

_SPECS: Dict[str, object] = {}


def set_sharding(name: str, sharding) -> None:
    if sharding is None:
        _SPECS.pop(name, None)
    else:
        _SPECS[name] = sharding


@contextlib.contextmanager
def sharding_hints(**kw):
    prev = dict(_SPECS)
    for k, v in kw.items():
        set_sharding(k, v)
    try:
        yield
    finally:
        _SPECS.clear()
        _SPECS.update(prev)


# back-compat alias used by launch/steps.py
@contextlib.contextmanager
def act_sharding(sharding, **kw):
    with sharding_hints(act=sharding, **kw):
        yield


def shard_as(x: jax.Array, name: str) -> jax.Array:
    s = _SPECS.get(name)
    if s is None:
        return x
    return jax.lax.with_sharding_constraint(x, s)


def shard_act(x: jax.Array) -> jax.Array:
    s = _SPECS.get("act")
    if s is None or x.ndim != 3:
        return x
    return jax.lax.with_sharding_constraint(x, s)
