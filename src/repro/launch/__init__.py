from repro.launch.mesh import (dp_axes, make_host_mesh, make_mesh,  # noqa: F401
                               make_production_mesh)
