"""Standalone jittable step functions + abstract input specs for the dry-run.

Every (arch x shape) cell lowers exactly one of these:
  train_*   -> make_train_step      (full finetune or SHiRA-packed variant)
  prefill_* -> make_prefill_step    (encoder archs: make_encode_step)
  decode_*  -> make_decode_step     (one token against a full cache)

``abstract_*`` build ShapeDtypeStruct stand-ins (weak-type-correct, zero
allocation) for params / optimizer / batches / caches, and the matching
NamedSharding trees, so ``jit(...).lower(...)`` needs no real arrays.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import core
from repro.configs.base import (AdapterConfig, ModelConfig, ShapeSpec,
                                TrainConfig)
from repro.launch import sharding as shd
from repro.launch.actctx import act_sharding
from repro.launch.mesh import axis_size, dp_axes
from repro.models import lm
from repro.optim import adamw_update, lr_schedule
from repro.optim.adamw import AdamWState


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, tcfg: TrainConfig) -> Callable:
    """Full-finetune step; ``tcfg.microbatch`` > 1 enables gradient
    accumulation (scan over microbatches — live activations shrink by the
    accumulation factor at the cost of re-running the forward per slice)."""
    schedule = lr_schedule(tcfg)
    n_micro = max(tcfg.microbatch, 1)

    def loss_of(params, batch):
        from repro.models.layers import cast_compute
        return lm.train_loss(cast_compute(params), cfg, batch)

    def grads_of(params, batch):
        if n_micro == 1:
            return jax.value_and_grad(loss_of, has_aux=True)(params, batch)
        micro = jax.tree.map(
            lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                + x.shape[1:]), batch)

        def body(carry, mb):
            acc, loss_acc = carry
            (loss, _), g = jax.value_and_grad(loss_of, has_aux=True)(
                params, mb)
            acc = jax.tree.map(jnp.add, acc, g)
            return (acc, loss_acc + loss), None

        zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                             params)
        (g, loss), _ = jax.lax.scan(body, (zeros, jnp.zeros(())), micro)
        g = jax.tree.map(lambda x: x / n_micro, g)
        return (loss / n_micro, {}), g

    def train_step(state, batch):
        lr = schedule(state["step"])
        (loss, metrics), grads = grads_of(state["trainable"], batch)
        new_t, opt, om = adamw_update(
            grads, AdamWState(state["step"], state["mu"], state["nu"]),
            state["trainable"], tcfg, lr)
        return ({"trainable": new_t, "mu": opt.mu, "nu": opt.nu,
                 "step": opt.step},
                {"loss": loss, "grad_norm": om["grad_norm"]})

    return train_step


def make_shira_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                          acfg: AdapterConfig, mesh=None,
                          pspecs=None) -> Callable:
    """Packed-SHiRA step: trainable = (…,K) values; base weights frozen.

    With ``mesh``: shard-local packed adapters (core.materialize_sharded) —
    the scatter is communication-free and the value-grad sync shrinks to the
    packed 1% (the beyond-paper collective-compression win, §Perf)."""
    schedule = lr_schedule(tcfg)

    def train_step(state, batch, base, indices):
        lr = schedule(state["step"])
        aux = {"indices": indices}

        def loss_fn(values):
            from repro.models.layers import cast_compute
            if mesh is not None:
                eff = core.adapters.materialize_sharded(
                    base, values, indices, pspecs, mesh, alpha=1.0)
            else:
                eff = core.materialize(base, values, aux, acfg, alpha=1.0)
            return lm.train_loss(cast_compute(eff), cfg, batch)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["trainable"])
        new_t, opt, om = adamw_update(
            grads, AdamWState(state["step"], state["mu"], state["nu"]),
            state["trainable"], tcfg, lr)
        return ({"trainable": new_t, "mu": opt.mu, "nu": opt.nu,
                 "step": opt.step},
                {"loss": loss, "grad_norm": om["grad_norm"]})

    return train_step


def make_prefill_step(cfg: ModelConfig, cache_size: int) -> Callable:
    def prefill_step(params, batch):
        return lm.prefill(params, cfg, batch, cache_size)
    return prefill_step


def make_encode_step(cfg: ModelConfig) -> Callable:
    def encode_step(params, batch):
        return lm.encode(params, cfg, batch)
    return encode_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    def decode_step(params, caches, tokens, pos):
        return lm.decode_step(params, cfg, tokens, caches, pos)
    return decode_step


# ---------------------------------------------------------------------------
# Abstract values (ShapeDtypeStruct) + shardings
# ---------------------------------------------------------------------------

def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def abstract_params(cfg: ModelConfig, dtype=jnp.float32):
    shapes = jax.eval_shape(lambda k: lm.init_params(cfg, k),
                            jax.random.PRNGKey(0))
    if dtype != jnp.float32:
        shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, dtype), shapes)
    return shapes


def abstract_train_state(cfg: ModelConfig):
    p = abstract_params(cfg)
    return {"trainable": p, "mu": p, "nu": p,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def abstract_batch(cfg: ModelConfig, shape: ShapeSpec,
                   with_labels: bool = True) -> Dict[str, Any]:
    n, s = shape.global_batch, shape.seq_len
    out: Dict[str, Any] = {}
    if cfg.modality == "audio":
        out["frame_embeds"] = jax.ShapeDtypeStruct((n, s, cfg.d_model),
                                                   jnp.float32)
    elif cfg.modality == "vision":
        p = cfg.num_prefix_embeds
        out["tokens"] = jax.ShapeDtypeStruct((n, s - p), jnp.int32)
        out["patch_embeds"] = jax.ShapeDtypeStruct((n, p, cfg.d_model),
                                                   jnp.float32)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((n, s), jnp.int32)
    if with_labels:
        lbl_s = s - cfg.num_prefix_embeds if cfg.modality == "vision" else s
        out["labels"] = jax.ShapeDtypeStruct((n, lbl_s), jnp.int32)
    return out


def abstract_cache(cfg: ModelConfig, bsz: int, cache_size: int):
    # bsz/cache_size are shape-building statics — close over them so
    # eval_shape doesn't turn them into tracers.
    return jax.eval_shape(lambda: lm.init_cache(cfg, bsz, cache_size))


def abstract_shira(cfg: ModelConfig, acfg: AdapterConfig):
    """Abstract (values, indices) trees for the packed-SHiRA step."""
    p = abstract_params(cfg)
    idx = jax.eval_shape(
        lambda k: core.make_packed_indices(
            jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), p),
            acfg, k),
        jax.random.PRNGKey(0))
    values = jax.tree.map(
        lambda i: None if i is None
        else jax.ShapeDtypeStruct(i.shape, jnp.float32),
        idx, is_leaf=lambda x: x is None)
    return values, idx


def abstract_shira_sharded(cfg: ModelConfig, acfg: AdapterConfig, mesh):
    """Shard-local packed adapter: (L, DPC, TPC, Ks) per 3D target leaf.

    Returns (values_sds, idx_sds, pspecs, value_shardings)."""
    from repro.core.masks import budget, is_target
    p = abstract_params(cfg)
    pspecs = shd.param_specs(p, cfg, mesh)

    def per_leaf(path, leaf, spec):
        if not is_target(path, leaf, acfg.target_modules) or leaf.ndim != 3:
            return None
        L, n, m = leaf.shape
        dpc = shd._axis_prod(mesh, spec[1] if len(spec) > 1 else None)
        tpc = shd._axis_prod(mesh, spec[2] if len(spec) > 2 else None)
        ks = budget(n // dpc, m // tpc, acfg.sparsity)
        return jax.ShapeDtypeStruct((L, dpc, tpc, ks), jnp.int32)

    idx = jax.tree_util.tree_map_with_path(per_leaf, p, pspecs)
    values = jax.tree.map(
        lambda i: None if i is None
        else jax.ShapeDtypeStruct(i.shape, jnp.float32),
        idx, is_leaf=lambda x: x is None)
    vsh = jax.tree.map(
        lambda i, s: None if i is None else NamedSharding(
            mesh, P(s[0] if len(s) > 0 else None,
                    s[1] if len(s) > 1 else None,
                    s[2] if len(s) > 2 else None, None)),
        idx, pspecs, is_leaf=lambda x: x is None)
    return values, idx, pspecs, vsh


# ---------------------------------------------------------------------------
# Sharding trees per step kind
# ---------------------------------------------------------------------------

def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def train_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh):
    p = abstract_params(cfg)
    pspec = shd.param_specs(p, cfg, mesh)
    state_spec = {"trainable": pspec, "mu": pspec, "nu": pspec, "step": P()}
    bspec = shd.sanitize_tree(shd.batch_spec(cfg, shape, mesh),
                              abstract_batch(cfg, shape), mesh)
    return _ns(mesh, state_spec), _ns(mesh, bspec)


def serve_param_shardings(cfg: ModelConfig, mesh):
    # no FSDP at serving time: weights replicated over data, TP over model
    serve_cfg = cfg.replace(fsdp=False)
    p = abstract_params(serve_cfg, dtype=jnp.bfloat16)
    return _ns(mesh, shd.param_specs(p, serve_cfg, mesh))


def decode_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh):
    pshard = serve_param_shardings(cfg, mesh)
    cshape = abstract_cache(cfg, shape.global_batch, shape.seq_len)
    cspec = shd.sanitize_tree(shd.cache_specs(cfg, shape, mesh), cshape, mesh)
    b_ax, _ = shd.cache_batch_axes(cfg, shape, mesh)
    tok = shd.sanitize_spec(P(b_ax, None), (shape.global_batch, 1), mesh)
    return pshard, _ns(mesh, cspec), NamedSharding(mesh, tok)


def act_spec_for(cfg: ModelConfig, shape: ShapeSpec, mesh) -> NamedSharding:
    dp = dp_axes(mesh)
    dp_size = int(np.prod([axis_size(mesh, a) for a in dp]))
    b = dp if (shape.global_batch % dp_size == 0
               and shape.global_batch >= dp_size) else None
    return NamedSharding(mesh, P(b, None, "model"))


def sharding_hints_for(cfg: ModelConfig, shape: ShapeSpec, mesh) -> dict:
    """All activation-sharding hints for one cell (see actctx)."""
    dp = dp_axes(mesh)
    dp_size = int(np.prod([axis_size(mesh, a) for a in dp]))
    b = dp if (shape.global_batch % dp_size == 0
               and shape.global_batch >= dp_size) else None
    hints = {"act": NamedSharding(mesh, P(b, None, "model")),
             # loss chunks are flattened global tokens: batch-sharded rows,
             # hidden gathered (vocab-parallel unembed)
             "loss_act": NamedSharding(mesh, P(b, None))}
    if cfg.moe and cfg.moe.num_experts % axis_size(mesh, "model") == 0:
        # expert parallelism via shard_map (see moe._moe_ffn_ep)
        hints["moe_ep_mesh"] = (mesh, axis_size(mesh, "model"))
    return hints
