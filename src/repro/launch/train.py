"""Training driver.

Runs real training on this host (reduced/smoke configs — the container is
CPU-only) or lowers the production step for a mesh (``--dryrun``-style use
should go through repro.launch.dryrun instead).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch starcoder2-7b --smoke \
      --adapter shira-wm --steps 100
  PYTHONPATH=src python -m repro.launch.train --preset 100m --steps 300
"""
from __future__ import annotations

import argparse
import json
import os

from repro.configs import (AdapterConfig, ModelConfig, RunConfig, TrainConfig,
                           get_config, get_smoke_config)
from repro.configs.base import ShapeSpec
from repro.runtime import Trainer
from repro.runtime.trainer import TrainerConfig

PRESET_100M = ModelConfig(
    name="dense-100m", family="dense", num_layers=12, d_model=768,
    num_heads=12, num_kv_heads=12, d_ff=3072, vocab_size=32000,
    tie_embeddings=True,
)


def parse_adapter(spec: str) -> AdapterConfig:
    """'none' | 'lora' | 'dora' | 'shira-<mask>' | 'shira-<mask>-hook'."""
    if spec == "none":
        return AdapterConfig(kind="none")
    if spec in ("lora", "dora"):
        return AdapterConfig(kind=spec, rank=16)
    if spec.startswith("shira-dora"):
        return AdapterConfig(kind="shira-dora", mask="wm")
    if spec.startswith("shira"):
        parts = spec.split("-")
        mask = parts[1] if len(parts) > 1 else "wm"
        hook = len(parts) > 2 and parts[2] == "hook"
        return AdapterConfig(kind="shira", mask=mask, packed=not hook)
    raise ValueError(spec)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--preset", default=None, choices=[None, "100m"])
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config for --arch")
    ap.add_argument("--adapter", default="none")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--task", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--out", default=None, help="write loss history JSON")
    args = ap.parse_args()

    if args.preset == "100m":
        cfg = PRESET_100M
    elif args.arch:
        cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    else:
        raise SystemExit("need --arch or --preset")

    shape = ShapeSpec("cli", args.seq, args.batch, "train")
    run = RunConfig(model=cfg, shape=shape, adapter=parse_adapter(args.adapter),
                    train=TrainConfig(learning_rate=args.lr, seed=args.seed,
                                      total_steps=args.steps,
                                      warmup_steps=max(args.steps // 20, 1)))
    trainer = Trainer(run, TrainerConfig(ckpt_dir=args.ckpt_dir,
                                         log_every=max(args.steps // 20, 1)))
    from repro.data import TaskSpec, batch_iterator
    batches = batch_iterator(cfg, shape, seed=args.seed,
                             task=TaskSpec(task_id=args.task))
    out = trainer.fit(args.steps, batches=batches)
    losses = [h["loss"] for h in out["history"]]
    print(f"[train] {cfg.name} adapter={args.adapter} "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump({"arch": cfg.name, "adapter": args.adapter,
                       "losses": losses}, f)


if __name__ == "__main__":
    main()
