"""Logical -> physical sharding rules.

Parameter leaves are mapped to PartitionSpecs by *leaf name* (the naming
convention documented in repro/models/layers.py). Rules give the spec of the
trailing "semantic" dims; any extra leading dims (layer stacks (L, ...),
hybrid groups (G, k, ...), expert stacks) are padded with None.

Megatron-style TP over the ``model`` axis:
  column-parallel (out-dim sharded): wq wk wv w_up w_gate in_proj w_dkv wq_a
                                     wq_b w_uk w_uv + their biases
  row-parallel  (in-dim sharded):    wo w_down out_proj
  expert-parallel:                   experts_* sharded on the expert dim
  vocab-parallel:                    emb (V, d) and lm_head (d, V)

FSDP (cfg.fsdp) additionally shards the non-TP matrix dim over ``data``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.core.masks import leaf_name, path_str
from repro.launch.mesh import axis_size, dp_axes

# name -> (n_semantic_dims, spec builder(fsdp_axis) )
_COL = lambda f: (2, lambda: P(f, "model"))
_ROW = lambda f: (2, lambda: P("model", f))


def _rules(fsdp: Optional[str]) -> Dict[str, Tuple[int, Any]]:
    f = fsdp
    return {
        # attention / MLA
        "wq": _COL(f), "wk": _COL(f), "wv": _COL(f),
        "wq_a": _COL(f), "wq_b": _COL(f),
        "w_dkv": (2, lambda: P(f, None)),     # latent dim is tiny: replicate
        "w_uk": _COL(f), "w_uv": _COL(f),
        "wo": _ROW(f),
        "bq": (1, lambda: P("model")), "bk": (1, lambda: P("model")),
        "bv": (1, lambda: P("model")),
        # MLPs
        "w_up": _COL(f), "w_gate": _COL(f), "w_down": _ROW(f),
        # MoE
        "w_router": (2, lambda: P(f, None)),
        "experts_w_up": (3, lambda: P("model", f, None)),
        "experts_w_gate": (3, lambda: P("model", f, None)),
        "experts_w_down": (3, lambda: P("model", None, f)),
        # Mamba2 (separate shard-aligned projections; see mamba2.py docstring)
        "in_z": _COL(f), "in_x": _COL(f), "in_dt": _COL(f),
        "in_bc": (2, lambda: P(f, None)),     # 2*g*n is tiny: replicate
        "out_proj": _ROW(f),
        "conv_x_w": (2, lambda: P(None, "model")),
        "conv_x_b": (1, lambda: P("model")),
        "conv_bc_w": (2, lambda: P(None, None)),
        "conv_bc_b": (1, lambda: P(None)),
        "A_log": (1, lambda: P(None)), "D": (1, lambda: P(None)),
        "dt_bias": (1, lambda: P(None)),
        # zamba2 shared-block fuse
        "w_fuse": (2, lambda: P(f, None)),
        # embeddings
        "emb": (2, lambda: P("model", f)),
        "lm_head": (2, lambda: P(f, "model")),
        # norms
        "scale": (1, lambda: P(None)),
    }


def _axis_prod(mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        out = 1
        for e in entry:
            out *= axis_size(mesh, e)
        return out
    return axis_size(mesh, entry)


def sanitize_spec(spec: P, shape, mesh) -> P:
    """Drop axes that do not divide the corresponding dim (jit in_shardings
    require exact divisibility; GSPMD-internal padding is not available for
    explicitly-specified argument shardings)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        out.append(entry if dim % _axis_prod(mesh, entry) == 0 else None)
    return P(*out)


def param_spec(path, leaf, cfg: ModelConfig, mesh) -> P:
    name = leaf_name(path)
    fsdp = "data" if cfg.fsdp else None
    rules = _rules(fsdp)
    if name not in rules:
        return P()  # replicate anything unknown (defensive)
    # Head-alignment guard (found via dry-run, §Perf): sharding the flat
    # (H*hd) projection when H doesn't divide TP splits *inside* a head, so
    # the attention contractions run over a sharded head_dim — GSPMD then
    # psums score/value tensors every layer. Replicating the projection is
    # strictly cheaper (weights are small; heads compute replicates).
    tp = axis_size(mesh, "model")
    if cfg.attn_type == "gqa":
        from repro.models.attention import padded_heads
        hp, kvp = padded_heads(cfg)
        if name in ("wq", "wo", "bq") and hp % tp != 0:
            return P(*([None] * leaf.ndim))
        if name in ("wk", "wv", "bk", "bv") and kvp % tp != 0:
            return P(*([None] * leaf.ndim))
    nsem, builder = rules[name]
    spec = builder()
    extra = leaf.ndim - nsem
    if extra < 0:
        return P()
    spec = P(*([None] * extra + list(spec)))
    spec = sanitize_spec(spec, leaf.shape, mesh)
    # vocab dims that don't divide TP (50280, 49155, 504): fall back to
    # sharding the embedding dim over `model` instead of replicating ~1GB.
    if name == "emb" and spec[0] is None and \
            leaf.shape[1] % axis_size(mesh, "model") == 0:
        spec = P(None, "model")
    if name == "lm_head" and spec[1] is None and \
            leaf.shape[0] % axis_size(mesh, "model") == 0:
        spec = P("model", None)
    return spec


def param_specs(params_shape, cfg: ModelConfig, mesh):
    """Pytree of PartitionSpec matching an eval_shape'd parameter tree."""
    return jax.tree_util.tree_map_with_path(
        lambda p, x: param_spec(p, x, cfg, mesh), params_shape)


def sanitize_tree(spec_tree, shape_tree, mesh):
    return jax.tree.map(
        lambda s, x: sanitize_spec(s, x.shape, mesh), spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, P))


def shardings_for(tree_shape, cfg: ModelConfig, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(tree_shape, cfg, mesh))


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------

def batch_spec(cfg: ModelConfig, shape: ShapeSpec, mesh) -> Dict[str, P]:
    dp = dp_axes(mesh)
    dp_size = int(np.prod([axis_size(mesh, a) for a in dp]))
    bspec = dp if shape.global_batch % dp_size == 0 and \
        shape.global_batch >= dp_size else None
    out: Dict[str, P] = {}
    if cfg.modality == "audio":
        out["frame_embeds"] = P(bspec, None, None)
    else:
        out["tokens"] = P(bspec, None)
        if cfg.modality == "vision":
            out["patch_embeds"] = P(bspec, None, None)
    out["labels"] = P(bspec, None)
    return out


def cache_batch_axes(cfg: ModelConfig, shape: ShapeSpec, mesh):
    """How to shard (batch, seq) of KV caches: batch over dp when divisible,
    otherwise shard cache *sequence* over 'data' (long-context batch=1)."""
    dp = dp_axes(mesh)
    dp_size = int(np.prod([axis_size(mesh, a) for a in dp]))
    if shape.global_batch % dp_size == 0 and shape.global_batch >= dp_size:
        return dp, None          # (batch axes, seq axes)
    return None, ("data",)       # sequence-sharded decode


def kv_cache_spec(cfg: ModelConfig, shape: ShapeSpec, mesh, lead: int,
                  mla: bool) -> Any:
    """Spec for one stage's stacked KVCache; ``lead`` = # leading stack dims.

    Head dim is sharded over ``model`` when it divides evenly; otherwise the
    cache *sequence* is sharded over ``model`` (MQA kv=1, kv=8 vs 16-way TP,
    MHA kv=40) — even sharding beats GSPMD padding waste. MLA caches shard
    the latent dim (it is 512 = 32x16)."""
    b_ax, s_ax = cache_batch_axes(cfg, shape, mesh)
    pad = [None] * lead
    if mla:  # (..., B, S, r) latent + (..., B, S, rope)
        lat = "model" if cfg.mla.kv_lora_rank % axis_size(mesh, "model") == 0 \
            else None
        k = P(*pad, b_ax, s_ax, lat)
        v = P(*pad, b_ax, s_ax, None)
    else:    # (..., B, S, KV, hd)
        from repro.models.attention import padded_heads
        if padded_heads(cfg)[1] % axis_size(mesh, "model") == 0:
            heads, seq = "model", s_ax
        else:
            heads = None
            seq = ("data", "model") if s_ax else "model"
        k = P(*pad, b_ax, seq, heads, None)
        v = P(*pad, b_ax, seq, heads, None)
    from repro.models.attention import KVCache
    return KVCache(k, v)


def mamba_cache_spec(cfg: ModelConfig, shape: ShapeSpec, mesh,
                     lead: int) -> Any:
    b_ax, _ = cache_batch_axes(cfg, shape, mesh)
    pad = [None] * lead
    d_inner = cfg.ssm.expand * cfg.d_model
    n_heads = d_inner // cfg.ssm.head_dim
    heads = "model" if n_heads % axis_size(mesh, "model") == 0 else None
    from repro.models.mamba2 import MambaCache
    return MambaCache(
        ssm=P(*pad, b_ax, heads, None, None),
        conv_x=P(*pad, b_ax, None, "model"),
        conv_bc=P(*pad, b_ax, None, None),
    )


def cache_specs(cfg: ModelConfig, shape: ShapeSpec, mesh):
    """Spec tree matching lm.init_cache's structure."""
    from repro.models.lm import stage_plan
    out = []
    for kind, n in stage_plan(cfg):
        if kind == "mamba":
            out.append(mamba_cache_spec(cfg, shape, mesh, lead=1))
        elif kind == "hybrid":
            out.append({
                "mamba": mamba_cache_spec(cfg, shape, mesh, lead=2),
                "attn": kv_cache_spec(cfg, shape, mesh, lead=1,
                                      mla=cfg.attn_type == "mla"),
            })
        else:
            out.append(kv_cache_spec(cfg, shape, mesh, lead=1,
                                     mla=cfg.attn_type == "mla"))
    return out
